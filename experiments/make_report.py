"""Render the EXPERIMENTS.md roofline tables from the dry-run JSONs."""
import json
import sys


def table(path, mesh="single"):
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, v in sorted(results.items()):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        if v.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | skipped | — | — | — | — | — | — |")
            continue
        if v.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | ERROR | — | — | — | — | — | — |")
            continue
        r = v["roofline"]
        p = v["per_device"]
        rows.append(
            f"| {arch} | {shape} | {r['bottleneck']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} "
            f"| {r['useful_flop_fraction']:.3f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {p['argument_bytes'] / 2**30:.2f} |")
    head = ("| arch | shape | bottleneck | compute (s) | memory (s) | "
            "collective (s) | useful-FLOP frac | roofline frac | args GiB/dev |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def multi_pod_check(path):
    with open(path) as f:
        results = json.load(f)
    n_ok = sum(1 for k, v in results.items()
               if k.endswith("|multi") and v.get("status") == "ok")
    n_skip = sum(1 for k, v in results.items()
                 if k.endswith("|multi") and v.get("status") == "skipped")
    n_err = sum(1 for k, v in results.items()
                if k.endswith("|multi") and v.get("status") == "error")
    return n_ok, n_skip, n_err


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json"
    print(table(path))
    print()
    print("multi-pod:", multi_pod_check(path))
