"""One benchmark function per paper table. Each emits CSV rows
(name,us_per_call,derived) where us_per_call is the quantization wall time
and derived is the metric (ppl / accuracy / bits)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import APConfig, CLAQConfig, ORConfig

from . import common
from .common import emit, perplexity, quantized, recipe, trained_model, \
    zero_shot_proxy_accuracy


def table1_ppl():
    """Table 1: perplexity by method x bit-width (fp / RTN / GPTQ / CLAQ /
    CLAQ* fusion)."""
    cfg, params, hess = trained_model()
    rows = [("table1/fp16,16bit", 0.0, f"ppl={perplexity(cfg, params):.4f}")]
    for tag in ("rtn4", "rtn3", "gptq4", "claq4", "gptq3", "claq3",
                "gptq2", "claq2", "claq2.12", "claq2.24"):
        hessians = {} if tag.startswith("rtn") else None
        c, qp, rep, us = quantized(recipe(tag), hessians=hessians)
        rows.append((f"table1/{tag}", us,
                     f"ppl={perplexity(c, qp):.4f};bits={rep.mean_effective_bits:.2f}"))
    emit(rows)
    return rows


def table2_zeroshot():
    """Table 2: zero-shot proxy accuracy (cloze ranking), fp vs low-bit."""
    cfg, params, _ = trained_model()
    rows = [("table2/fp16", 0.0,
             f"acc={zero_shot_proxy_accuracy(cfg, params):.4f}")]
    for tag in ("claq4", "gptq2", "claq2.12"):
        c, qp, rep, us = quantized(recipe(tag))
        rows.append((f"table2/{tag}", us,
                     f"acc={zero_shot_proxy_accuracy(c, qp):.4f}"))
    emit(rows)
    return rows


def table3_ap():
    """Table 3: Adaptive Precision (Outlier Order) vs MP-dagger
    (magnitude metric) at matched average bits."""
    rows = []
    for target in (2.1, 2.2, 2.5):
        for metric, tag in (("magnitude_mp", "mp"), ("outlier_order", "ap")):
            qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                              gptq_blocksize=32,
                              ap=APConfig(target, 2, 4), metric=metric)
            c, qp, rep, us = quantized(qcfg)
            rows.append((f"table3/{tag}_{target}", us,
                         f"ppl={perplexity(c, qp):.4f};bits={rep.mean_effective_bits:.2f}"))
    emit(rows)
    return rows


def table4_or():
    """Table 4: adaptive OR vs fixed per-column outlier keeping."""
    rows = []
    for extra in (0.14, 0.28):
        for (o1, o2, tag) in ((0.10, 0.90, "fix"), (0.28, 0.72, "or")):
            qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                              gptq_blocksize=32,
                              orr=ORConfig(extra, o1=o1, o2=o2))
            c, qp, rep, us = quantized(qcfg)
            rows.append((f"table4/{tag}_{2 + extra:.2f}", us,
                         f"ppl={perplexity(c, qp):.4f};bits={rep.mean_effective_bits:.2f}"))
    emit(rows)
    return rows


def table5_outlier_standard():
    """Appendix B: outlier standard S sweep at 2.2-bit AP."""
    rows = []
    for S in (1, 5, 9, 13, 17):
        qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                          gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                          outlier_standard=float(S))
        c, qp, rep, us = quantized(qcfg)
        rows.append((f"table5/S{S}", us, f"ppl={perplexity(c, qp):.4f}"))
    emit(rows)
    return rows


def table6_or_split():
    """Appendix C: OR budget split settings 1/2/3."""
    rows = []
    for o1, tag in ((0.19, "setting1"), (0.28, "setting2"), (0.37, "setting3")):
        qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                          gptq_blocksize=32,
                          orr=ORConfig(0.28, o1=o1, o2=1.0 - o1))
        c, qp, rep, us = quantized(qcfg)
        rows.append((f"table6/{tag}", us, f"ppl={perplexity(c, qp):.4f}"))
    emit(rows)
    return rows


def table7_bit_pairs():
    """Appendix D: AP candidate pair 2&3 vs 2&4 at 2.1 average bits."""
    rows = []
    for p_hi, tag in ((3, "2and3"), (4, "2and4")):
        qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                          gptq_blocksize=32, ap=APConfig(2.1, 2, p_hi))
        c, qp, rep, us = quantized(qcfg)
        rows.append((f"table7/{tag}", us, f"ppl={perplexity(c, qp):.4f}"))
    emit(rows)
    return rows


def table12_heuristic_search():
    """Appendix G: heuristic cross-matrix AP search vs plain AP at 2.5."""
    import jax
    from repro.core import MatrixInfo, heuristic_ap_search, layer_outlier_ratio
    from repro.core.search import assignment_to_claq_configs
    from repro.launch.quantize import quantize_model_params

    cfg, params, hess = trained_model()
    # plain AP 2.5
    c, qp, rep, us = quantized(CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
        ap=APConfig(2.5, 2, 4)))
    rows = [("table12/plain_ap_2.5", us, f"ppl={perplexity(c, qp):.4f}")]

    # heuristic search: rank matrices by whole-matrix outlier ratio
    flat = jax.tree_util.tree_flatten_with_path(params["blocks"])[0]
    mats = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if "kernel" not in name or leaf.ndim != 3:
            continue
        for i in range(leaf.shape[0]):
            mats.append(MatrixInfo(f"{name}[{i}]", leaf.shape[1],
                                   leaf.shape[2],
                                   float(layer_outlier_ratio(leaf[i]))))
    res = heuristic_ap_search(mats, target_bits=2.5)
    rows.append(("table12/heuristic_search", 0.0,
                 f"avg_bits={res.avg_bits:.3f};score={res.score:.3f};"
                 f"n_24={sum(1 for v in res.assignment.values() if v[0] == (2, 4))}"))
    emit(rows)
    return rows


def table13_calibration():
    """Appendix H: calibration-set distribution effect (c4like vs wikilike
    calibration, evaluated on both)."""
    from repro.data import calibration_set
    from repro.launch.quantize import calibrate

    cfg, params, _ = trained_model()
    rows = []
    for calib_name in ("c4like", "wikilike"):
        calib = calibration_set(vocab=common.VOCAB, n_segments=16,
                                seq_len=common.SEQ, name=calib_name)
        hess = calibrate(params, cfg, calib, batch_size=4)
        c, qp, rep, us = quantized(recipe("claq3"), hessians=hess)
        rows.append((f"table13/calib_{calib_name}", us,
                     f"ppl_c4like={perplexity(c, qp, 'c4like'):.4f};"
                     f"ppl_wikilike={perplexity(c, qp, 'wikilike'):.4f}"))
    emit(rows)
    return rows
