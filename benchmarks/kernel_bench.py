"""Kernel microbenchmarks + structural perf accounting.

Wall times on this CPU container are NOT TPU estimates; the TPU-relevant
derived quantities are structural: HBM bytes per matmul for the CLAQ
kernel path vs the dense-bf16 path (the memory-bound decode speedup the
deployment format buys), kernel-launch counts for the ahead-of-time plan
path vs the per-stripe path, and interpret-mode correctness timing.

`kernel_bench()` also writes BENCH_kernel.json at the repo root so the
prepared-vs-unprepared perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import APConfig, CLAQConfig, ORConfig, quantize_matrix
from repro.kernels import dequant_matmul as dm
from repro.kernels import ops, ref as ref_lib
from repro.kernels.plan import prepare_for_inference

_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernel.json")


def _sample(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def _time_pair(fn_a, fn_b, *args, reps=11):
    """Interleaved A/B timing: alternating samples cancel container CPU
    drift; min-of-N is robust to additive noise (this box is shared)."""
    for fn in (fn_a, fn_b):
        fn(*args)  # compile / warm caches
        fn(*args)
    a, b = [], []
    for _ in range(reps):
        a.append(_sample(fn_a, *args))
        b.append(_sample(fn_b, *args))
    return float(np.min(a)), float(np.min(b))


def _quantize(W, bits):
    """One tensor per benchmarked bit-width; fractional widths get the
    paper's AP+OR fusion (multi-stripe mixed precision + outliers)."""
    base = int(bits)
    ap = orr = None
    if bits != base:
        ap = APConfig(base + (bits - base) * 0.6, base, 4)
        orr = ORConfig((bits - base) * 0.4)
    qt, _, _ = quantize_matrix(W, None, CLAQConfig(
        bits=base, method="kmeans", kmeans_iters=4, gptq_blocksize=128,
        ap=ap, orr=orr))
    return qt


def kernel_bench(out_json: str = _BENCH_JSON):
    rows = []
    results = {}
    rng = np.random.default_rng(0)
    n, k_dim, m = 512, 512, 64
    W = jnp.asarray(rng.normal(size=(n, k_dim)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k_dim)).astype(np.float32))

    for bits in (2, 2.5, 3, 4):
        qt = _quantize(W, bits)
        pqt = prepare_for_inference(qt)

        # structural HBM bytes per token for the weight stream:
        dense_bytes = n * k_dim * 2                       # bf16 weights
        q_bytes = sum(s.packed.size * 4 + s.codebook.size * 2
                      for s in qt.stripes)
        ratio = dense_bytes / q_bytes

        # XLA (dry-run lowering) path, jitted steady state
        us_xla_unprep, us_xla_prep = _time_pair(
            jax.jit(lambda a, q=qt: ops.qmatmul(a, q)),
            jax.jit(lambda a, q=pqt: ops.qmatmul(a, q)), x)

        # Pallas interpret path (eager dispatch, counts real launches)
        def run_unprep(a, q=qt):
            return ops.qmatmul(a, q, use_kernel=True, interpret=True)

        def run_prep(a, q=pqt):
            return ops.qmatmul(a, q, use_kernel=True, interpret=True)

        c0 = dm.launch_count
        run_unprep(x)
        launches_unprep = dm.launch_count - c0
        c0 = dm.launch_count
        run_prep(x)
        launches_prep = dm.launch_count - c0

        us_ker_unprep, us_ker_prep = _time_pair(run_unprep, run_prep, x)

        err = float(jnp.max(jnp.abs(run_prep(x) - ref_lib.ref_qmatmul(x, qt))))

        key = str(bits)
        results[key] = {
            "stripes": [(s.bits, s.n_cols) for s in qt.stripes],
            "distinct_bitwidths": len({s.bits for s in qt.stripes}),
            "launches_unprepared": launches_unprep,
            "launches_prepared": launches_prep,
            "xla_us_unprepared": us_xla_unprep,
            "xla_us_prepared": us_xla_prep,
            "interp_us_unprepared": us_ker_unprep,
            "interp_us_prepared": us_ker_prep,
            "weight_bytes_ratio_vs_bf16": ratio,
            "prepared_max_err_vs_ref": err,
        }
        rows.append((f"kernel/dequant_matmul_{key}bit_xla_unprepared",
                     us_xla_unprep, f"weight_bytes_ratio={ratio:.2f}"))
        rows.append((f"kernel/dequant_matmul_{key}bit_xla_prepared",
                     us_xla_prep,
                     f"speedup={us_xla_unprep / max(us_xla_prep, 1e-9):.2f}x"))
        rows.append((f"kernel/dequant_matmul_{key}bit_interp_unprepared",
                     us_ker_unprep, f"launches={launches_unprep}"))
        rows.append((f"kernel/dequant_matmul_{key}bit_interp_prepared",
                     us_ker_prep,
                     f"launches={launches_prep};max_err={err:.2e}"))

    with open(out_json, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    rows.append((f"kernel/bench_json_written", 0.0, out_json))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def roofline_rows(dryrun_path="experiments/dryrun.json"):
    """Surface the dry-run roofline table through the benchmark CSV."""
    rows = []
    if not os.path.exists(dryrun_path):
        print("roofline/missing,0.0,run launch.dryrun first")
        return rows
    with open(dryrun_path) as f:
        results = json.load(f)
    for key, v in sorted(results.items()):
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline/{key}", dom * 1e6,
                     f"bottleneck={r['bottleneck']};"
                     f"frac={r['roofline_fraction']:.4f};"
                     f"useful={r['useful_flop_fraction']:.3f}"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
