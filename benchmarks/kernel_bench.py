"""Kernel microbenchmarks + structural perf accounting.

Wall times on this CPU container are NOT TPU estimates; the TPU-relevant
derived quantities are structural: HBM bytes per matmul for the CLAQ
kernel path vs the dense-bf16 path (the memory-bound decode speedup the
deployment format buys), kernel-launch counts for the ahead-of-time plan
path vs the per-stripe path, and interpret-mode correctness timing.

Rows cover decode-shaped matmuls (M=1 single-token, M=8 a decode batch)
next to the prefill-ish M=64, and A/B the two activation-fetch paths of
the prepared matmul: the pre-fold XLA gather (gather="xla") vs the
in-kernel fetch (gather="kernel" — aligned block reads for integer
bit-widths, in-kernel takes for mixed-precision plans; DESIGN.md §9).
The opt-in int8 activation path is timed alongside with its measured
error against the f32 reference checked under the documented bound.
A small bk/bn sweep at 4 bits chases the near-parity prepared result
PR 1 left on the table.

`kernel_bench()` writes BENCH_kernel.json at the repo root so the
prepared-vs-unprepared perf trajectory is tracked across PRs.  `--smoke`
(the CI step) shrinks reps and SELF-ASSERTS the structural claims:
prepared runs at or under the unprepared time, and the in-kernel gather
at or under the XLA gather's time, at every bit-width (a 25% tolerance
plus a 4x-reps re-measure absorbs shared-box noise; the interleaved
min-of-N sampling cancels drift).

  PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import APConfig, CLAQConfig, ORConfig, quantize_matrix
from repro.kernels import dequant_matmul as dm
from repro.kernels import ops, ref as ref_lib
from repro.kernels.plan import prepare_for_inference

_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernel.json")

# shared-box noise tolerance for the smoke-mode self-asserts
_SMOKE_SLACK = 1.25


def _sample(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def _time_pair(fn_a, fn_b, *args, reps=11):
    """Interleaved A/B timing: alternating samples cancel container CPU
    drift; min-of-N is robust to additive noise (this box is shared)."""
    for fn in (fn_a, fn_b):
        fn(*args)  # compile / warm caches
        fn(*args)
    a, b = [], []
    for _ in range(reps):
        a.append(_sample(fn_a, *args))
        b.append(_sample(fn_b, *args))
    return float(np.min(a)), float(np.min(b))


def _assert_not_slower(fast_fn, base_fn, x, us_fast, us_base, reps, label):
    """Smoke-mode perf claim with one escalation: this box's wall times
    swing ~2x under neighboring load, so a first-pass miss re-measures
    with 4x the samples before declaring a regression."""
    if us_fast <= us_base * _SMOKE_SLACK:
        return us_fast, us_base
    us_base, us_fast = _time_pair(base_fn, fast_fn, x, reps=4 * reps)
    assert us_fast <= us_base * _SMOKE_SLACK, (
        f"{label}: {us_fast:.0f}us vs baseline {us_base:.0f}us "
        f"(> {_SMOKE_SLACK:.2f}x, re-measured)")
    return us_fast, us_base


def _quantize(W, bits):
    """One tensor per benchmarked bit-width; fractional widths get the
    paper's AP+OR fusion (multi-stripe mixed precision + outliers)."""
    base = int(bits)
    ap = orr = None
    if bits != base:
        ap = APConfig(base + (bits - base) * 0.6, base, 4)
        orr = ORConfig((bits - base) * 0.4)
    qt, _, _ = quantize_matrix(W, None, CLAQConfig(
        bits=base, method="kmeans", kmeans_iters=4, gptq_blocksize=128,
        ap=ap, orr=orr))
    return qt


def kernel_bench(out_json: str = _BENCH_JSON, smoke: bool = False):
    rows = []
    results = {}
    rng = np.random.default_rng(0)
    n, k_dim = 512, 512
    reps = 9 if smoke else 17
    ms = (1, 8) if smoke else (1, 8, 64)
    W = jnp.asarray(rng.normal(size=(n, k_dim)).astype(np.float32))
    xs = {m: jnp.asarray(rng.normal(size=(m, k_dim)).astype(np.float32))
          for m in ms}

    for bits in (2, 2.5, 3, 4):
        qt = _quantize(W, bits)
        pqt = prepare_for_inference(qt)

        # structural HBM bytes per token for the weight stream:
        dense_bytes = n * k_dim * 2                       # bf16 weights
        q_bytes = sum(s.packed.size * 4 + s.codebook.size * 2
                      for s in qt.stripes)
        ratio = dense_bytes / q_bytes

        def run_unprep(a, q=qt):
            return ops.qmatmul(a, q, use_kernel=True, interpret=True)

        def run_xla_gather(a, q=pqt):
            return ops.prepared_qmatmul(a, q, gather="xla")

        def run_kernel_gather(a, q=pqt):
            return ops.prepared_qmatmul(a, q, gather="kernel")

        def run_int8(a, q=pqt):
            return ops.prepared_qmatmul(a, q, gather="kernel",
                                        act_dtype="int8")

        x_big = xs[max(ms)]
        c0 = dm.launch_count
        run_unprep(x_big)
        launches_unprep = dm.launch_count - c0
        c0 = dm.launch_count
        run_kernel_gather(x_big)
        launches_prep = dm.launch_count - c0

        # prepared-vs-unprepared continuity row (PR 1's fusion claim) at
        # the largest M, on the in-kernel-gather path serving now
        us_unprep, us_prep = _time_pair(run_unprep, run_kernel_gather,
                                        x_big, reps=reps)
        err = float(jnp.max(jnp.abs(run_kernel_gather(x_big)
                                    - ref_lib.ref_qmatmul(x_big, qt))))
        if smoke:
            us_prep, us_unprep = _assert_not_slower(
                run_kernel_gather, run_unprep, x_big, us_prep, us_unprep,
                reps, f"{bits}-bit prepared-vs-unprepared")

        key = str(bits)
        results[key] = {
            "stripes": [(s.bits, s.n_cols) for s in qt.stripes],
            "distinct_bitwidths": len({s.bits for s in qt.stripes}),
            "x_gather_free": pqt.x_gather_free,
            "launches_unprepared": launches_unprep,
            "launches_prepared": launches_prep,
            "interp_us_unprepared": us_unprep,
            "interp_us_prepared": us_prep,
            "weight_bytes_ratio_vs_bf16": ratio,
            "prepared_max_err_vs_ref": err,
        }
        rows.append((f"kernel/dequant_matmul_{key}bit_interp_unprepared",
                     us_unprep, f"weight_bytes_ratio={ratio:.2f};"
                     f"launches={launches_unprep}"))
        rows.append((f"kernel/dequant_matmul_{key}bit_interp_prepared",
                     us_prep, f"launches={launches_prep};max_err={err:.2e}"))

        # decode-shaped rows: in-kernel gather vs XLA gather, + int8
        Wd = qt.dequantize()
        for m in ms:
            x = xs[m]
            # recorded figures sample at 4x the smoke budget, in TWO
            # temporally separated passes min-combined — unconditional, so
            # no result-conditioned re-roll can bias the published A/B
            # (smoke keeps the small budget; its asserts escalate
            # themselves on a miss)
            us_xla, us_ker = _time_pair(run_xla_gather, run_kernel_gather,
                                        x, reps=reps if smoke else 4 * reps)
            if smoke:
                us_i8 = min(_sample(run_int8, x) for _ in range(reps))
            else:
                a2, k2 = _time_pair(run_xla_gather, run_kernel_gather,
                                    x, reps=4 * reps)
                us_xla, us_ker = min(us_xla, a2), min(us_ker, k2)
                # int8 rides the same protocol: interleaved against the
                # kernel-gather baseline (drift-cancelled), two separated
                # passes min-combined; the companion sample is discarded
                # so the published A/B pair stays symmetric
                _, i1 = _time_pair(run_kernel_gather, run_int8, x,
                                   reps=2 * reps)
                _, i2 = _time_pair(run_kernel_gather, run_int8, x,
                                   reps=2 * reps)
                us_i8 = min(i1, i2)
            assert np.array_equal(np.asarray(run_kernel_gather(x)),
                                  np.asarray(run_xla_gather(x))), \
                f"{bits}-bit m={m}: gather paths diverged (must be bitwise)"
            y_ref = ref_lib.ref_qmatmul(x, qt)
            err_el = jnp.abs(run_int8(x) - y_ref)
            bound_el = ref_lib.ref_act_int8_bound(x, Wd)
            # per-ELEMENT check (the documented guarantee is per output
            # element; a global max-vs-max compare would let one token's
            # violation hide under another token's larger bound)
            assert bool(jnp.all(err_el <= bound_el * 1.01 + 1e-5)), \
                (bits, m, float(jnp.max(err_el - bound_el)))
            i8_err = float(jnp.max(err_el))
            i8_bound = float(jnp.max(bound_el))
            if smoke:
                us_ker, us_xla = _assert_not_slower(
                    run_kernel_gather, run_xla_gather, x, us_ker, us_xla,
                    reps, f"{bits}-bit m={m} in-kernel-vs-XLA gather")
            results[key][f"m{m}"] = {
                "interp_us_xla_gather": us_xla,
                "interp_us_kernel_gather": us_ker,
                "interp_us_int8": us_i8,
                "int8_max_err": i8_err,
                "int8_err_bound": i8_bound,
            }
            rows.append((f"kernel/dequant_matmul_{key}bit_m{m}_xla_gather",
                         us_xla, "prefold_take"))
            rows.append((f"kernel/dequant_matmul_{key}bit_m{m}_kernel_gather",
                         us_ker,
                         f"speedup={us_xla / max(us_ker, 1e-9):.2f}x;"
                         f"gather_free={pqt.x_gather_free}"))
            rows.append((f"kernel/dequant_matmul_{key}bit_m{m}_act_int8",
                         us_i8, f"max_err={i8_err:.2e};bound={i8_bound:.2e}"))

    # bk/bn sweep at 4 bits, decode batch shape (the near-parity cell PR 1
    # left: plan tiles were never tuned below the defaults)
    if not smoke:
        qt4 = _quantize(W, 4)
        x8 = xs[8]
        sweep = {}
        for bk, bn in ((128, 128), (256, 128), (512, 128), (512, 256),
                       (512, 512)):
            p = prepare_for_inference(qt4, bn=bn, bk=bk)

            def run(a, q=p):
                return ops.prepared_qmatmul(a, q, gather="kernel")

            run(x8)
            us = min(_sample(run, x8) for _ in range(reps))
            sweep[f"bk{bk}_bn{bn}"] = us
            rows.append((f"kernel/sweep_4bit_m8_bk{bk}_bn{bn}", us, ""))
        best = min(sweep, key=sweep.get)
        results["sweep_4bit_m8"] = {**sweep, "best": best}
        rows.append((f"kernel/sweep_4bit_m8_best", sweep[best], best))

    with open(out_json, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
        f.write("\n")
    rows.append(("kernel/bench_json_written", 0.0, out_json))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def roofline_rows(dryrun_path="experiments/dryrun.json"):
    """Surface the dry-run roofline table through the benchmark CSV."""
    rows = []
    if not os.path.exists(dryrun_path):
        print("roofline/missing,0.0,run launch.dryrun first")
        return rows
    with open(dryrun_path) as f:
        results = json.load(f)
    for key, v in sorted(results.items()):
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline/{key}", dom * 1e6,
                     f"bottleneck={r['bottleneck']};"
                     f"frac={r['roofline_fraction']:.4f};"
                     f"useful={r['useful_flop_fraction']:.3f}"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer reps, decode shapes only, and "
                         "self-assert that prepared runs no slower than "
                         "unprepared and the in-kernel gather no slower "
                         "than the XLA gather, at every bit-width")
    ap.add_argument("--out", default=_BENCH_JSON)
    args = ap.parse_args()
    kernel_bench(out_json=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
