"""Kernel microbenchmarks + structural perf accounting.

Wall times on this CPU container are NOT TPU estimates; the TPU-relevant
derived quantities are structural: HBM bytes per matmul for the CLAQ
kernel path vs the dense-bf16 path (the memory-bound decode speedup the
deployment format buys), and interpret-mode correctness timing.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CLAQConfig, quantize_matrix
from repro.kernels import ops, ref as ref_lib


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def kernel_bench():
    rows = []
    rng = np.random.default_rng(0)
    n, k_dim, m = 512, 512, 64
    W = jnp.asarray(rng.normal(size=(n, k_dim)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k_dim)).astype(np.float32))

    for bits in (2, 3, 4):
        qt, _, _ = quantize_matrix(W, None, CLAQConfig(
            bits=bits, method="kmeans", kmeans_iters=4, gptq_blocksize=128))

        # structural HBM bytes per token for the weight stream:
        dense_bytes = n * k_dim * 2                       # bf16 weights
        q_bytes = sum(s.packed.size * 4 + s.codebook.size * 2
                      for s in qt.stripes)
        ratio = dense_bytes / q_bytes

        us_ref = _time(jax.jit(lambda a, q=qt: ops.qmatmul(a, q)), x)
        us_ker = _time(lambda a, q=qt: ops.qmatmul(
            a, q, use_kernel=True, interpret=True), x)
        err = float(jnp.max(jnp.abs(
            ops.qmatmul(x, qt, use_kernel=True, interpret=True)
            - ref_lib.ref_qmatmul(x, qt))))
        rows.append((f"kernel/dequant_matmul_{bits}bit_xla", us_ref,
                     f"weight_bytes_ratio={ratio:.2f}"))
        rows.append((f"kernel/dequant_matmul_{bits}bit_pallas_interp", us_ker,
                     f"max_err={err:.2e}"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def roofline_rows(dryrun_path="experiments/dryrun.json"):
    """Surface the dry-run roofline table through the benchmark CSV."""
    import json
    import os
    rows = []
    if not os.path.exists(dryrun_path):
        print("roofline/missing,0.0,run launch.dryrun first")
        return rows
    with open(dryrun_path) as f:
        results = json.load(f)
    for key, v in sorted(results.items()):
        if v.get("status") != "ok":
            continue
        r = v["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline/{key}", dom * 1e6,
                     f"bottleneck={r['bottleneck']};"
                     f"frac={r['roofline_fraction']:.4f};"
                     f"useful={r['useful_flop_fraction']:.3f}"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
