"""Serving-loop benchmark: vanilla vs self-speculative decode.

Wall times on this CPU container are NOT TPU estimates; the structural,
deterministic quantities are the deliverable: decode STEPS to drain a
request wave and accepted tokens per step (the decode-cadence multiplier
speculation buys), draft acceptance rate (how well a 2-bit CLAQ draft
tracks its higher-bit target when both come from ONE calibration pass),
and the compile counts proving the speculative path adds a constant
number of traces.  Greedy speculation is lossless, so the bench also
ASSERTS token parity between the vanilla and speculative engines — a
benchmark that cannot silently measure a broken configuration.

The substrate is benchmarks.common.trained_model(): a model trained until
it clearly beats unigram, so its logits are PEAKED — on a random-init
model any quantization noise flips the near-uniform argmax and acceptance
collapses to ~0, which measures nothing.  Target and draft are quantized
from the model's one set of tapped Hessians (the
`claq_quantize_with_draft` contract with calibration amortized).

The ROBUSTNESS scenario drives an engine through a seeded deterministic
fault plan (serve/faults.py: NaN/Inf logit injection, cache-pressure
windows forcing preemption+resume, bursty Poisson arrivals against a
bounded queue, transient step failures absorbed by bounded retry) and
ASSERTS the lifecycle contract instead of timing it: zero hangs, every
submitted request terminal, FINISHED requests' tokens bit-identical to a
clean engine's (including preempted-and-resumed ones), and an exact
replay under the same seed.  Counters (terminal states, preemptions,
resumes, backpressure) land in BENCH_serve.json next to the speed rows.
It runs on an fp smoke model — lifecycle behavior is numerics-blind, so
CI's `--inject-faults` mode skips the trained-model setup entirely.

The PAGED CAPACITY scenario fixes an HBM budget (the contiguous
layout's slot-cache bytes) and counts admissions before typed
backpressure under a shared system prompt: contiguous slots vs a paged
pool of the same byte size (DESIGN.md §11), fp and int8 resident pages.
It ASSERTS paged >= 2x contiguous and int8 >= paged fp, and the counts
land in BENCH_serve.json under ``paged_capacity``.

The REPLAY scenario (serve/replay.py, DESIGN.md §13) drives a seeded
synthesized arrival trace through a telemetry-instrumented engine under
a pressure-window fault plan and records the scheduling report —
TTFT/TPOT p50/p90/p99, tokens/s/slot, queue-depth and page-occupancy
timelines — under ``results["replay"]``.  It ASSERTS that the telemetry
hooks are observation-only: the telemetry-on and telemetry-off token
streams must be bit-identical, and the preempt/resume path must have
actually fired (a latency report over an idle engine proves nothing).

`serve_bench()` writes BENCH_serve.json at the repo root (the serving
trajectory's counterpart to BENCH_kernel.json); CI runs `--smoke` and
the fault-injection smoke `--smoke --inject-faults`.

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from repro.core import APConfig, CLAQConfig, ORConfig, draft_config
from repro.launch.quantize import quantize_model_params
from repro.serve import (AdmissionRejected, FaultInjector, Replayer,
                         RetryPolicy, ServingEngine, SpecConfig, StepClock,
                         Telemetry, synthesize_trace, validate_report)

_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")

GAMMAS = (2, 4)


def _run(eng, prompts, max_new):
    """Admit everything, decode to completion; returns (tokens in prompt
    order, steps, decode seconds)."""
    uids = eng.add_requests(prompts, max_new_tokens=max_new)
    steps = 0
    t_decode = 0.0
    while eng.active:
        t0 = time.perf_counter()
        eng.step()
        t_decode += time.perf_counter() - t0
        steps += 1
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids], steps, t_decode


def robustness_scenario(smoke: bool = False, seed: int = 0) -> dict:
    """Seeded fault-plan survival run (see module docstring).  Returns the
    counters recorded under ``results["robustness"]``; raises on any
    lifecycle-contract violation (hang, non-terminal request, parity break,
    replay divergence) so CI cannot silently pass a broken engine."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api

    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    horizon = 24 if smoke else 48
    # pressure_frac is tuned so the windows' limit (frac * max_len) falls
    # BELOW running fills — a pressure window that never preempts anything
    # would record a vacuous survival
    injector_kw = dict(seed=seed, horizon=horizon, arrival_lambda=0.25,
                       burst_every=10, burst_size=2, pressure_windows=2,
                       pressure_frac=(0.15, 0.25))
    max_new = 8 if smoke else 12
    n_slots, max_len = 3, 48
    prng = np.random.default_rng(1)
    prompts = [prng.integers(1, cfg.vocab,
                             size=prng.integers(3, 11)).tolist()
               for _ in range(4 * horizon)]

    def engine(**kw):
        return ServingEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                             min_bucket=8, **kw)

    def run_once():
        injector = FaultInjector(**injector_kw)
        clock = StepClock(step_ms=10.0)
        eng = engine(guards=True, faults=injector, clock=clock,
                     queue_depth=4, on_pressure="preempt")
        retry = RetryPolicy(max_attempts=4, backoff_s=0.0)
        submitted = []                       # (uid, prompt index)
        pending = []
        next_idx = 0
        retries = backpressure = 0
        step = 0
        max_steps = 40 * horizon             # hang budget, far above need
        while step < max_steps:
            if step < injector.horizon:
                for _ in range(injector.arrivals(step)):
                    pending.append(next_idx)
                    next_idx += 1
            while pending:
                idx = pending[0]
                # every third request carries a tight SLO: under queueing
                # and pressure windows some of these MUST abandon
                dl = 150.0 if idx % 3 == 2 else None
                try:
                    uid = eng.submit(prompts[idx], max_new_tokens=max_new,
                                     deadline_ms=dl)
                except AdmissionRejected:
                    backpressure += 1        # bounded queue pushed back
                    break
                submitted.append((uid, idx))
                pending.pop(0)
            _, r = retry.run(eng.step)
            retries += r
            clock.advance()
            step += 1
            if (step >= injector.horizon and not pending and not eng.active
                    and not len(eng.queue)):
                break
        fin = eng.take_finished()
        outcome = [(idx,
                    fin[uid].state.value if uid in fin else "nonterminal",
                    list(fin[uid].tokens) if uid in fin else None)
                   for uid, idx in submitted]
        return {"outcome": outcome, "stats": eng.stats(),
                "retries": retries, "backpressure": backpressure,
                "steps": step, "hang": step >= max_steps}

    r1 = run_once()
    assert not r1["hang"], (
        f"robustness scenario did not drain in {r1['steps']} driver steps")
    assert all(state != "nonterminal" for _, state, _ in r1["outcome"]), (
        f"non-terminal requests survived the run: {r1['outcome']}")

    # exact replay: same seed -> bit-identical outcomes and counters
    r2 = run_once()
    assert r1["outcome"] == r2["outcome"], "seeded fault plan did not replay"
    assert r1["stats"]["lifecycle"] == r2["stats"]["lifecycle"]
    assert r1["retries"] == r2["retries"]

    # FINISHED parity: a clean engine over the same prompts must emit the
    # same tokens — in particular for requests preempted and resumed
    fin_idx = [idx for idx, state, _ in r1["outcome"] if state == "finished"]
    assert fin_idx, "no request finished under the fault plan"
    clean = engine()
    base = {}
    for i in range(0, len(fin_idx), n_slots):
        chunk = fin_idx[i:i + n_slots]
        uids = clean.add_requests([prompts[j] for j in chunk],
                                  max_new_tokens=max_new)
        clean.run_to_completion()
        fin = clean.take_finished()
        for j, u in zip(chunk, uids):
            base[j] = fin[u].tokens
    for idx, state, toks in r1["outcome"]:
        if state == "finished":
            assert toks == base[idx], (
                f"request {idx} finished with divergent tokens under "
                f"faults: {toks} vs clean {base[idx]}")

    st = r1["stats"]
    # the plan must have actually exercised the preemption/resume path —
    # a survival claim over faults that never fired proves nothing
    assert st["preemptions"] >= 1 and st["resumes"] >= 1, (
        f"fault plan never preempted (preemptions={st['preemptions']}, "
        f"resumes={st['resumes']}): scenario is vacuous, retune "
        f"pressure_frac")
    assert r1["retries"] >= 1, "no transient step failure was retried"
    return {
        "plan": FaultInjector(**injector_kw).describe(),
        "submitted": len(r1["outcome"]),
        "driver_steps": r1["steps"],
        "lifecycle": st["lifecycle"],
        "preemptions": st["preemptions"],
        "resumes": st["resumes"],
        "admission_rejections": st["admission_rejections"],
        "backpressure_waits": r1["backpressure"],
        "transient_retries": r1["retries"],
        "finished": len(fin_idx),
        "finished_parity": True,
        "deterministic_replay": True,
        "all_terminal": True,
    }


def replay_scenario(smoke: bool = False, seed: int = 0) -> dict:
    """Trace-driven replay under a preempt/resume storm (see module
    docstring).  Returns the scheduling-report subset recorded under
    ``results["replay"]``; raises if telemetry perturbs the token stream
    or the pressure plan never preempted."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api

    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    steps = 16 if smoke else 32
    trace = synthesize_trace(seed=seed, steps=steps, vocab=cfg.vocab,
                             max_new=(4, 9))

    def run(telemetry):
        # pressure-only fault plan: deterministic preempt/resume churn,
        # no numeric faults (latency accounting, not quarantine, is under
        # test here)
        injector = FaultInjector(seed=seed + 7, horizon=max(16, steps),
                                 nan_faults=0, inf_faults=0,
                                 transient_failures=0, pressure_windows=2,
                                 pressure_frac=(0.15, 0.25))
        eng = ServingEngine(params, cfg, n_slots=3, max_len=48,
                            min_bucket=8, clock=StepClock(step_ms=10.0),
                            faults=injector, on_pressure="preempt",
                            telemetry=telemetry)
        rep = Replayer(eng, trace).run()
        fin = eng.take_finished()
        return rep, {u: list(r.tokens) for u, r in fin.items()}

    rep_off, toks_off = run(None)
    assert rep_off is None       # no telemetry -> no report, by contract
    report, toks_on = run(Telemetry())
    validate_report(report)
    # the hooks must be observation-only: bit-identical token streams
    assert toks_on == toks_off, (
        "telemetry-on token stream diverged from telemetry-off")
    sched = report["scheduling"]
    assert sched["preemptions"] >= 1 and sched["resumes"] >= 1, (
        f"pressure plan never preempted (preemptions="
        f"{sched['preemptions']}, resumes={sched['resumes']}): replay "
        f"scenario is vacuous, retune pressure_frac")
    assert report["ttft_ms"]["count"] >= 1, "no request reached a first token"
    return {
        "trace": report["trace"],
        "requests": report["requests"],
        "ttft_ms": report["ttft_ms"],
        "tpot_ms": report["tpot_ms"],
        "queue_wait_ms": report["queue_wait_ms"],
        "tokens": report["tokens"],
        "scheduling": sched,
        "driver_steps": report["driver_steps"],
        "telemetry_parity": True,
    }


def paged_capacity_scenario(smoke: bool = False) -> dict:
    """Admission capacity at a FIXED HBM budget: contiguous slots vs a
    paged pool of the same byte size (fp and int8 resident pages), under
    a common system prompt.  Counts requests admitted before typed
    backpressure (AdmissionRejected / PoolExhausted) with no decoding —
    pure cache-capacity accounting, deterministic by construction.

    The contiguous layout pins n_slots * max_len positions no matter how
    short the requests are; the paged layout pins only the pages each
    request touches, prefix sharing collapses the common system prompt to
    ONE physical copy, and int8 pages fit ~4x the tokens per byte.  The
    scenario ASSERTS paged >= 2x contiguous and int8 >= paged fp, so CI
    cannot silently regress the capacity win."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api as mapi

    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = mapi.init_params(jax.random.PRNGKey(0), cfg)
    n_slots, max_len, ps = 4, 64, 8
    max_new = 4
    sys_prompt = list(range(1, 25))          # 24-token shared system prompt

    def admit_until_full(eng, budget):
        count = 0
        for i in range(budget):
            try:
                eng.add_request(sys_prompt + [30 + i % (cfg.vocab - 31)],
                                max_new_tokens=max_new)
            except AdmissionRejected:        # PoolExhausted subclasses it
                break
            count += 1
        return count

    def paged(n_pages, kv_dtype=None, share=True):
        # slots are table rows (tiny) for the paged layout — size the slot
        # count so only the PAGE POOL can be the binding constraint
        return ServingEngine(params, cfg, n_slots=n_pages,
                             max_len=max_len, min_bucket=8, prepare=False,
                             kv_layout="paged", page_size=ps,
                             kv_pages=n_pages, kv_dtype=kv_dtype,
                             share_prefixes=share)

    contig = ServingEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                           min_bucket=8, prepare=False)
    cap_contig = admit_until_full(contig, 4 * n_slots)

    pool_fp = n_slots * (max_len // ps)      # capacity-equivalent fp pool
    probe_fp = paged(pool_fp).stats()["paged"]
    hbm_budget = pool_fp * probe_fp["bytes_per_page"]
    probe_i8 = paged(pool_fp, kv_dtype="int8").stats()["paged"]
    pool_i8 = hbm_budget // probe_i8["bytes_per_page"]

    budget = 4 * pool_i8
    cap_fp_noshare = admit_until_full(paged(pool_fp, share=False), budget)
    eng_fp = paged(pool_fp)
    cap_fp = admit_until_full(eng_fp, budget)
    eng_i8 = paged(pool_i8, kv_dtype="int8")
    cap_i8 = admit_until_full(eng_i8, budget)

    assert cap_fp >= 2 * cap_contig, (
        f"paged fp capacity {cap_fp} < 2x contiguous {cap_contig} at the "
        f"same HBM budget — the paged layout lost its capacity win")
    assert cap_i8 >= cap_fp, (
        f"int8-page capacity {cap_i8} < paged fp {cap_fp} — int8 pages "
        f"stopped paying for themselves")
    st = eng_fp.stats()["paged"]
    return {
        "n_slots_contiguous": n_slots, "max_len": max_len,
        "page_size": ps, "system_prompt_tokens": len(sys_prompt),
        "hbm_budget_bytes": int(hbm_budget),
        "pool_pages": {"fp": pool_fp, "int8": int(pool_i8)},
        "capacity": {"contiguous": cap_contig,
                     "paged_fp_noshare": cap_fp_noshare,
                     "paged_fp": cap_fp, "paged_int8": cap_i8},
        "paged_fp_stats": {k: st[k] for k in
                           ("prefix_hits", "prefix_shared_tokens",
                            "pages_in_use", "pool_utilization")},
    }


def overload_scenario(smoke: bool = False, seed: int = 0) -> dict:
    """Overload control-plane A/B/C (DESIGN.md §14): one seeded burst
    trace through three engines under the SAME deterministic step-cost
    model — no controller, admission-only ([nominal, shed]), and the
    full degradation ladder — recording p99 TTFT/TPOT, goodput
    (finished tokens per virtual second) and shed rate for each.

    ASSERTS the control claim instead of just charting it: the
    uncontrolled baseline must VIOLATE the p99 TTFT target, the full
    ladder must MEET it, and the full ladder must do so at
    equal-or-better goodput than admission-only shedding (degrading
    before abandoning is the whole point of the ladder)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.serve import AdmissionController, SLOConfig, StepCostModel
    from repro.serve.replay import overload_trace

    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    target = 250.0
    # ONE pinned storm for smoke and full mode: the trace is a seeded
    # artifact the assertions are tuned against, not a scale knob
    steps = 32
    trace = overload_trace(seed=seed, steps=steps, vocab=cfg.vocab)
    cost = StepCostModel()

    def arm(controller=None, chunk=None):
        eng = ServingEngine(params, cfg, n_slots=3, max_len=64,
                            min_bucket=8, clock=StepClock(step_ms=10.0),
                            telemetry=Telemetry(), queue_depth=48,
                            chunked_prefill=chunk, controller=controller,
                            cost_model=cost)
        t0 = eng.clock()
        report = Replayer(eng, trace, retry=RetryPolicy(backoff_s=0.0)).run()
        validate_report(report)
        elapsed_s = eng.clock() - t0
        fin = eng.take_finished()
        good = sum(len(r.tokens) for r in fin.values()
                   if r.state.value == "finished")
        out = {
            "ttft_p99_ms": report["ttft_ms"]["p99"],
            "ttft_count": report["ttft_ms"]["count"],
            "tpot_p99_ms": report["tpot_ms"]["p99"],
            "goodput_tok_per_s": good / max(elapsed_s, 1e-9),
            "finished_tokens": good,
            "elapsed_virtual_s": elapsed_s,
            "submitted": len(fin),
            "sheds": controller.sheds if controller else 0,
            "shed_rate": (controller.sheds / max(len(fin), 1)
                          if controller else 0.0),
        }
        if controller is not None:
            out["controller"] = controller.stats()
        return out

    slo = SLOConfig(ttft_p99_ms=target)
    base = arm()
    adm = arm(AdmissionController(slo, mode="admission"), chunk=8)
    full = arm(AdmissionController(slo, mode="full"), chunk=8)

    assert base["ttft_p99_ms"] > target, (
        f"baseline p99 TTFT {base['ttft_p99_ms']:.1f}ms already meets "
        f"the {target:.0f}ms target: the overload storm is not a storm")
    assert full["ttft_p99_ms"] <= target, (
        f"full-ladder p99 TTFT {full['ttft_p99_ms']:.1f}ms misses the "
        f"{target:.0f}ms target the controller exists to defend")
    assert full["goodput_tok_per_s"] >= adm["goodput_tok_per_s"], (
        f"full ladder goodput {full['goodput_tok_per_s']:.1f} tok/s < "
        f"admission-only {adm['goodput_tok_per_s']:.1f} tok/s — "
        f"degrading before shedding stopped paying for itself")
    assert full["controller"]["rung_changes"] > 0 and (
        full["sheds"] > 0 or full["controller"]["defers"] > 0), (
        "vacuous full-ladder run: the controller never acted")
    return {
        "ttft_p99_ms_target": target,
        "trace": {"arrivals": len(trace), "seed": seed, "steps": steps},
        "baseline": base,
        "admission_only": adm,
        "full_ladder": full,
        "slo_defended": True,
    }


def serve_bench(out_json: str = _BENCH_JSON, smoke: bool = False,
                faults_only: bool = False):
    if faults_only:
        # CI fault-injection smoke: lifecycle contract only, no trained
        # model, no timing rows
        rob = robustness_scenario(smoke=smoke)
        results = {"smoke": smoke, "faults_only": True, "robustness": rob}
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"serve/robustness,{rob['driver_steps']},"
              f"submitted={rob['submitted']};finished={rob['finished']};"
              f"preemptions={rob['preemptions']};resumes={rob['resumes']};"
              f"lifecycle={json.dumps(rob['lifecycle'])}")
        return []
    from benchmarks.common import trained_model

    cfg, params, hessians = trained_model()
    qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4,
                      gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                      orr=ORConfig(0.1))
    t0 = time.perf_counter()
    qparams, rep = quantize_model_params(params, cfg, hessians, qcfg)
    dparams, drep = quantize_model_params(params, cfg, hessians,
                                          draft_config(qcfg, 2))
    t_quant = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    n_req = 4 if smoke else 8
    max_new = 12 if smoke else 24
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(3, 14)).tolist()
               for _ in range(n_req)]

    def make(spec=None, **kw):
        return ServingEngine(
            qparams, cfg, n_slots=n_req, max_len=64, min_bucket=8,
            draft_params=dparams if spec else None, spec=spec, **kw)

    rows = []
    results = {
        "model": {"arch": "llama1_7b-smoke-trained",
                  "n_layers": cfg.n_layers, "vocab": cfg.vocab,
                  "d_model": cfg.d_model},
        "target_bits": rep.mean_effective_bits,
        "draft_bits": drep.mean_effective_bits,
        "quantize_pair_s": t_quant,
        "requests": n_req,
        "max_new": max_new,
        "smoke": smoke,
    }

    base_tokens, steps, secs = _run(make(), prompts, max_new)
    total = sum(len(t) for t in base_tokens)
    results["vanilla"] = {
        "tokens": total, "steps": steps,
        "tokens_per_step": total / steps,
        "ms_per_step": secs / steps * 1e3,
    }
    rows.append(("serve/decode_vanilla", secs / steps * 1e6,
                 f"steps={steps};tokens_per_step={total / steps:.2f}"))

    for gamma in GAMMAS:
        eng = make(SpecConfig(gamma=gamma, draft_bits=2))
        toks, steps, secs = _run(eng, prompts, max_new)
        # greedy speculation is LOSSLESS — a divergence means the bench is
        # measuring a bug, so fail loudly instead of recording it
        assert toks == base_tokens, (
            f"speculative gamma={gamma} diverged from vanilla greedy")
        st = eng.stats()
        total = sum(len(t) for t in toks)
        results[f"spec_gamma{gamma}"] = {
            "tokens": total, "steps": steps,
            "tokens_per_step": total / steps,
            "ms_per_step": secs / steps * 1e3,
            "acceptance_rate": st["acceptance_rate"],
            "verify_traces": st["verify_traces"],
            "draft_decode_traces": st["draft_decode_traces"],
        }
        rows.append((f"serve/decode_spec_gamma{gamma}", secs / steps * 1e6,
                     f"steps={steps};"
                     f"tokens_per_step={total / steps:.2f};"
                     f"acceptance={st['acceptance_rate']:.2f}"))

    # draft-specific plan tiles (ROADMAP spec item b): the 2-bit draft's
    # groups are skinnier than the target's, so its plans get their own
    # bn cap — losslessness is tile-independent, so parity still ASSERTS,
    # and the recorded delta is pure plan-tile effect on the draft chain
    gamma = GAMMAS[0]
    eng = make(SpecConfig(gamma=gamma, draft_bits=2), draft_plan_bn=32)
    toks, steps, secs = _run(eng, prompts, max_new)
    assert toks == base_tokens, (
        f"draft_plan_bn=32 gamma={gamma} diverged from vanilla greedy")
    st = eng.stats()
    total = sum(len(t) for t in toks)
    results[f"spec_gamma{gamma}_draft_bn32"] = {
        "tokens": total, "steps": steps,
        "tokens_per_step": total / steps,
        "ms_per_step": secs / steps * 1e3,
        "acceptance_rate": st["acceptance_rate"],
    }
    rows.append((f"serve/decode_spec_gamma{gamma}_draft_bn32",
                 secs / steps * 1e6,
                 f"steps={steps};tokens_per_step={total / steps:.2f};"
                 f"acceptance={st['acceptance_rate']:.2f}"))

    cap = paged_capacity_scenario(smoke=smoke)
    results["paged_capacity"] = cap
    rows.append(("serve/paged_capacity", float(cap["capacity"]["paged_fp"]),
                 f"contiguous={cap['capacity']['contiguous']};"
                 f"paged_fp={cap['capacity']['paged_fp']};"
                 f"paged_int8={cap['capacity']['paged_int8']};"
                 f"hbm_bytes={cap['hbm_budget_bytes']}"))

    rob = robustness_scenario(smoke=smoke)
    results["robustness"] = rob
    rows.append(("serve/robustness", float(rob["driver_steps"]),
                 f"submitted={rob['submitted']};"
                 f"finished={rob['finished']};"
                 f"preemptions={rob['preemptions']};"
                 f"resumes={rob['resumes']};"
                 f"abandoned={rob['lifecycle']['abandoned']};"
                 f"failed={rob['lifecycle']['failed']}"))

    ov = overload_scenario(smoke=smoke)
    results["overload"] = ov
    rows.append(("serve/overload", ov["full_ladder"]["ttft_p99_ms"],
                 f"target={ov['ttft_p99_ms_target']:.0f};"
                 f"base_p99={ov['baseline']['ttft_p99_ms']:.1f};"
                 f"adm_p99={ov['admission_only']['ttft_p99_ms']:.1f};"
                 f"full_p99={ov['full_ladder']['ttft_p99_ms']:.1f};"
                 f"full_goodput={ov['full_ladder']['goodput_tok_per_s']:.1f};"
                 f"adm_goodput="
                 f"{ov['admission_only']['goodput_tok_per_s']:.1f};"
                 f"full_shed_rate={ov['full_ladder']['shed_rate']:.2f}"))

    rp = replay_scenario(smoke=smoke)
    results["replay"] = rp
    rows.append(("serve/replay", rp["ttft_ms"]["p50"],
                 f"ttft_p50={rp['ttft_ms']['p50']:.2f};"
                 f"ttft_p99={rp['ttft_ms']['p99']:.2f};"
                 f"tpot_p50={rp['tpot_ms']['p50']:.2f};"
                 f"tok_s_slot={rp['tokens']['per_s_per_slot']:.2f};"
                 f"preemptions={rp['scheduling']['preemptions']}"))

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request count / budgets (CI mode)")
    ap.add_argument("--inject-faults", action="store_true",
                    help="run ONLY the seeded fault-plan robustness "
                         "scenario (CI fault-injection smoke: asserts "
                         "zero hangs and every request terminal)")
    ap.add_argument("--out", default=_BENCH_JSON)
    args = ap.parse_args()
    serve_bench(out_json=args.out, smoke=args.smoke,
                faults_only=args.inject_faults)


if __name__ == "__main__":
    main()
