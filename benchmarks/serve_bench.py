"""Serving-loop benchmark: vanilla vs self-speculative decode.

Wall times on this CPU container are NOT TPU estimates; the structural,
deterministic quantities are the deliverable: decode STEPS to drain a
request wave and accepted tokens per step (the decode-cadence multiplier
speculation buys), draft acceptance rate (how well a 2-bit CLAQ draft
tracks its higher-bit target when both come from ONE calibration pass),
and the compile counts proving the speculative path adds a constant
number of traces.  Greedy speculation is lossless, so the bench also
ASSERTS token parity between the vanilla and speculative engines — a
benchmark that cannot silently measure a broken configuration.

The substrate is benchmarks.common.trained_model(): a model trained until
it clearly beats unigram, so its logits are PEAKED — on a random-init
model any quantization noise flips the near-uniform argmax and acceptance
collapses to ~0, which measures nothing.  Target and draft are quantized
from the model's one set of tapped Hessians (the
`claq_quantize_with_draft` contract with calibration amortized).

`serve_bench()` writes BENCH_serve.json at the repo root (the serving
trajectory's counterpart to BENCH_kernel.json); CI runs `--smoke`.

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import APConfig, CLAQConfig, ORConfig, draft_config
from repro.launch.quantize import quantize_model_params
from repro.serve import ServingEngine, SpecConfig

_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")

GAMMAS = (2, 4)


def _run(eng, prompts, max_new):
    """Admit everything, decode to completion; returns (tokens in prompt
    order, steps, decode seconds)."""
    uids = eng.add_requests(prompts, max_new_tokens=max_new)
    steps = 0
    t_decode = 0.0
    while eng.active:
        t0 = time.perf_counter()
        eng.step()
        t_decode += time.perf_counter() - t0
        steps += 1
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids], steps, t_decode


def serve_bench(out_json: str = _BENCH_JSON, smoke: bool = False):
    from benchmarks.common import trained_model

    cfg, params, hessians = trained_model()
    qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4,
                      gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                      orr=ORConfig(0.1))
    t0 = time.perf_counter()
    qparams, rep = quantize_model_params(params, cfg, hessians, qcfg)
    dparams, drep = quantize_model_params(params, cfg, hessians,
                                          draft_config(qcfg, 2))
    t_quant = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    n_req = 4 if smoke else 8
    max_new = 12 if smoke else 24
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(3, 14)).tolist()
               for _ in range(n_req)]

    def make(spec=None, **kw):
        return ServingEngine(
            qparams, cfg, n_slots=n_req, max_len=64, min_bucket=8,
            draft_params=dparams if spec else None, spec=spec, **kw)

    rows = []
    results = {
        "model": {"arch": "llama1_7b-smoke-trained",
                  "n_layers": cfg.n_layers, "vocab": cfg.vocab,
                  "d_model": cfg.d_model},
        "target_bits": rep.mean_effective_bits,
        "draft_bits": drep.mean_effective_bits,
        "quantize_pair_s": t_quant,
        "requests": n_req,
        "max_new": max_new,
        "smoke": smoke,
    }

    base_tokens, steps, secs = _run(make(), prompts, max_new)
    total = sum(len(t) for t in base_tokens)
    results["vanilla"] = {
        "tokens": total, "steps": steps,
        "tokens_per_step": total / steps,
        "ms_per_step": secs / steps * 1e3,
    }
    rows.append(("serve/decode_vanilla", secs / steps * 1e6,
                 f"steps={steps};tokens_per_step={total / steps:.2f}"))

    for gamma in GAMMAS:
        eng = make(SpecConfig(gamma=gamma, draft_bits=2))
        toks, steps, secs = _run(eng, prompts, max_new)
        # greedy speculation is LOSSLESS — a divergence means the bench is
        # measuring a bug, so fail loudly instead of recording it
        assert toks == base_tokens, (
            f"speculative gamma={gamma} diverged from vanilla greedy")
        st = eng.stats()
        total = sum(len(t) for t in toks)
        results[f"spec_gamma{gamma}"] = {
            "tokens": total, "steps": steps,
            "tokens_per_step": total / steps,
            "ms_per_step": secs / steps * 1e3,
            "acceptance_rate": st["acceptance_rate"],
            "verify_traces": st["verify_traces"],
            "draft_decode_traces": st["draft_decode_traces"],
        }
        rows.append((f"serve/decode_spec_gamma{gamma}", secs / steps * 1e6,
                     f"steps={steps};"
                     f"tokens_per_step={total / steps:.2f};"
                     f"acceptance={st['acceptance_rate']:.2f}"))

    # draft-specific plan tiles (ROADMAP spec item b): the 2-bit draft's
    # groups are skinnier than the target's, so its plans get their own
    # bn cap — losslessness is tile-independent, so parity still ASSERTS,
    # and the recorded delta is pure plan-tile effect on the draft chain
    gamma = GAMMAS[0]
    eng = make(SpecConfig(gamma=gamma, draft_bits=2), draft_plan_bn=32)
    toks, steps, secs = _run(eng, prompts, max_new)
    assert toks == base_tokens, (
        f"draft_plan_bn=32 gamma={gamma} diverged from vanilla greedy")
    st = eng.stats()
    total = sum(len(t) for t in toks)
    results[f"spec_gamma{gamma}_draft_bn32"] = {
        "tokens": total, "steps": steps,
        "tokens_per_step": total / steps,
        "ms_per_step": secs / steps * 1e3,
        "acceptance_rate": st["acceptance_rate"],
    }
    rows.append((f"serve/decode_spec_gamma{gamma}_draft_bn32",
                 secs / steps * 1e6,
                 f"steps={steps};tokens_per_step={total / steps:.2f};"
                 f"acceptance={st['acceptance_rate']:.2f}"))

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request count / budgets (CI mode)")
    ap.add_argument("--out", default=_BENCH_JSON)
    args = ap.parse_args()
    serve_bench(out_json=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
