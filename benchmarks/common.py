"""Shared benchmark substrate: one small LM trained on the synthetic corpus,
calibrated once; every table quantizes it with a different recipe and
reports perplexity / zero-shot-proxy accuracy.

Absolute LLaMA numbers are not reproducible without the weights (data gate,
see DESIGN.md §6) — the deliverable is the paper's orderings and deltas.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import DataConfig, SyntheticCorpus, calibration_set
from repro.launch.quantize import calibrate, quantize_model_params
from repro.models import api
from repro.optim import OptimConfig, init_opt_state
from repro.train import make_train_step

VOCAB = 512
SEQ = 64


@functools.lru_cache(maxsize=1)
def trained_model():
    """Train a ~1M-param llama-family model until it clearly beats unigram,
    then calibrate (paper protocol: random segments from the corpus)."""
    cfg = dataclasses.replace(
        get_smoke_config("llama1_7b"), vocab=VOCAB, n_layers=4,
        d_model=160, n_heads=4, n_kv_heads=4, head_dim=40, d_ff=448)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimConfig(lr=6e-3, warmup_steps=10, total_steps=220)
    opt = init_opt_state(params, ocfg)
    data = SyntheticCorpus(DataConfig(vocab=VOCAB, seq_len=SEQ, batch=16,
                                      seed=0, name="c4like"))
    step = jax.jit(make_train_step(cfg, ocfg))
    for s in range(200):
        params, opt, m = step(params, opt, {"tokens": data.batch_at(s)})
    calib = calibration_set(vocab=VOCAB, n_segments=16, seq_len=SEQ,
                            name="c4like")
    hessians = calibrate(params, cfg, calib, batch_size=4)
    return cfg, params, hessians


@functools.lru_cache(maxsize=4)
def eval_batches(name: str = "c4like", n: int = 4):
    data = SyntheticCorpus(DataConfig(vocab=VOCAB, seq_len=SEQ, batch=16,
                                      seed=123, name=name))
    return tuple(data.batch_at(10_000 + i) for i in range(n))


def perplexity(cfg, params, name: str = "c4like") -> float:
    fn = jax.jit(lambda p, b: api.loss_fn(p, cfg, b)[1]["nll"])
    nlls = [float(fn(params, {"tokens": b})) for b in eval_batches(name)]
    return float(np.exp(np.mean(nlls)))


def quantized(qcfg: CLAQConfig, hessians=None):
    cfg, params, hess = trained_model()
    t0 = time.time()
    qp, report = quantize_model_params(params, cfg,
                                       hessians if hessians is not None else hess,
                                       qcfg)
    return cfg, qp, report, (time.time() - t0) * 1e6


def zero_shot_proxy_accuracy(cfg, params, n_items: int = 128) -> float:
    """Cloze-ranking suite standing in for the zero-shot tasks: given a
    context from the eval distribution, the model must rank the true next
    token above 3 distractors by log-probability."""
    batches = eval_batches("c4like", 2)
    toks = jnp.concatenate(batches)[:, : SEQ // 2]
    fn = jax.jit(lambda p, t: api.loss_fn(p, cfg, {"tokens": t})[1]["nll"])
    # score each item: true continuation vs distractor continuations
    from repro.models import transformer as tf
    logits_fn = jax.jit(lambda p, t: tf.forward(p, cfg, t)[0])
    logits = logits_fn(params, toks)            # (B, S, V)
    rng = np.random.default_rng(7)
    correct = 0
    total = 0
    lg = np.asarray(logits, np.float32)
    tk = np.asarray(toks)
    for b in range(min(len(tk), n_items // 4)):
        for pos in range(8, SEQ // 2 - 1, 8):
            true_tok = tk[b, pos + 1]
            distractors = rng.integers(0, VOCAB, size=3)
            scores = lg[b, pos, [true_tok, *distractors]]
            correct += int(np.argmax(scores) == 0)
            total += 1
    return correct / max(total, 1)


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


# ---- standard recipes -------------------------------------------------------

def recipe(tag: str) -> CLAQConfig:
    base = dict(kmeans_iters=6, gptq_blocksize=32)
    table = {
        # rtn* = same grids quantized with identity Hessians (no calibration)
        "rtn4": CLAQConfig(bits=4, method="uniform", gptq_blocksize=32),
        "rtn3": CLAQConfig(bits=3, method="uniform", gptq_blocksize=32),
        "gptq4": CLAQConfig(bits=4, method="uniform", gptq_blocksize=32),
        "claq4": CLAQConfig(bits=4, method="kmeans", **base),
        "gptq3": CLAQConfig(bits=3, method="uniform", gptq_blocksize=32),
        "claq3": CLAQConfig(bits=3, method="kmeans", **base),
        "gptq2": CLAQConfig(bits=2, method="uniform", gptq_blocksize=32),
        "claq2": CLAQConfig(bits=2, method="kmeans", **base),
        "claq2.12": CLAQConfig(bits=2, method="kmeans",
                               ap=APConfig(2.05, 2, 4), orr=ORConfig(0.07),
                               **base),
        "claq2.24": CLAQConfig(bits=2, method="kmeans",
                               ap=APConfig(2.1, 2, 4), orr=ORConfig(0.13),
                               **base),
        "claq3.12": CLAQConfig(bits=3, method="kmeans",
                               ap=APConfig(3.05, 3, 4), orr=ORConfig(0.07),
                               **base),
    }
    return table[tag]
