"""Benchmark harness: one function per paper table (+ kernel/roofline).

  PYTHONPATH=src python -m benchmarks.run [--only table1,kernel]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list, e.g. table1,kernel")
    args, _ = ap.parse_known_args()

    from . import tables
    from .kernel_bench import kernel_bench, roofline_rows
    from .serve_bench import serve_bench

    suite = {
        "table1": tables.table1_ppl,
        "table2": tables.table2_zeroshot,
        "table3": tables.table3_ap,
        "table4": tables.table4_or,
        "table5": tables.table5_outlier_standard,
        "table6": tables.table6_or_split,
        "table7": tables.table7_bit_pairs,
        "table12": tables.table12_heuristic_search,
        "table13": tables.table13_calibration,
        "kernel": kernel_bench,
        "roofline": roofline_rows,
        "serve": serve_bench,
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
