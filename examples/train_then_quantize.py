"""Train-then-PTQ with fault tolerance: train a small LM for a few hundred
steps with async checkpointing, simulate a preemption + resume, then
quantize at several bit-widths and report the perplexity curve.

  PYTHONPATH=src python examples/train_then_quantize.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import DataConfig, SyntheticCorpus, calibration_set
from repro.launch.quantize import calibrate, quantize_model_params
from repro.models import api
from repro.optim import OptimConfig, init_opt_state
from repro.train import make_train_step

VOCAB, SEQ = 512, 64
cfg = dataclasses.replace(get_smoke_config("qwen2_1p5b"), vocab=VOCAB,
                          n_layers=3, d_model=128, d_ff=352)
ocfg = OptimConfig(lr=6e-3, warmup_steps=10, total_steps=240)
data = SyntheticCorpus(DataConfig(vocab=VOCAB, seq_len=SEQ, batch=16, seed=0))
step = jax.jit(make_train_step(cfg, ocfg, n_microbatches=2))

params = api.init_params(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params, ocfg)

with tempfile.TemporaryDirectory() as ckpt_dir:
    mgr = CheckpointManager(ckpt_dir, keep=2)
    print("training (async checkpoints every 40 steps) ...")
    for s in range(120):
        params, opt, m = step(params, opt, {"tokens": data.batch_at(s)})
        if (s + 1) % 40 == 0:
            mgr.save(s + 1, {"params": params, "opt": opt}, blocking=False)
            print(f"  step {s + 1:4d} loss {float(m['loss']):.3f} (ckpt queued)")
    mgr.wait()

    print("simulating preemption ... restoring newest valid checkpoint")
    latest = mgr.latest_step()
    st = mgr.restore(latest, {"params": params, "opt": opt})
    params, opt = st["params"], st["opt"]
    for s in range(latest, 200):   # resume exactly where the data cursor was
        params, opt, m = step(params, opt, {"tokens": data.batch_at(s)})
    print(f"  resumed from {latest}, final loss {float(m['loss']):.3f}")

# ---- PTQ sweep ---------------------------------------------------------------
calib = calibration_set(vocab=VOCAB, n_segments=16, seq_len=SEQ)
hess = calibrate(params, cfg, calib, batch_size=4)
eval_batch = {"tokens": data.batch_at(9999)}


def ppl(p):
    _, met = jax.jit(lambda pp, b: api.loss_fn(pp, cfg, b))(p, eval_batch)
    return float(jnp.exp(met["nll"]))


print(f"\n{'recipe':28s} {'bits':>6s} {'ppl':>9s}")
print(f"{'fp32':28s} {'32':>6s} {ppl(params):9.3f}")
for name, qcfg in [
    ("CLAQ 4-bit", CLAQConfig(bits=4, method="kmeans", kmeans_iters=6,
                              gptq_blocksize=32)),
    ("CLAQ 3-bit", CLAQConfig(bits=3, method="kmeans", kmeans_iters=6,
                              gptq_blocksize=32)),
    ("CLAQ 2-bit", CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                              gptq_blocksize=32)),
    ("CLAQ* 2.24 (AP+OR fusion)",
     CLAQConfig(bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
                ap=APConfig(2.1, 2, 4), orr=ORConfig(0.13))),
]:
    qp, rep = quantize_model_params(params, cfg, hess, qcfg)
    print(f"{name:28s} {rep.mean_effective_bits:6.2f} {ppl(qp):9.3f}")
