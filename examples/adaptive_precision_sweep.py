"""Adaptive-precision landscape: sweep equivalent bit-width from 2.0 to 4.0
with the paper's three strategies on one heavy-tailed matrix:

  * AP only (2&4 column mixes, Outlier-Order-guided)
  * OR only (fp16 outlier reservation at matched extra budget)
  * AP+OR fusion (half budget each)

  PYTHONPATH=src python examples/adaptive_precision_sweep.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import APConfig, CLAQConfig, ORConfig, quantize_matrix

rng = np.random.default_rng(1)
rows, cols = 192, 192
W = rng.normal(size=(rows, cols)).astype(np.float32)
mask = rng.random(W.shape) < 0.01           # element-scattered outliers
W[mask] += np.sign(W[mask]) * rng.uniform(6, 15, size=mask.sum())
W[:, :12] *= 3.0                            # plus a few hot columns
X = rng.normal(size=(768, cols)).astype(np.float32)
H = jnp.asarray(2 * X.T @ X)
W = jnp.asarray(W)

print(f"{'target':>7s} {'AP only':>12s} {'OR only':>12s} {'AP+OR':>12s}")
for target in (2.0, 2.1, 2.2, 2.5, 3.0, 3.5):
    extra = target - 2.0
    base = dict(bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32)
    ap = quantize_matrix(W, H, CLAQConfig(
        **base, ap=APConfig(target, 2, 4) if extra else None))[2]
    orr = quantize_matrix(W, H, CLAQConfig(
        **base, orr=ORConfig(extra) if extra else None))[2]
    fusion = quantize_matrix(W, H, CLAQConfig(
        **base,
        ap=APConfig(2.0 + extra / 2, 2, 4) if extra else None,
        orr=ORConfig(extra / 2) if extra else None))[2]
    print(f"{target:7.2f} {ap.proxy_loss:12.1f} {orr.proxy_loss:12.1f} "
          f"{fusion.proxy_loss:12.1f}")

print("\n(expected shape per the paper: OR > AP at matched budget on "
      "scattered outliers; fusion best overall in the low-bit regime)")
