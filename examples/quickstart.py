"""CLAQ quickstart: quantize a weight matrix with each strategy and watch
the calibration-objective error.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (APConfig, CLAQConfig, ORConfig, proxy_loss,
                        quantize_matrix, rtn_quantize_matrix)

# A weight matrix with heavy-tailed columns (the regime the paper targets)
rng = np.random.default_rng(0)
rows, cols = 256, 256
W = rng.normal(size=(rows, cols)).astype(np.float32)
W[:, :16] += rng.standard_t(df=2, size=(rows, 16)) * 5.0

# Calibration second moments (stand-in for activations through this layer)
X = rng.normal(size=(1024, cols)).astype(np.float32)
X[:, ::5] *= 2.5
H = jnp.asarray(2 * X.T @ X)
W = jnp.asarray(W)

print(f"{'method':34s} {'bits':>6s} {'proxy loss':>12s}")
Q_rtn, _, _ = rtn_quantize_matrix(W, 2, "uniform")
print(f"{'RTN uniform (no compensation)':34s} {2.0:6.2f} "
      f"{float(proxy_loss(W, Q_rtn, H)):12.1f}")

for name, cfg in [
    ("GPTQ uniform", CLAQConfig(bits=2, method="uniform")),
    ("CLAQ K-Means (paper §3.1)", CLAQConfig(bits=2, method="kmeans")),
    ("CLAQ + AP 2.2 (paper §3.3)",
     CLAQConfig(bits=2, method="kmeans", ap=APConfig(2.2, 2, 4))),
    ("CLAQ + OR 2.2 (paper §3.4)",
     CLAQConfig(bits=2, method="kmeans", orr=ORConfig(0.2))),
    ("CLAQ AP+OR fusion (paper SOTA)",
     CLAQConfig(bits=2, method="kmeans", ap=APConfig(2.1, 2, 4),
                orr=ORConfig(0.1))),
]:
    qt, Q, st = quantize_matrix(W, H, cfg)
    print(f"{name:34s} {st.effective_bits:6.2f} {st.proxy_loss:12.1f}")

print("\nDeployment format of the fusion model:")
qt, _, st = quantize_matrix(W, H, CLAQConfig(
    bits=2, method="kmeans", ap=APConfig(2.1, 2, 4), orr=ORConfig(0.1)))
for s in qt.stripes:
    print(f"  stripe: {s.bits}-bit x {s.n_cols} columns, "
          f"packed {s.packed.shape} uint32 words")
print(f"  reserved outliers: {int(qt.out_count.sum())} fp values "
      f"(structured (k, cols) planes, no CSR)")
print(f"  effective bits/element: {st.effective_bits:.3f} "
      f"(+codebooks: {st.effective_bits_with_codebooks:.3f})")
