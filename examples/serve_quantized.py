"""End-to-end driver: train a small LM on the synthetic corpus, CLAQ-
quantize it to ~2.2 bits (AP+OR fusion), and serve batched requests
through the continuous-batching engine — the paper's deployment story.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import DataConfig, SyntheticCorpus, calibration_set
from repro.launch.quantize import claq_quantize
from repro.models import api
from repro.optim import OptimConfig, init_opt_state
from repro.serve import ServingEngine
from repro.train import make_train_step

VOCAB, SEQ = 512, 64

# ---- 1. train ---------------------------------------------------------------
cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=VOCAB,
                          n_layers=4, d_model=160, n_heads=4, n_kv_heads=4,
                          head_dim=40, d_ff=448)
params = api.init_params(jax.random.PRNGKey(0), cfg)
ocfg = OptimConfig(lr=6e-3, warmup_steps=10, total_steps=200)
opt = init_opt_state(params, ocfg)
data = SyntheticCorpus(DataConfig(vocab=VOCAB, seq_len=SEQ, batch=16, seed=0))
step = jax.jit(make_train_step(cfg, ocfg))
print("training a small LM on the synthetic corpus ...")
for s in range(150):
    params, opt, m = step(params, opt, {"tokens": data.batch_at(s)})
    if s % 50 == 0:
        print(f"  step {s:4d} loss {float(m['loss']):.3f}")
print(f"  final loss {float(m['loss']):.3f}")

# ---- 2. CLAQ PTQ ------------------------------------------------------------
qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
                  ap=APConfig(2.1, 2, 4), orr=ORConfig(0.13))
calib = calibration_set(vocab=VOCAB, n_segments=16, seq_len=SEQ)
t0 = time.time()
qparams, report = claq_quantize(params, cfg, calib, qcfg)
print(f"\nCLAQ AP+OR fusion: {report.mean_effective_bits:.2f} bits/weight, "
      f"{len(report.stats)} matrices, {time.time() - t0:.1f}s")

# ---- 3. serve ---------------------------------------------------------------
served = {}
for tag, p in (("fp32", params), ("claq-2.2bit", qparams)):
    eng = ServingEngine(p, cfg, n_slots=4, max_len=128)
    prompts = [[1 + i, 2 + i, 3 + i, 4 + i] for i in range(8)]
    order = []
    t0 = time.time()
    while prompts or eng.active:
        if prompts and eng.free:
            batch = [prompts.pop(0)
                     for _ in range(min(len(prompts), len(eng.free)))]
            order += eng.add_requests(batch, max_new_tokens=12)
        eng.step()
    dt = time.time() - t0
    finished = eng.take_finished()
    served[tag] = [finished[uid].tokens for uid in order]
    st = eng.stats()
    print(f"[{tag:12s}] served 8 requests x 12 tokens in {dt:.2f}s "
          f"({st['prefill_traces']} prefill traces, bucket hit rate "
          f"{st['bucket_hit_rate']:.0%}); sample: {served[tag][0][:8]}")

agree = sum(a[i] == b[i]
            for a, b in zip(served["fp32"], served["claq-2.2bit"])
            for i in range(8)) / (8 * 8)
print(f"\nquantized model serves through the identical engine "
      f"(QuantizedTensor leaves dispatch inside dense()); "
      f"fp32 vs 2.2-bit greedy-token agreement: {agree:.0%}.")
