"""Chunked prefill: admission-time prompt processing split into fixed-size
token chunks interleaved with decode steps (DESIGN.md §14).

A monolithic bucketed prefill stalls every in-flight decode stream for the
full prompt length; under a latency SLO that stall IS the tail.  Chunking
bounds the per-step prefill work: an admitted group's prompts advance
``chunk_tokens`` positions per processed chunk, and the engine interleaves
chunks with decode steps under a per-step token budget (fixed here, or set
dynamically by ``serve.admission.AdmissionController``).

Requests being chunk-prefilled occupy a first-class lifecycle state,
``PREFILLING``: their slot is reserved (popped from the free list) and
their cache fragment fills chunk by chunk, but NOTHING is written into the
batched slot cache until the final chunk — completion runs the same masked
group-insert (or paged scatter) as monolithic admission.  That makes
mid-``PREFILLING`` preemption trivial: drop the fragment, free the slot,
re-queue — no cache rollback, because the slot row was never written.

Bitwise parity with monolithic prefill (pinned in
tests/test_chunked_prefill.py the way bucketed==unbucketed was in PR 2):
the model layers' uniform-fill prefill branch (layers.py ``gqa_attention``
/ mla.py ``mla_attention``) is ALREADY chunk-shaped — monolithic prefill
is the single-chunk case.  Each chunk appends K/V at ``cache.length`` via
``dynamic_update_slice`` and attends with ``q_offset=start`` /
``kv_len=start+C``; for a query at global position i the effective mask
(causal ∧ fill) is ``kv_pos <= i`` in both the chunked and the monolithic
call, fully-masked kv blocks are exact no-ops in the online-softmax scan
(p is zeroed where masked, and 0.0 * finite == 0.0 bit-exactly), and rows
are batch-independent — so the K/V written for every valid position and
the logits read at each row's true last token are bit-identical.  Chunk
garbage past a row's true length n (zero-padding tokens) writes K/V only
at positions >= n, which are causally invisible to the row's logits at
n-1 and zeroed by the completion masked insert.

Compile budget: every chunk call has the fixed operand shape
``(batch_bucket, chunk_tokens)`` — the chunk position arrives as a traced
scalar — so chunking mints at most one trace per batch bucket
(``floor(log2(n_slots)) + 1`` total), counted against its own TRC-CC1
budget (analysis/artifacts.py ``compile_budgets``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class ChunkedPrefillConfig:
    """Engine-level chunked-prefill knobs.

    ``chunk_tokens`` is the fixed chunk length C (the jit's token-axis
    shape).  ``budget_tokens`` caps the PADDED prefill tokens
    (batch_bucket * C per chunk) processed per engine step; ``None``
    drains every pending chunk each step (chunking then only changes
    the work's shape, not its schedule — the parity-test default).  A
    wired ``AdmissionController`` overrides the budget dynamically.
    Regardless of budget, at least one chunk runs per step whenever any
    group is pending — forward progress is unconditional, so a tiny
    budget can throttle prefill but never livelock it."""

    chunk_tokens: int = 64
    budget_tokens: Optional[int] = None

    def __post_init__(self):
        if self.chunk_tokens < 1:
            raise ValueError(
                f"chunk_tokens must be >= 1, got {self.chunk_tokens}")
        if self.budget_tokens is not None and self.budget_tokens < 1:
            raise ValueError(
                f"budget_tokens must be >= 1 or None, got "
                f"{self.budget_tokens}")


@dataclasses.dataclass
class PrefillGroup:
    """One batch of requests mid-chunked-prefill.

    Rows of the fragment cache align with ``reqs``; ``bb`` is the batch
    bucket (the fragment/jit batch dim — tail rows past ``len(reqs)``
    are bucketing dummies).  ``progress`` counts tokens prefilled so far,
    uniform across rows (the model's uniform-fill branch requires it).
    Members cancelled mid-flight (deadline, pressure preemption) go into
    ``cancelled``; their rows keep being computed — a chunk's rows are
    batch-independent, so dead-row garbage can't leak — but completion
    skips them."""

    reqs: List[Any]                      # engine.Request, row-aligned
    slots: List[int]                     # reserved slot per row
    lens: List[int]                      # true prompt length per row
    bb: int                              # fragment batch bucket
    frag: Any                            # target fragment cache
    draft_frag: Any = None               # draft fragment cache (spec)
    plans: Dict[int, Any] = dataclasses.field(default_factory=dict)
    progress: int = 0
    t0: float = 0.0                      # admit-start time (telemetry)
    cancelled: set = dataclasses.field(default_factory=set)
    # row -> first-token argmax / non-finite count, stashed by the chunk
    # containing the row's TRUE last prompt token, consumed at completion
    firsts: Dict[int, int] = dataclasses.field(default_factory=dict)
    nf: Dict[int, int] = dataclasses.field(default_factory=dict)

    def live(self) -> List[Any]:
        return [r for r in self.reqs if r.uid not in self.cancelled]

    def live_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.reqs)
                if r.uid not in self.cancelled]

    def cancel(self, uid: int) -> None:
        self.cancelled.add(uid)

    @property
    def target_len(self) -> int:
        """Tokens the group must prefill: the longest LIVE prompt (a
        cancelled long row no longer forces extra chunks)."""
        return max((self.lens[i] for i in self.live_rows()), default=0)

    @property
    def done(self) -> bool:
        return self.progress >= self.target_len

    def chunks_remaining(self, chunk_tokens: int) -> int:
        rem = self.target_len - self.progress
        return max(0, -(-rem // chunk_tokens))
