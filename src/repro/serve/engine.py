"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch, with bucketed prefill admission into free slots.

Admission pads each prompt to its power-of-2 length bucket
(serve/bucketing.py), so N distinct prompt lengths cost at most
``ceil(log2(max_len / min_bucket)) + 1`` prefill traces instead of N at
any fixed admission batch size; batch sizes are bucketed the same way
(next power of 2, capped at n_slots), so the total trace count is
bounded by the product length-buckets x batch-buckets
(<= ``floor(log2(n_slots)) + 1`` of the latter).
The prefill reads logits at the true last-token position (``logits_at =
n - 1``, not ``-1``), and the per-request cache fragment enters the
batched cache through a masked insert: K/V positions ``n..bucket-1``
(the padded tail) are zeroed and the fill counter is set to the true
length ``n``, so decode appends at position ``n`` and the attention mask
never exposes a padding slot.  ``add_requests`` admits prompts sharing a
bucket in one batched prefill call (except moe, whose router couples
rows — it admits one per prefill), with the batch size itself bucketed
so a drifting free-slot count doesn't mint fresh compiles either.

Padding applies to the dense attention family, where causal masking
makes a padded suffix invisible to valid positions.  Recurrent families
(rwkv / hybrid) fold every token into their state, and moe's
capacity-bounded router sees padded tokens (see _PADDED_FAMILIES), so
those are admitted at exact lengths (bucket == n, grouping still batches
equal-length prompts).

Weights may be dense or CLAQ-quantized — QuantizedTensor leaves are
compiled into their ahead-of-time inference plans once at init, and the
model dispatches per leaf, so the same engine serves fp and 2/3/4-bit
models.  ``act_dtype="int8"`` additionally opts every quantized matmul
into per-token dynamic int8 activation quantization (weight-activation
quantized serving, DESIGN.md §9) — opt-in because it changes numerics
(bounded by scale/2 * ||W||_1 per output element), unlike every other
engine knob, which is bit-exact.

Multi-device serving: pass ``mesh=`` (e.g. ``jax.make_mesh((2, 4),
("data", "model"))``) and the engine device_puts the prepared params with
``dist.sharding`` rules — PreparedQuantizedTensor units split along N over
"model" with whole (bn, bk) tiles per shard, dense leaves by the generic
TP rule — shards the slot cache over "dp" (plus KV heads over "model"),
and runs the hoisted prefill/decode jits under ``dist.context.use_mesh``
so the layer-level sharding constraints activate.  Decode stays
weight-resident: each shard dequantizes only its own N slice, so the step
moves activations, never weights (asserted on compiled HLO in
tests/test_dist_serving.py via ``lower_decode()``).

Request lifecycle (DESIGN.md §10, serve/lifecycle.py): every request
carries an explicit state machine (QUEUED -> RUNNING -> {FINISHED,
TRUNCATED, ABANDONED, FAILED, PREEMPTED}; PREEMPTED -> QUEUED) with an
optional per-request deadline and priority.  ``submit()`` enqueues into
a bounded admission queue and raises typed ``AdmissionRejected``
backpressure when it is full; each ``step()`` first runs lifecycle
housekeeping (``pump()``): deadline-expired work is ABANDONED (queued
or running — partial tokens are kept), cache pressure is applied, and
free slots are filled from the queue (highest priority first, resumed
work ahead of fresh).

Preemption replaces silent truncation: when the effective slot-cache
limit drops below ``max_len`` (fault injection, ``set_cache_pressure``)
or strictly-higher-priority work is queued behind a full engine, the
lowest-priority/youngest victim is PREEMPTED — its slot is cleared by
the jitted masked rollback (``_rollback_tail``, the same leaf
classification as the bucketed masked insert) — and re-queued at the
front.  Resume re-prefills the ORIGINAL prompt through the normal
bucketed prefill, then replays the generated prefix through the decode
jit teacher-forced (bitwise the decode steps the uninterrupted run
executed — prefilling prompt+prefix would NOT be bitwise: the prefill
path uses online softmax, decode does not), so a resumed request's
remaining tokens are bit-identical to an uninterrupted run.  Truncation
survives only where resume is physically impossible (fill reached
``max_len`` itself) or as the opt-in ``on_pressure="truncate"`` policy.
moe cannot preempt (decode rows are router-coupled, so a batch-1 replay
is not bitwise) and falls back to truncation.

Numeric guards: ``guards=True`` folds one ``jnp.isfinite`` all-reduce
over the selected logits into the prefill/decode/verify jits; a
non-finite row quarantines ONLY the offending request (FAILED, with
diagnostics: phase, non-finite count, engine step) while the rest of the
batch proceeds — mid-speculative-window the slot is rolled back, then
quarantined.  ``faults=FaultInjector(...)`` (serve/faults.py) wires a
seeded deterministic fault plan: NaN/Inf injection rides a traced
operand added to the logits inside the jit (so guards see injected
faults exactly like genuine ones), pressure windows drive preemption,
and planned transient ``EngineFault`` raises happen BEFORE any state
mutation so a bounded-retry driver can simply call ``step()`` again.

Flow: add_requests() buckets, pads, and prefills; step() decodes every
active slot in one batched decode_step and emits one token per active
request.  Retirement (``max_new_tokens`` reached or EOS sampled) is
checked wherever a token is appended — including the prefill-sampled
first token, so a one-token budget or an immediate EOS retires the
request at admission without entering the decode loop.  Retired requests
move to ``finished`` (drain with ``take_finished()``).

Self-speculative decoding: pass ``draft_params=`` (the same checkpoint
quantized at a lower bit-width from the same calibration pass — see
``launch.quantize.claq_quantize_with_draft``) and ``spec=SpecConfig(γ)``,
and ``step()`` becomes a propose/verify/rollback window
(serve/speculative.py): γ+1 draft decode steps, ONE target span verify
(``models.api.decode_span``, bitwise γ+1 successive decodes), greedy
acceptance, and a batched per-slot rollback of both caches
(``_rollback_tail``: masked K/V tail zeroing + fill-counter rewind,
the same leaf classification as the bucketed masked insert).  Greedy
speculation is lossless — emitted tokens, retirement points, and the
rolled-back cache are bit-identical to vanilla decode (DESIGN.md §8).
Families that cannot roll back (recurrent state, router-coupled moe,
ring caches) are rejected at construction.

Paged KV cache (``kv_layout="paged"``, DESIGN.md §11): instead of one
contiguous (n_slots, max_len) K/V strip per slot, each layer owns a
global page pool ``(n_pages + 1, page_size, ...)`` (the last row is the
scratch page absorbing masked writes) and each slot a page-table row,
mirrored on the host and broadcast to the device before any consuming
jit (``_sync_tables``).  Pages are allocated on demand — at admission
for the prompt, per step for decode writes (``_ensure_capacity``) — and
freed at retirement/preemption; ``PoolExhausted`` is the typed
backpressure when the pool runs dry (queued work waits, running work
preempts or retires TRUNCATED with diagnostics).  Prefill stays
contiguous: fragments are scattered into pages afterwards
(``_paged_insert``), so the prefill jits are shared with the contiguous
layout.  Paged fp decode is bit-identical to contiguous decode (the
gathered view has the contiguous cache's exact shape, so XLA reduces
identically; fresh pages are zeroed so masked rows contribute exactly
0.0).  ``kv_dtype="int8"`` stores resident pages quantized per token row
(absmax/127 scales in a parallel pool) — bounded error (scale/2 per
element), ~4x the tokens per byte of fp32, and no preemption (an fp
replay cannot reproduce int8 history; pressure truncates, like moe).
Requests sharing a prompt prefix share physical pages (refcounted via
``PrefixRegistry``) and copy-on-write at the first write into a shared
page — prefill right-padding invariance makes the donor's page contents
bitwise what the sharer's own prefill would have produced.

``prefill_traces`` / ``decode_traces`` count actual XLA traces (a Python
side effect inside the jitted function runs once per trace); ``stats()``
reports them next to the bucketing policy's compile-cache accounting.
Speculation adds its own counters (``draft_prefill/draft_decode/verify
_traces``) — all bounded by constants independent of how many windows
run.  Lifecycle adds terminal-state, preemption/resume, and
admission-rejection counters.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.trace_rules import TraceSentinel
from repro.dist import context as dctx
from repro.dist import sharding as shd
from repro.kernels import ops as kops
from repro.kernels.plan import prepare_tree
from repro.models import api
from repro.models import modules as nn

from . import lifecycle as lc
from . import speculative
from .admission import AdmissionController, StepCostModel
from .bucketing import BucketingPolicy
from .chunked_prefill import ChunkedPrefillConfig, PrefillGroup
from .faults import FaultInjector, nonfinite_rows
from .lifecycle import (AdmissionQueue, AdmissionRejected, DeadlineExceeded,
                        EngineFault, IncompleteRun, RequestState, RetryPolicy,
                        TERMINAL_STATES)
from .paging import PageAllocator, PoolExhausted, PrefixRegistry
from .speculative import SpecConfig
from .telemetry import MetricsRegistry, Telemetry, registry_from_stats

Array = jax.Array

# Families whose caches are position-indexed and masked by a fill counter,
# making right-padding invisible to valid tokens.  moe is excluded even
# though its cache is attention-shaped: capacity-bounded routing sees the
# padded suffix (cap and the group-local cumsum depend on total token
# count), so padded prefill changes which valid tokens are capacity-dropped
# — bucketing moe needs a routing mask first.  The same router coupling
# makes moe prefill rows batch-DEPENDENT, so moe admissions are also never
# batched together (see add_requests); every other family's prefill rows
# are independent.
_PADDED_FAMILIES = ("dense",)

# Cache leaf names with a sequence axis to zero-mask past the true length
# (KVCache.k/v, MLACache.c_kv/k_pe) vs. fill counters to pin to it.
_SEQ_LEAVES = ("k", "v", "c_kv", "k_pe")
_LEN_LEAVES = ("length",)

# Paged-cache leaf names (models/layers.py PagedKVCache, mla.py
# PagedMLACache): pool rows / per-row int8 scales, each mapped to the
# contiguous-fragment leaf that feeds it at admission scatter time.  The
# "table" leaf is owned by the engine's host mirror (see _sync_tables) and
# the rollback/insert machinery never touches it — masking by the fill
# counter is what hides a rolled-back tail, exactly as in the contiguous
# layout.
_POOL_SRC = {"kp": "k", "vp": "v", "cp": "c_kv", "pp": "k_pe"}
_SCALE_SRC = {"k_scale": "k", "v_scale": "v",
              "c_scale": "c_kv", "p_scale": "k_pe"}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    state: RequestState = RequestState.QUEUED
    priority: int = 0                   # higher preempts lower under load
    deadline: Optional[float] = None    # absolute clock() time, or None
    submitted_at: float = 0.0
    preemptions: int = 0                # times this request was preempted
    diagnostics: Optional[Dict[str, Any]] = None
    kv_int8: bool = False               # admitted under the kv_int8 rung:
                                        # prefill K/V carries int8-page
                                        # numerics, so no fp resume replay
                                        # can reproduce it (non-preemptible)

    @property
    def tokens_out(self) -> int:
        """Tokens actually emitted so far — on a retired request, the
        post-hoc denominator for TPOT (``(last - first) / (tokens_out -
        1)``) and the per-request throughput numerator."""
        return len(self.tokens)

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def truncated(self) -> bool:
        return self.state is RequestState.TRUNCATED

    def transition(self, new_state: RequestState) -> None:
        lc.transition(self, new_state)


def _rollback_tail(cache, new_lens):
    """Rewind every slot's fill counter to ``new_lens`` ((B,) int32) and
    zero the K/V positions at or past it — the per-slot cache rollback a
    rejected speculation window needs, and (with a victim's length set to
    0) the masked slot CLEAR preemption needs.  Reuses the bucketed-insert
    leaf classification (`_SEQ_LEAVES` / `_LEN_LEAVES` by NamedTuple field
    name in the key path), so the rolled-back cache is bit-identical to one
    that never saw the rejected tail (the tail past a slot's fill is zero
    from init / the masked insert).  Jitted once in the engine — the
    target and the draft cache share the treedef, so one trace serves
    both; lengths arrive traced, so acceptance patterns never retrace."""
    new_lens = jnp.asarray(new_lens, jnp.int32)

    def rb(path, leaf):
        name = getattr(path[-1], "name", None)
        if name in _LEN_LEAVES:
            if leaf.ndim == 1:                   # (B,)
                return new_lens.astype(leaf.dtype)
            return jnp.broadcast_to(              # (layers, B)
                new_lens, leaf.shape).astype(leaf.dtype)
        if name in _SEQ_LEAVES:                  # (layers, B, S, ...)
            pos = jnp.arange(leaf.shape[2])
            keep = (pos[None, :] < new_lens[:, None]).reshape(
                (1,) + leaf.shape[1:3] + (1,) * (leaf.ndim - 3))
            return jnp.where(keep, leaf, jnp.zeros((), leaf.dtype))
        return leaf

    return jax.tree_util.tree_map_with_path(rb, cache)


def _masked_group_insert(full, frag, slots: Sequence[int],
                         lens: Sequence[int], masked: bool):
    """Insert the first ``len(slots)`` rows of a prefill cache fragment
    into the batched cache at ``slots``, keeping only each row's first
    ``lens[r]`` sequence positions.  One whole-cache copy per admitted
    GROUP, not per request (the fragment batch may be larger — its tail
    rows are batch-bucketing dummies and are dropped).

    With `masked` (padded admission): fill counters advanced to the bucket
    size by the padded prefill are reset to the true lengths, and the
    padded K/V tail is zeroed — the batched cache ends up bit-identical to
    an unpadded prefill's.  Leaves are classified by their NamedTuple field
    name in the pytree key path.
    """
    B = len(slots)
    slots = jnp.asarray(slots, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)

    def ins(path, fl, fr):
        name = getattr(path[-1], "name", None)
        if masked and name in _LEN_LEAVES:
            if fl.ndim == 1:
                return fl.at[slots].set(lens.astype(fl.dtype))
            return fl.at[:, slots].set(
                jnp.broadcast_to(lens, (fl.shape[0], B)).astype(fl.dtype))
        if fl.ndim == 1:            # per-slot scalars, e.g. enc_len
            return fl.at[slots].set(fr[:B])
        v = fr[:, :B]               # (layers, B, seq?, ...) fragment rows
        if masked and name in _SEQ_LEAVES:
            pos = jnp.arange(v.shape[2])
            keep = (pos[None, :] < lens[:, None]).reshape(
                (1, B, -1) + (1,) * (v.ndim - 3))
            v = jnp.where(keep, v, jnp.zeros((), v.dtype))
        return fl.at[:, slots].set(v)

    return jax.tree_util.tree_map_with_path(ins, full, frag)


class ServingEngine:
    def __init__(self, params, cfg, n_slots: int = 8, max_len: int = 1024,
                 dtype=jnp.float32, prepare: bool = True,
                 min_bucket: int = 16, bucketing: bool = True,
                 mesh=None, plan_bn: Optional[int] = None,
                 plan_bk: Optional[int] = None,
                 draft_params=None, spec: Optional[SpecConfig] = None,
                 draft_plan_bn: Optional[int] = None,
                 draft_plan_bk: Optional[int] = None,
                 act_dtype: Optional[str] = None,
                 guards: bool = False,
                 faults: Optional[FaultInjector] = None,
                 queue_depth: Optional[int] = None,
                 on_pressure: str = "preempt",
                 clock=None,
                 kv_layout: str = "contiguous",
                 page_size: Optional[int] = None,
                 kv_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 share_prefixes: bool = True,
                 verify_contracts: bool = False,
                 telemetry: Optional[Telemetry] = None,
                 chunked_prefill=None,
                 controller: Optional[AdmissionController] = None,
                 cost_model: Optional[StepCostModel] = None):
        if cfg.family == "encdec":
            raise NotImplementedError(
                "ServingEngine serves decoder-only families; encdec "
                "admission needs a frames input and a length-masked encoder")
        act_dtype = kops.normalize_act_dtype(act_dtype)
        if act_dtype is not None and not prepare:
            raise ValueError(
                "act_dtype='int8' needs ahead-of-time plans — drop "
                "prepare=False (the int8 path runs on prepared leaves only)")
        if on_pressure not in ("preempt", "truncate"):
            raise ValueError(
                f"on_pressure must be 'preempt' or 'truncate', got "
                f"{on_pressure!r}")
        if kv_layout not in ("contiguous", "paged"):
            raise ValueError(
                f"kv_layout must be 'contiguous' or 'paged', got "
                f"{kv_layout!r}")
        self._paged = kv_layout == "paged"
        if not self._paged and (page_size is not None or kv_pages is not None
                                or kv_dtype is not None):
            raise ValueError(
                "page_size / kv_pages / kv_dtype configure the paged cache "
                "— pass kv_layout='paged'")
        self.page_size = None
        self.n_pages = None
        self.kv_dtype = None
        if self._paged:
            api.validate_paged_support(cfg)
            if kv_dtype in (None, "f32"):
                kv_dtype = None
            elif kv_dtype != "int8":
                raise ValueError(
                    f"unsupported kv_dtype {kv_dtype!r} (expected 'f32' or "
                    f"'int8')")
            self.page_size = int(page_size) if page_size is not None else 16
            if self.page_size < 1 or max_len % self.page_size:
                raise ValueError(
                    f"page_size={self.page_size} must be >= 1 and divide "
                    f"max_len={max_len}")
            # capacity-equivalent default: the pool holds exactly what the
            # contiguous layout reserved; pass kv_pages to over/undercommit
            self.n_pages = (int(kv_pages) if kv_pages is not None
                            else n_slots * (max_len // self.page_size))
            self.kv_dtype = kv_dtype
        if draft_plan_bn is not None or draft_plan_bk is not None:
            if spec is None:
                raise ValueError(
                    "draft_plan_bn/draft_plan_bk tune the speculative "
                    "draft's plan tiles — pass spec=SpecConfig(...) and "
                    "draft_params")
            if not prepare:
                raise ValueError(
                    "draft_plan_bn/draft_plan_bk shape the draft's "
                    "ahead-of-time plans — they do nothing with "
                    "prepare=False, so that combination is rejected")
        if spec is not None:
            speculative.validate_spec_support(cfg)
            if draft_params is None:
                raise ValueError(
                    "speculative decoding needs draft_params (the same "
                    "checkpoint quantized at SpecConfig.draft_bits — see "
                    "launch.quantize.claq_quantize_with_draft)")
        elif draft_params is not None:
            raise ValueError("draft_params given without spec=SpecConfig(...)")
        # Compile every QuantizedTensor leaf into its ahead-of-time
        # inference plan ONCE; the prepared leaves then flow through the
        # jitted steps with zero per-trace layout work and one kernel
        # launch per distinct stripe bit-width.  plan_bn / plan_bk cap the
        # kernel block sizes (deployment tuning knob; smaller bn also
        # lowers the whole-tile granularity at which plans shard over
        # "model").
        prep_kw = {}
        if plan_bn is not None:
            prep_kw["bn"] = plan_bn
        if plan_bk is not None:
            prep_kw["bk"] = plan_bk
        self.params = prepare_tree(params, **prep_kw) if prepare else params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mesh = mesh
        self.act_dtype = act_dtype
        # ---- lifecycle / robustness knobs --------------------------------
        self.guards = bool(guards)
        self.faults = faults
        self.on_pressure = on_pressure
        self._clock = clock if clock is not None else time.monotonic
        # Per-request span recorder (serve/telemetry.py): every hook call
        # below is guarded by `is not None`, so a disabled engine pays one
        # predicate per lifecycle edge and NOTHING inside the jits —
        # telemetry is host-side by construction (AST/trace contract
        # rules stay green with it attached).  The recorder binds THIS
        # engine's injectable clock, so StepClock runs record
        # deterministic timestamps.
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.attach(n_slots=n_slots, clock=self._clock)
        self._pressure_limit: Optional[int] = None
        # moe decode rows are router-coupled: a batch-1 resume replay is
        # not bitwise the batched decode, so moe cannot preempt and falls
        # back to truncation under pressure.  int8 resident pages cannot
        # preempt either: resume replays history through the fp decode jit,
        # which cannot reproduce the quantized K/V the uninterrupted run
        # accumulated — truncation under pressure, same as moe.
        self._preemptible = (cfg.family != "moe"
                             and self.kv_dtype != "int8")
        self.queue = AdmissionQueue(
            queue_depth if queue_depth is not None else max(2 * n_slots, 1))
        # Padding additionally requires linear (non-ring) caches: a
        # sliding-window ring keeps the LAST W keys, so a padded suffix
        # would evict valid ones and the masked insert's linear-position
        # zeroing would be meaningless in ring-slot space.
        self.bucketing = BucketingPolicy(
            min_bucket=min_bucket, max_len=max_len,
            enabled=(bucketing and cfg.family in _PADDED_FAMILIES
                     and cfg.attn_window is None))
        # ---- chunked prefill (serve/chunked_prefill.py) ------------------
        # The gate mirrors the bucketing/padding gate, but hard: chunking
        # rides the model layers' uniform-fill prefill branch, which only
        # the linear-cache padded families implement (moe's router couples
        # rows; ring caches have no linear chunk positions).
        self.chunked: Optional[ChunkedPrefillConfig] = None
        if chunked_prefill is not None:
            cpc = (chunked_prefill
                   if isinstance(chunked_prefill, ChunkedPrefillConfig)
                   else ChunkedPrefillConfig(chunk_tokens=int(chunked_prefill)))
            if (cfg.family not in _PADDED_FAMILIES
                    or cfg.attn_window is not None):
                raise NotImplementedError(
                    f"chunked prefill supports the padded linear-cache "
                    f"families {_PADDED_FAMILIES} (family={cfg.family!r}, "
                    f"attn_window={cfg.attn_window!r})")
            if max_len % cpc.chunk_tokens:
                raise ValueError(
                    f"chunk_tokens={cpc.chunk_tokens} must divide max_len="
                    f"{max_len}: the final chunk's dynamic_update_slice "
                    f"would clamp past the cache end and shift real rows")
            self.chunked = cpc
        self._prefill_groups: List[PrefillGroup] = []
        self.chunk_prefill_traces = 0
        self.draft_chunk_prefill_traces = 0
        self.chunks_processed = 0
        # ---- overload control plane (serve/admission.py) -----------------
        self.controller = controller
        self.cost_model = cost_model
        self.last_step_cost_ms: Optional[float] = None
        self._step_prefill_tokens = 0
        self._step_decode_calls = 0
        self._step_draft_calls = 0
        self._step_verify_tokens = 0
        # Degradation-ladder knobs the controller drives; nominal values
        # make an uncontrolled engine behave exactly as before.
        self._gamma_eff = spec.gamma if spec is not None else 0
        self._spec_enabled = spec is not None
        self._kv_int8_admission = False
        # Distinct speculative window sizes this engine may verify at —
        # the verify compile budget (controller.attach adds γ//2 when the
        # spec_half rung exists).
        self.verify_gammas = {spec.gamma} if spec is not None else set()
        self._cache_kw: Dict[str, Any] = {}
        if self._paged:
            self._cache_kw = dict(page_size=self.page_size,
                                  n_pages=self.n_pages,
                                  kv_dtype=self.kv_dtype)
        self.cache = api.make_cache(cfg, n_slots, max_len, dtype=dtype,
                                    **self._cache_kw)
        self._cache_shardings = None
        if mesh is not None:
            # Shard params by the serve TP rule (quantized units split
            # along N as whole tile groups, dense leaves by largest
            # model-divisible dim) and the slot cache over "dp" (+ KV
            # heads over "model").  The cache shardings are kept: eager
            # admission inserts produce mixed placements, so the cache is
            # re-pinned after every insert (see add_requests).
            self.params = jax.device_put(
                self.params, shd.tree_shardings(
                    self.params, shd.spec_for_param_serve, cfg, mesh))
            self._cache_shardings = shd.tree_shardings(
                self.cache, shd.spec_for_cache, cfg, mesh)
            self.cache = jax.device_put(self.cache, self._cache_shardings)
        # fp fragment dtype: prefill fragments are ALWAYS contiguous fp
        # caches (paged admission scatters them into pool pages afterwards),
        # so derive the dtype from the request, canonicalized exactly as
        # make_cache would — the first cache leaf may be int8/int32 paged.
        self._cache_dtype = jnp.zeros((), dtype).dtype
        # ---- paged-cache host state: allocator, tables, counters ---------
        self.allocator: Optional[PageAllocator] = None
        self.prefix_registry: Optional[PrefixRegistry] = None
        if self._paged:
            self.allocator = PageAllocator(self.n_pages, self.page_size)
            if share_prefixes:
                self.prefix_registry = PrefixRegistry(self.allocator)
            # host mirror of every slot's table row; the engine is the sole
            # mutator — _sync_tables broadcasts it into the device cache(s)
            # before any jit that consumes them
            self._tables = np.full((n_slots, max_len // self.page_size),
                                   self.allocator.scratch, np.int32)
            self._tables_dirty = False
            self._req_pages: Dict[int, List[int]] = {}
            self.cow_copies = 0
            self.prefix_hits = 0
            self.prefix_shared_tokens = 0
            self.page_evictions = 0
            self.peak_pages_in_use = 0
            self.peak_pages_per_request = 0
        self.free = list(range(n_slots))
        self.active: Dict[int, Request] = {}
        self.finished: Dict[int, Request] = {}
        self.last_token = np.zeros((n_slots,), np.int32)
        self._uid = 0

        # Trace counters: a Python side effect inside a jitted function
        # runs once per trace, so these count compiles, not calls.
        self.prefill_traces = 0
        self.decode_traces = 0
        self.draft_prefill_traces = 0
        self.draft_decode_traces = 0
        self.verify_traces = 0
        # Retrace sentinel: records the abstract signature of every jit
        # call so the trace rules (repro.analysis) can cross-check the
        # counters above against distinct-signature counts and the
        # bucketing compile budget.
        self.sentinel = TraceSentinel()

        # Emission counters (all modes): tokens actually appended to
        # requests, and the engine steps that produced them (decode steps
        # vanilla, verify windows speculative) — stats() derives
        # tokens-per-step from these.  Speculation adds drafted/accepted.
        self.emitted_tokens = 0
        self.engine_steps = 0
        self.spec_drafted = 0
        self.spec_accepted = 0

        # Lifecycle counters: terminal states, preemption/resume traffic,
        # and typed admission rejections (backpressure observed).
        self.state_counts: collections.Counter = collections.Counter()
        self.preemptions = 0
        self.resumes = 0
        self.admission_rejections = 0

        # act_dtype scopes the per-token int8 activation quantization of
        # every quantized matmul inside the jitted steps; QuantMode.mode /
        # .interpret stay whatever the ambient context set (the wrap runs
        # at trace time — QuantMode is read inside dense()).
        #
        # The jitted steps return (logits, cache, nonfinite) — the third
        # output is the per-row non-finite count when guards are on, None
        # (an empty pytree node, zero cost) otherwise.  ``iv`` is the
        # fault injector's additive per-slot vector, applied INSIDE the
        # jit so the guard sees injected faults exactly like genuine
        # ones; engines without an injector pass None.
        def _decode_fn(p, t, c, iv):
            self.decode_traces += 1
            with nn.activation_quant(self.act_dtype):
                logits, cache = api.decode_step(p, cfg, t, c)
            if iv is not None:
                logits = logits + iv[:, None]
            nf = nonfinite_rows(logits) if self.guards else None
            return logits, cache, nf

        # One stable jitted prefill keyed on the (batch, bucket) operand
        # shape: admissions at a previously seen shape hit the compile
        # cache.  True lengths arrive as a traced operand (logits_at), so
        # they never force a retrace.
        def _prefill_fn(p, t, c, lens):
            self.prefill_traces += 1
            with nn.activation_quant(self.act_dtype):
                logits, cache = api.prefill_step(p, cfg, {"tokens": t}, c,
                                                 logits_at=lens - 1)
            nf = nonfinite_rows(logits) if self.guards else None
            return logits, cache, nf

        self._decode = jax.jit(_decode_fn)
        self._prefill = jax.jit(_prefill_fn)

        # Chunked prefill: the operand shape is FIXED at (batch_bucket,
        # chunk_tokens) and the chunk position rides the fragment cache's
        # fill counter (the model's uniform-fill branch reads
        # cache.length[0] as the append offset), so every chunk of every
        # prompt at a given batch bucket shares ONE trace.  Logits are
        # read per row at the position of the row's true last token IF it
        # falls in this chunk (clipped otherwise; the host discards those
        # rows) — same traced-logits_at idea as the monolithic prefill.
        if self.chunked is not None:
            C = self.chunked.chunk_tokens

            def _chunk_prefill_fn(p, t, c, lens, start):
                self.chunk_prefill_traces += 1
                with nn.activation_quant(self.act_dtype):
                    logits, cache = api.prefill_step(
                        p, cfg, {"tokens": t}, c,
                        logits_at=jnp.clip(lens - 1 - start, 0, C - 1))
                nf = nonfinite_rows(logits) if self.guards else None
                return logits, cache, nf

            self._chunk_prefill = jax.jit(_chunk_prefill_fn)
            if spec is not None:
                def _draft_chunk_prefill_fn(p, t, c):
                    self.draft_chunk_prefill_traces += 1
                    # cache only, like the monolithic draft prefill
                    with nn.activation_quant(self.act_dtype):
                        _, cache = api.prefill_step(p, cfg, {"tokens": t}, c)
                    return cache

                self._draft_chunk_prefill = jax.jit(_draft_chunk_prefill_fn)
        # One rollback trace serves every cache with the engine's treedef
        # (target and draft alike) and doubles as the preemption slot
        # clear; per-slot lengths are traced, so acceptance/eviction
        # patterns never mint compiles.
        self._rollback = jax.jit(_rollback_tail)

        # -------- speculative decoding: draft model + verify + rollback --
        self.spec = spec
        self.draft_params = None
        self.draft_cache = None
        if spec is not None:
            # The draft rides the same machinery as the target: prepared
            # CLAQ plans, the same sharding rules, its own slot cache.
            # Its jits are SEPARATE (draft params have their own pytree
            # structure — fewer stripes at 2-bit — so they could never
            # share a compile cache entry with the target anyway) and
            # carry their own trace counters.
            # Draft-specific plan tiles: the 2-bit draft's groups span
            # skinnier K stripes and smaller matrices benefit from smaller
            # output tiles, so its bn/bk caps are tunable independently of
            # the target's (ROADMAP spec item b); they default to the
            # target's caps.
            dprep_kw = dict(prep_kw)
            if draft_plan_bn is not None:
                dprep_kw["bn"] = draft_plan_bn
            if draft_plan_bk is not None:
                dprep_kw["bk"] = draft_plan_bk
            self.draft_params = (prepare_tree(draft_params, **dprep_kw)
                                 if prepare else draft_params)
            self.draft_cache = api.make_cache(cfg, n_slots, max_len,
                                              dtype=dtype, **self._cache_kw)
            if mesh is not None:
                self.draft_params = jax.device_put(
                    self.draft_params, shd.tree_shardings(
                        self.draft_params, shd.spec_for_param_serve, cfg,
                        mesh))
                self.draft_cache = jax.device_put(self.draft_cache,
                                                  self._cache_shardings)

            def _draft_decode_fn(p, t, c):
                self.draft_decode_traces += 1
                with nn.activation_quant(self.act_dtype):
                    return api.decode_step(p, cfg, t, c)

            def _draft_prefill_fn(p, t, c):
                self.draft_prefill_traces += 1
                # cache only: the draft's prefill logits are never read,
                # and not returning them lets XLA drop the whole-bucket
                # unembedding matmul from the compiled draft prefill
                with nn.activation_quant(self.act_dtype):
                    _, cache = api.prefill_step(p, cfg, {"tokens": t}, c)
                return cache

            def _verify_fn(p, t, c, iv):
                self.verify_traces += 1
                with nn.activation_quant(self.act_dtype):
                    logits, cache = api.decode_span(p, cfg, t, c)
                if iv is not None:
                    logits = logits + iv[:, None, None]
                nf = nonfinite_rows(logits) if self.guards else None
                return logits, cache, nf

            self._draft_decode = jax.jit(_draft_decode_fn)
            self._draft_prefill = jax.jit(_draft_prefill_fn)
            self._verify = jax.jit(_verify_fn)

        # Attach the SLO controller last: it reads the engine's realized
        # capabilities (spec, kv_dtype) to build its degradation ladder,
        # and may extend verify_gammas — so this must precede the contract
        # gate below, whose compile budgets read that set.
        if controller is not None:
            controller.attach(self)

        # Opt-in contract gate: lower+compile the decode path NOW and run
        # the compiled-artifact rules against it (plus a dense dequantized
        # twin as the gather baseline), raising ContractViolation before
        # the engine serves a single token from a non-conforming artifact.
        self.contract_report = None
        if verify_contracts:
            from repro.analysis.artifacts import verify_engine
            self.contract_report = verify_engine(self)

    @property
    def clock(self):
        """The engine's injectable monotonic clock (``StepClock`` in
        deterministic runs) — drivers and the replayer read time through
        this, never through the wall clock directly (AST-DT1)."""
        return self._clock

    @contextlib.contextmanager
    def _mesh_scope(self):
        """Activate the engine's mesh around jit calls so the layer-level
        `dist.context.constrain` hints apply inside the traces; a no-op
        for single-device engines."""
        if self.mesh is None:
            yield
            return
        with self.mesh, dctx.use_mesh(self.mesh):
            yield

    def _repin_cache(self):
        """Re-pin the slot cache(s) after an eager host-side update (masked
        insert, preemption clear) so the decode jit keeps one stable input
        sharding; a no-op for single-device engines."""
        if self._cache_shardings is None:
            return
        self.cache = jax.device_put(self.cache, self._cache_shardings)
        if self.spec is not None:
            self.draft_cache = jax.device_put(self.draft_cache,
                                              self._cache_shardings)

    # ------------------------------------------------------------- paged sync
    def _sync_tables(self) -> None:
        """Broadcast the host page-table mirror into every cache's
        (L, n_slots, max_pages) table leaves before a jit consumes them.
        The engine is the SOLE table mutator (model code only reads
        tables), so one broadcast per dirty step keeps host and device in
        lockstep; clean steps cost nothing."""
        if not self._paged or not self._tables_dirty:
            return
        tbl = jnp.asarray(self._tables)

        def st(path, leaf):
            if getattr(path[-1], "name", None) == "table":
                return jnp.broadcast_to(tbl, leaf.shape).astype(leaf.dtype)
            return leaf

        self.cache = jax.tree_util.tree_map_with_path(st, self.cache)
        if self.spec is not None:
            self.draft_cache = jax.tree_util.tree_map_with_path(
                st, self.draft_cache)
        self._repin_cache()
        self._tables_dirty = False

    def _map_pools(self, fn) -> None:
        """Apply ``fn`` to every pool/scale leaf of the target (and draft)
        cache — the shared plumbing of page zeroing and COW copies."""
        def go(path, leaf):
            name = getattr(path[-1], "name", None)
            if name in _POOL_SRC or name in _SCALE_SRC:
                return fn(leaf)
            return leaf

        self.cache = jax.tree_util.tree_map_with_path(go, self.cache)
        if self.spec is not None:
            self.draft_cache = jax.tree_util.tree_map_with_path(
                go, self.draft_cache)
        self._repin_cache()

    def _zero_pages(self, pages: Sequence[int]) -> None:
        """Zero freshly allocated pages in both caches: preserves the
        contiguous invariant that every masked/unwritten cache row is
        exactly zero, so a recycled page can never leak its previous
        holder's rows into another request's (zero-weight) gather — and
        the zero-weight contribution itself stays exactly 0.0, keeping
        paged decode bitwise."""
        idx = jnp.asarray(sorted(set(int(p) for p in pages)), jnp.int32)
        self._map_pools(lambda l: l.at[:, idx].set(jnp.zeros((), l.dtype)))

    def _copy_pages(self, pairs: Sequence) -> None:
        """Copy-on-write: duplicate the pool (and scale) rows of shared
        pages into fresh private ones, target and draft cache alike."""
        olds = jnp.asarray([int(o) for o, _ in pairs], jnp.int32)
        news = jnp.asarray([int(n) for _, n in pairs], jnp.int32)
        self._map_pools(lambda l: l.at[:, news].set(l[:, olds]))

    def lower_decode(self):
        """AOT-lower the decode step against the engine's CURRENT
        params/cache (sharded when a mesh is wired) — for HLO inspection:
        tests assert the compiled step contains no weight-sized all-gather
        (decode stays weight-resident per shard).  Note: lowering traces,
        so it bumps `decode_traces`."""
        self._sync_tables()
        self.sentinel.observe_lowering("decode")
        toks = jnp.asarray(self.last_token, jnp.int32)
        with self._mesh_scope():
            return self._decode.lower(self.params, toks, self.cache, None)

    # ------------------------------------------------------------------ admit
    @staticmethod
    def _fill(req: Request) -> int:
        """Slot-cache positions this request occupies: the prompt plus one
        K/V write per decode step so far (the pending last_token's write
        belongs to the NEXT step)."""
        return len(req.prompt) + len(req.tokens) - 1

    # ------------------------------------------------------------- page plans
    def _note_page_peaks(self, req: Optional[Request] = None) -> None:
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.allocator.pages_in_use)
        if req is not None:
            self.peak_pages_per_request = max(
                self.peak_pages_per_request,
                len(self._req_pages.get(req.uid, ())))

    def _alloc_evicting(self, n: int) -> List[int]:
        """Allocate n pages, evicting prefix-registry entries (oldest
        first) under exhaustion; ``PoolExhausted`` propagates only once
        even an empty registry cannot satisfy the request."""
        while True:
            try:
                return self.allocator.alloc(n)
            except PoolExhausted:
                if (self.prefix_registry is None
                        or not self.prefix_registry.evict_one()):
                    raise
                self.page_evictions += 1

    def _plan_pages(self, req: Request, n_tokens: int,
                    exact_ok: bool = True):
        """Reserve the pages covering ``n_tokens`` resident positions for
        one request: shared prefix pages first (retained read-only from
        the registry), fresh private pages for the rest.  Returns
        ``(pages, table_row, write_row)`` — ``write_row`` marks the blocks
        the admission scatter may write (scratch everywhere else: shared
        pages stay read-only until COW).  All-or-nothing: on
        ``PoolExhausted`` no reference survives.  ``exact_ok=False``
        (resume) shares only whole pages, since the resumed request's
        tokens diverge inside the first partial page."""
        ps, scratch = self.page_size, self.allocator.scratch
        mp = self.max_len // ps
        nb = -(-n_tokens // ps)
        shared: List[int] = []
        if self.prefix_registry is not None:
            _, shared = self.prefix_registry.lookup(req.prompt,
                                                    exact_ok=exact_ok)
            shared = shared[:nb]
        if shared:
            # retain BEFORE allocating: allocation may evict the donor's
            # registry entry, and only our reference keeps its pages alive
            self.allocator.retain(shared)
        try:
            priv = self._alloc_evicting(nb - len(shared))
        except PoolExhausted:
            if shared:
                self.allocator.free(shared)
            raise
        if shared:
            self.prefix_hits += 1
            self.prefix_shared_tokens += min(len(shared) * ps, n_tokens)
        pages = shared + priv
        row = np.full((mp,), scratch, np.int32)
        row[:nb] = pages
        wrow = np.full((mp,), scratch, np.int32)
        wrow[len(shared):nb] = priv
        return pages, row, wrow

    def _release_pages(self, req: Request) -> None:
        """Drop a retiring/preempted request's page references and point
        its table row back at scratch.  The stale device rows stay until
        the pages are reallocated (and zeroed) — masking already hides
        them, exactly as a contiguous slot's stale tail is hidden."""
        if not self._paged:
            return
        pages = self._req_pages.pop(req.uid, None)
        if pages:
            self.allocator.free(pages)
        if req.slot >= 0:
            self._tables[req.slot, :] = self.allocator.scratch
            self._tables_dirty = True

    def _paged_insert(self, cache, frag, slots: Sequence[int],
                      lens: Sequence[int], wrows) -> Any:
        """Scatter a prefilled CONTIGUOUS fp cache fragment into pool
        pages — the paged counterpart of `_masked_group_insert`.
        ``wrows`` ((B, max_pages) int32) names the page each max_len block
        of each fragment row lands in; scratch marks blocks that are not
        this group's to write (shared prefix pages, unallocated tail) —
        their rows land in the pool's scratch page.  Rows past each true
        length are zeroed first (the bucketed-padding fix), so resident
        pages never hold padding garbage; int8 pools quantize each token
        row on the way in.  Device tables are NOT touched here — the host
        mirror was updated by the caller and `_sync_tables` broadcasts it
        before the next consuming jit."""
        B = len(slots)
        ps = self.page_size
        mp = self.max_len // ps
        lens_j = jnp.asarray(lens, jnp.int32)
        wt = jnp.asarray(np.asarray(wrows, np.int32).reshape(-1))
        slots_j = jnp.asarray(slots, jnp.int32)

        frag_leaves: Dict[Any, Array] = {}

        def collect(path, leaf):
            frag_leaves[getattr(path[-1], "name", None)] = leaf
            return leaf

        jax.tree_util.tree_map_with_path(collect, frag)

        def rows_for(src):
            v = frag_leaves[src][:, :B]          # (L, B, max_len, feat...)
            pos = jnp.arange(v.shape[2])
            keep = (pos[None, :] < lens_j[:, None]).reshape(
                (1, B, -1) + (1,) * (v.ndim - 3))
            v = jnp.where(keep, v, jnp.zeros((), v.dtype))
            v = v.reshape((v.shape[0], B, mp, ps) + v.shape[3:])
            return v.reshape((v.shape[0], B * mp, ps) + v.shape[4:])

        def ins(path, fl):
            name = getattr(path[-1], "name", None)
            if name in _LEN_LEAVES:
                return fl.at[:, slots_j].set(
                    jnp.broadcast_to(lens_j, (fl.shape[0], B)).astype(
                        fl.dtype))
            if name in _POOL_SRC:
                v = rows_for(_POOL_SRC[name])
                if self.kv_dtype == "int8":
                    flat = v.reshape(v.shape[:3] + (-1,))
                    xq, _ = kops.quantize_activations(
                        flat.astype(jnp.float32))
                    v = xq.reshape(v.shape)
                return fl.at[:, wt].set(v.astype(fl.dtype))
            if name in _SCALE_SRC:
                v = rows_for(_SCALE_SRC[name])
                flat = v.reshape(v.shape[:3] + (-1,))
                _, sc = kops.quantize_activations(flat.astype(jnp.float32))
                return fl.at[:, wt].set(sc[..., 0])
            return fl

        return jax.tree_util.tree_map_with_path(ins, cache)

    def _make_request(self, prompt: Sequence[int], max_new_tokens: int,
                      eos_id: Optional[int], priority: int,
                      deadline_ms: Optional[float]) -> Request:
        prompt = list(prompt)
        if len(prompt) == 0:
            raise AdmissionRejected("empty prompt")
        if len(prompt) + max_new_tokens > self.max_len:
            # The slot cache must hold the prompt plus every generated
            # token fed back through decode; past max_len the K/V
            # update clamps/drops, silently corrupting the last cache
            # position — reject at admission instead.
            raise AdmissionRejected(
                f"request does not fit its slot cache: {len(prompt)} "
                f"prompt + {max_new_tokens} new tokens > max_len="
                f"{self.max_len}; shorten the prompt, lower "
                f"max_new_tokens, or build the engine with a larger "
                f"max_len")
        now = self._clock()
        deadline = None
        if deadline_ms is not None:
            if deadline_ms <= 0:
                raise DeadlineExceeded(
                    f"deadline_ms={deadline_ms} is already expired at "
                    f"submission")
            deadline = now + deadline_ms / 1e3
        req = Request(self._uid, prompt, max_new_tokens, eos_id,
                      priority=priority, deadline=deadline, submitted_at=now)
        self._uid += 1
        return req

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None, priority: int = 0,
               deadline_ms: Optional[float] = None) -> int:
        """Enqueue one request into the bounded admission queue (the
        backpressure path — ``AdmissionRejected`` when the queue is full
        or the request can never fit, ``DeadlineExceeded`` when its SLO
        is already blown).  Admission into a free slot happens at the
        next ``step()``/``pump()``; returns the uid."""
        try:
            req = self._make_request(prompt, max_new_tokens, eos_id,
                                     priority, deadline_ms)
            self.queue.push(req)
        except AdmissionRejected:
            self.admission_rejections += 1
            raise
        if self.telemetry is not None:
            self.telemetry.on_submit(req, self.engine_steps)
        return req.uid

    def add_request(self, prompt: Sequence[int], max_new_tokens: int = 16,
                    eos_id: Optional[int] = None) -> int:
        return self.add_requests([prompt], max_new_tokens, eos_id)[0]

    def add_requests(self, prompts: Sequence[Sequence[int]],
                     max_new_tokens: int = 16,
                     eos_id: Optional[int] = None, priority: int = 0,
                     deadline_ms: Optional[float] = None) -> List[int]:
        """Admit several prompts directly into free slots (bypassing the
        queue); those sharing a length bucket are padded to it and
        prefilled in ONE batched call.  Returns uids in prompt order
        (look in `active`/`finished` for the Request objects — an
        immediate EOS or a one-token budget retires at admission).
        Raises typed ``AdmissionRejected`` when the slots don't exist —
        use ``submit()`` for queued, backpressured admission."""
        if len(prompts) > len(self.free):
            raise AdmissionRejected(
                f"need {len(prompts)} free slots, have {len(self.free)}")
        reqs = [self._make_request(p, max_new_tokens, eos_id, priority,
                                   deadline_ms) for p in prompts]
        if self.telemetry is not None:
            for req in reqs:
                self.telemetry.on_submit(req, self.engine_steps)
        self._admit(reqs)
        return [r.uid for r in reqs]

    def _admit(self, reqs: List[Request]) -> None:
        """Prefill-admit fresh requests into free slots, grouped by length
        bucket (one batched prefill per group; moe one per prefill).
        Chunked engines route to ``_admit_chunked``: slots are reserved
        now, prefill happens chunk by chunk across subsequent steps."""
        if self.chunked is not None:
            self._admit_chunked(reqs)
            return
        # moe prefill rows are coupled through router capacity (a row's
        # tokens change which of another row's tokens are dropped), so moe
        # admissions run one per prefill to match per-request admission;
        # all other families' rows are independent and share a call.
        batch_safe = self.cfg.family != "moe"
        groups: Dict[Any, List[int]] = {}
        for i, req in enumerate(reqs):
            bucket = self.bucketing.bucket_for(len(req.prompt))
            groups.setdefault(bucket if batch_safe else (bucket, i),
                              []).append(i)

        # Paged: reserve every request's pages up front (shared prefix
        # pages from the registry, fresh ones from the pool), so a late
        # PoolExhausted cannot leave half the batch admitted — unwind and
        # re-raise with no reference leaked.
        plans: Dict[int, Any] = {}
        if self._paged:
            try:
                for req in reqs:
                    plans[req.uid] = self._plan_pages(req, len(req.prompt))
            except PoolExhausted:
                for pages, _, _ in plans.values():
                    self.allocator.free(pages)
                raise

        for key, idxs in groups.items():
            bucket = key if batch_safe else key[0]
            tel = self.telemetry
            t0 = tel.now() if tel is not None else 0.0
            B = len(idxs)
            # The batch size is bucketed too (next power of 2, capped at
            # n_slots): the jit cache is keyed on the (batch, bucket)
            # operand shape, so a drifting free-slot count must not mint
            # fresh compiles.  Dummy tail rows prefill garbage that is
            # never inserted.
            Bb = min(1 << (B - 1).bit_length(), self.n_slots)
            toks = np.zeros((Bb, bucket), np.int32)
            lens = np.ones((Bb,), np.int32)
            for r, i in enumerate(idxs):
                toks[r, :len(reqs[i].prompt)] = reqs[i].prompt
                lens[r] = len(reqs[i].prompt)
            self.bucketing.record(Bb, bucket)
            cache_b = api.make_cache(self.cfg, Bb, self.max_len,
                                     dtype=self._cache_dtype)
            self.sentinel.observe("prefill", (Bb, bucket))
            with self._mesh_scope():
                logits, cache_b, nf = self._prefill(
                    self.params, jnp.asarray(toks), cache_b,
                    jnp.asarray(lens))
                if self.spec is not None:
                    # the draft needs the prompt in ITS cache too (its
                    # first proposal continues from the target-sampled
                    # first token); the draft prefill's logits are unused
                    dcache_b = api.make_cache(self.cfg, Bb, self.max_len,
                                              dtype=self._cache_dtype)
                    self.sentinel.observe("draft_prefill", (Bb, bucket))
                    dcache_b = self._draft_prefill(
                        self.draft_params, jnp.asarray(toks), dcache_b)
            self._step_prefill_tokens += Bb * bucket * (
                2 if self.spec is not None else 1)
            if self._kv_int8_admission:
                # kv_int8 degradation rung: admit through int8 resident-page
                # numerics.  Skip the fake-quant when the pool is already
                # int8 (insertion quantizes anyway); always mark the request
                # so preemption treats it as non-resumable.
                for i in idxs:
                    reqs[i].kv_int8 = True
                if self.kv_dtype != "int8":
                    cache_b = self._fake_quant_frag(cache_b)
                    if self.spec is not None:
                        dcache_b = self._fake_quant_frag(dcache_b)
            firsts = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            nf_h = np.asarray(nf) if nf is not None else None
            slots = [self.free.pop(0) for _ in idxs]
            if self._paged:
                true_lens = lens[:B].tolist()
                wrows = np.stack([plans[reqs[i].uid][2] for i in idxs])
                self.cache = self._paged_insert(self.cache, cache_b, slots,
                                                true_lens, wrows)
                if self.spec is not None:
                    self.draft_cache = self._paged_insert(
                        self.draft_cache, dcache_b, slots, true_lens, wrows)
                for r, i in enumerate(idxs):
                    req = reqs[i]
                    pages, row, _ = plans[req.uid]
                    self._tables[slots[r]] = row
                    self._req_pages[req.uid] = list(pages)
                    # fake-quantized prefixes must never be shared with
                    # nominal admissions (on an int8 pool every insert is
                    # quantized identically, so sharing stays sound there)
                    if (self.prefix_registry is not None
                            and not (req.kv_int8
                                     and self.kv_dtype != "int8")):
                        self.prefix_registry.register(req.prompt, pages)
                    self._note_page_peaks(req)
                self._tables_dirty = True
            else:
                self.cache = _masked_group_insert(
                    self.cache, cache_b, slots, lens[:B].tolist(),
                    self.bucketing.enabled)
                if self.spec is not None:
                    self.draft_cache = _masked_group_insert(
                        self.draft_cache, dcache_b, slots, lens[:B].tolist(),
                        self.bucketing.enabled)
            self._repin_cache()
            if tel is not None:
                tel.on_admit([reqs[i].uid for i in idxs], slots, bucket,
                             Bb, tel.now() - t0, self.engine_steps)
            for r, i in enumerate(idxs):
                req = reqs[i]
                req.slot = slots[r]
                req.transition(RequestState.RUNNING)
                self.active[req.uid] = req
                if nf_h is not None and nf_h[r] > 0:
                    # genuine non-finite prompt logits: quarantine at
                    # admission — no first token is sampled from garbage
                    self._quarantine(req, "prefill", int(nf_h[r]))
                else:
                    self._append_token(req, int(firsts[r]))

    # ------------------------------------------------------- chunked prefill
    def _admit_chunked(self, reqs: List[Request]) -> None:
        """Reserve slots and open a ``PrefillGroup``: the prompts prefill
        chunk by chunk across subsequent steps (``_process_chunks``) and
        the batched cache is only written at completion, so a
        mid-``PREFILLING`` preempt needs no rollback.  Paged pools reserve
        every page up front with the same all-or-nothing unwind as
        ``_admit``."""
        plans: Dict[int, Any] = {}
        if self._paged:
            try:
                for req in reqs:
                    plans[req.uid] = self._plan_pages(req, len(req.prompt))
            except PoolExhausted:
                for pages, _, _ in plans.values():
                    self.allocator.free(pages)
                raise
        tel = self.telemetry
        B = len(reqs)
        Bb = min(1 << (B - 1).bit_length(), self.n_slots)
        frag = api.make_cache(self.cfg, Bb, self.max_len,
                              dtype=self._cache_dtype)
        draft_frag = None
        if self.spec is not None:
            draft_frag = api.make_cache(self.cfg, Bb, self.max_len,
                                        dtype=self._cache_dtype)
        slots = [self.free.pop(0) for _ in reqs]
        group = PrefillGroup(
            reqs=list(reqs), slots=slots,
            lens=[len(r.prompt) for r in reqs], bb=Bb, frag=frag,
            draft_frag=draft_frag, plans=plans,
            t0=tel.now() if tel is not None else 0.0)
        for req, slot in zip(reqs, slots):
            req.slot = slot
            req.transition(RequestState.PREFILLING)
            if self._paged:
                # pages live in _req_pages from reservation on, so the
                # one release path covers cancel mid-prefill and retire
                self._req_pages[req.uid] = list(plans[req.uid][0])
        self._prefill_groups.append(group)

    @property
    def pending_prefills(self) -> int:
        """Live requests currently mid-chunked-prefill."""
        return sum(len(g.live_rows()) for g in self._prefill_groups)

    @property
    def prefill_backlog_tokens(self) -> int:
        """Padded prefill tokens still owed to pending groups — the
        controller's defer signal."""
        if self.chunked is None:
            return 0
        C = self.chunked.chunk_tokens
        return sum(g.bb * C * g.chunks_remaining(C)
                   for g in self._prefill_groups)

    def _process_chunks(self) -> None:
        """Advance pending prefill groups by whole chunks, head group
        first, under the per-step padded-token budget (controller budget
        when attached, else the config's).  At least one chunk runs per
        step — progress is unconditional — and a group that finishes is
        completed immediately so its first tokens land this step."""
        if not self._prefill_groups:
            return
        C = self.chunked.chunk_tokens
        if self.controller is not None:
            budget = self.controller.prefill_budget()
        else:
            budget = self.chunked.budget_tokens
        spent = 0
        progressed = False
        while self._prefill_groups:
            g = self._prefill_groups[0]
            if not g.live_rows() or g.done:
                self._prefill_groups.pop(0)
                self._finish_group(g)
                continue
            cost = g.bb * C
            if budget is not None and progressed and spent + cost > budget:
                break
            self._run_chunk(g, C)
            spent += cost
            progressed = True
            if g.done:
                self._prefill_groups.pop(0)
                self._finish_group(g)

    def _run_chunk(self, g: PrefillGroup, C: int) -> None:
        """Run one ``(bb, C)`` chunk for a group: every live row advances
        C positions in the fragment cache.  Rows whose TRUE last prompt
        token falls inside this chunk stash their first-token argmax (and
        guard verdict) for completion; other rows' chunk logits are
        bucketing garbage and are ignored, exactly as monolithic prefill
        ignores all but the last position."""
        start = g.progress
        toks = np.zeros((g.bb, C), np.int32)
        for i in g.live_rows():
            seg = g.reqs[i].prompt[start:start + C]
            toks[i, :len(seg)] = seg
        lens = np.ones((g.bb,), np.int32)
        for i, n in enumerate(g.lens):
            lens[i] = n
        self.sentinel.observe("chunk_prefill", (g.bb, C))
        with self._mesh_scope():
            logits, g.frag, nf = self._chunk_prefill(
                self.params, jnp.asarray(toks), g.frag, jnp.asarray(lens),
                jnp.asarray(start, jnp.int32))
            if self.spec is not None:
                self.sentinel.observe("draft_chunk_prefill", (g.bb, C))
                g.draft_frag = self._draft_chunk_prefill(
                    self.draft_params, jnp.asarray(toks), g.draft_frag)
        g.progress = start + C
        self.chunks_processed += 1
        self._step_prefill_tokens += g.bb * C * (
            2 if self.spec is not None else 1)
        fin = [i for i in g.live_rows() if start <= g.lens[i] - 1 < start + C]
        if fin:
            firsts = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            nf_h = np.asarray(nf) if nf is not None else None
            for i in fin:
                g.firsts[i] = int(firsts[i])
                if nf_h is not None:
                    g.nf[i] = int(nf_h[i])
        if self.telemetry is not None:
            live = g.live_rows()
            self.telemetry.on_chunk(
                [g.reqs[i].uid for i in live],
                [g.slots[i] for i in live], start, C, g.bb,
                self.engine_steps)

    def _finish_group(self, g: PrefillGroup) -> None:
        """Complete a finished group: insert the fragment rows of every
        surviving member into the batched cache (the same masked insert /
        paged scatter as monolithic admission), transition them
        PREFILLING -> RUNNING, and release their stashed first tokens."""
        rows = g.live_rows()
        if not rows:
            return
        tel = self.telemetry
        reqs = [g.reqs[i] for i in rows]
        slots = [g.slots[i] for i in rows]
        lens = [g.lens[i] for i in rows]
        idx = jnp.asarray(rows, jnp.int32)

        def take(path, leaf):
            del path
            return leaf[idx] if leaf.ndim == 1 else leaf[:, idx]

        sel = jax.tree_util.tree_map_with_path(take, g.frag)
        dsel = None
        if g.draft_frag is not None:
            dsel = jax.tree_util.tree_map_with_path(take, g.draft_frag)
        if self._kv_int8_admission:
            for req in reqs:
                req.kv_int8 = True
            if self.kv_dtype != "int8":
                sel = self._fake_quant_frag(sel)
                if dsel is not None:
                    dsel = self._fake_quant_frag(dsel)
        if self._paged:
            wrows = np.stack([g.plans[r.uid][2] for r in reqs])
            self.cache = self._paged_insert(self.cache, sel, slots, lens,
                                            wrows)
            if dsel is not None:
                self.draft_cache = self._paged_insert(
                    self.draft_cache, dsel, slots, lens, wrows)
            for req, slot in zip(reqs, slots):
                pages, row, _ = g.plans[req.uid]
                self._tables[slot] = row
                # real data only lands in the pages NOW — registering the
                # prefix any earlier would let a sharer read garbage; a
                # fake-quantized prefix is never registered (sharing it
                # would leak kv_int8 numerics into nominal admissions)
                if (self.prefix_registry is not None
                        and not (req.kv_int8
                                 and self.kv_dtype != "int8")):
                    self.prefix_registry.register(req.prompt, pages)
                self._note_page_peaks(req)
            self._tables_dirty = True
        else:
            # always masked: the fragment fill is chunk-padded past each
            # row's true length, so the tail must be zeroed on insert
            self.cache = _masked_group_insert(self.cache, sel, slots, lens,
                                              True)
            if dsel is not None:
                self.draft_cache = _masked_group_insert(
                    self.draft_cache, dsel, slots, lens, True)
        self._repin_cache()
        if tel is not None:
            tel.on_admit([r.uid for r in reqs], slots, g.progress, g.bb,
                         tel.now() - g.t0, self.engine_steps)
        for req, i in zip(reqs, rows):
            req.transition(RequestState.RUNNING)
            self.active[req.uid] = req
            nfc = g.nf.get(i, 0)
            if nfc > 0:
                self._quarantine(req, "prefill", nfc)
            else:
                self._append_token(req, g.firsts[i])

    def _fake_quant_frag(self, frag):
        """Round-trip a fragment cache's sequence leaves through the int8
        resident-page numerics (per-token-row absmax/127, the same
        quantizer the paged pool applies on write) — the kv_int8
        degradation rung's cheaper operating point for fp pools."""
        def fq(path, leaf):
            if getattr(path[-1], "name", None) in _SEQ_LEAVES:
                flat = leaf.reshape(leaf.shape[:3] + (-1,)).astype(
                    jnp.float32)
                xq, sc = kops.quantize_activations(flat)
                deq = (xq.astype(jnp.float32) * sc).reshape(leaf.shape)
                return deq.astype(leaf.dtype)
            return leaf
        return jax.tree_util.tree_map_with_path(fq, frag)

    def _preempt_prefilling(self, req: Request, reason: str) -> None:
        """Preempt a mid-``PREFILLING`` request: drop its fragment
        progress and re-queue at the front.  No cache rollback — the
        batched slot row was never written (insertion happens only at
        completion), so the only state to unwind is the reservation.
        Caller must have cancelled it from its group first."""
        if self.telemetry is not None:
            self.telemetry.on_preempt([(req.uid, req.slot)], reason,
                                      self.engine_steps)
        req.transition(RequestState.PREEMPTED)
        req.preemptions += 1
        self.preemptions += 1
        self._release_pages(req)
        self.free.append(req.slot)
        req.slot = -1
        req.transition(RequestState.QUEUED)
        self.queue.push_front(req)

    def _admit_resume(self, req: Request) -> None:
        """Resume a preempted request into a free slot, bit-identically to
        an uninterrupted run: bucketed prefill of the ORIGINAL prompt
        (same op as its first admission), then a teacher-forced decode
        replay of the generated prefix at batch 1 — exactly the decode
        steps the uninterrupted run already executed, so the rebuilt slot
        cache and every subsequent token are bitwise reproductions.
        (Prefilling prompt+prefix instead would NOT be bitwise: the
        prefill path reduces attention with online softmax, decode does
        not.)  The replay reuses the engine's decode jit at a (1,) batch
        shape — one extra trace for the engine lifetime, independent of
        how many resumes run."""
        P, toks = req.prompt, req.tokens
        n = len(P)
        fill = n + len(toks) - 1
        tel = self.telemetry
        t0 = tel.now() if tel is not None else 0.0
        # Paged: reserve the resumed fill's pages BEFORE any replay work —
        # PoolExhausted must leave the request untouched (still QUEUED) so
        # _pump_queue can park it at the queue front.  Only whole prefix
        # pages are shared (exact_ok=False): the replayed decode writes
        # land strictly past them.
        plan = None
        if self._paged:
            plan = self._plan_pages(req, fill, exact_ok=False)
        bucket = self.bucketing.bucket_for(n)
        ta = np.zeros((1, bucket), np.int32)
        ta[0, :n] = P
        self.bucketing.record(1, bucket)
        n_j = jnp.asarray([n], jnp.int32)
        # replay must stay fault-free: injection targets engine steps, and
        # catch-up work re-executes history that already happened cleanly
        riv = None if self.faults is None else jnp.zeros((1,), jnp.float32)
        cache_b = api.make_cache(self.cfg, 1, self.max_len,
                                 dtype=self._cache_dtype)
        dcache_b = None
        self.sentinel.observe("prefill", (1, bucket))
        with self._mesh_scope():
            _, cache_b, _ = self._prefill(self.params, jnp.asarray(ta),
                                          cache_b, n_j)
            if bucket != n:
                # in-place equivalent of the masked insert's padding fix:
                # zero the padded K/V tail, pin the fill counter to n
                cache_b = self._rollback(cache_b, n_j)
            if self.spec is not None:
                dcache_b = api.make_cache(self.cfg, 1, self.max_len,
                                          dtype=self._cache_dtype)
                self.sentinel.observe("draft_prefill", (1, bucket))
                dcache_b = self._draft_prefill(self.draft_params,
                                               jnp.asarray(ta), dcache_b)
                if bucket != n:
                    dcache_b = self._rollback(dcache_b, n_j)
            for t in toks[:-1]:
                tok = jnp.asarray([t], jnp.int32)
                self.sentinel.observe("decode", (1, riv is not None))
                _, cache_b, _ = self._decode(self.params, tok, cache_b, riv)
                self._step_decode_calls += 1
                if self.spec is not None:
                    self.sentinel.observe("draft_decode", (1,))
                    _, dcache_b = self._draft_decode(self.draft_params, tok,
                                                     dcache_b)
                    self._step_draft_calls += 1
        self._step_prefill_tokens += bucket * (
            2 if self.spec is not None else 1)
        slot = self.free.pop(0)
        if self._paged:
            pages, row, wrow = plan
            self.cache = self._paged_insert(self.cache, cache_b, [slot],
                                            [fill], wrow[None])
            if self.spec is not None:
                self.draft_cache = self._paged_insert(
                    self.draft_cache, dcache_b, [slot], [fill], wrow[None])
            self._tables[slot] = row
            self._req_pages[req.uid] = list(pages)
            self._tables_dirty = True
            self._note_page_peaks(req)
        else:
            self.cache = _masked_group_insert(self.cache, cache_b, [slot],
                                              [fill], False)
            if self.spec is not None:
                self.draft_cache = _masked_group_insert(
                    self.draft_cache, dcache_b, [slot], [fill], False)
        self._repin_cache()
        req.slot = slot
        req.transition(RequestState.RUNNING)
        self.active[req.uid] = req
        self.last_token[slot] = toks[-1]
        self.resumes += 1
        if tel is not None:
            tel.on_resume(req.uid, slot, max(len(toks) - 1, 0),
                          tel.now() - t0, self.engine_steps)

    # -------------------------------------------------------------- lifecycle
    def _retire(self, req: Request, state: RequestState = RequestState.FINISHED,
                diagnostics: Optional[Dict[str, Any]] = None) -> None:
        """Move a request (active or queued) to `finished` in a terminal
        state and recycle its slot — the single retirement bookkeeping for
        budget/EOS, truncation, abandonment, and quarantine."""
        if diagnostics is not None:
            req.diagnostics = diagnostics
        if self.telemetry is not None:
            # before the transition/slot recycle: the event carries the
            # slot the request retired from (or -1 for queued work)
            self.telemetry.on_retire(req, state, self.engine_steps)
        req.transition(state)
        self._release_pages(req)
        if req.slot >= 0:
            self.free.append(req.slot)
            req.slot = -1
        self.active.pop(req.uid, None)
        self.finished[req.uid] = req
        self.state_counts[state.value] += 1

    def _quarantine(self, req: Request, phase: str, count: int) -> None:
        """Numeric-guard quarantine: FAIL only the offending request, with
        diagnostics, while the rest of the batch proceeds."""
        self._retire(req, RequestState.FAILED, diagnostics={
            "kind": "nonfinite_logits", "phase": phase,
            "nonfinite": count, "engine_step": self.engine_steps})

    def _append_token(self, req: Request, t: int) -> None:
        """Append a sampled token and apply retirement — the single place
        the max_new_tokens / EOS check lives, so the prefill-sampled first
        token is held to the same budget as decode-step tokens."""
        req.tokens.append(t)
        self.last_token[req.slot] = t
        if self.telemetry is not None:
            self.telemetry.on_token(req, self.engine_steps)
        if (len(req.tokens) >= req.max_new_tokens
                or (req.eos_id is not None and t == req.eos_id)):
            self._retire(req, RequestState.FINISHED)

    def set_cache_pressure(self, limit: Optional[int]) -> None:
        """Manually force an effective slot-cache limit below ``max_len``
        (None releases it).  Requests whose fill reaches the limit are
        preempted (or truncated under ``on_pressure="truncate"``) at the
        next step; the fault injector applies the same mechanism from its
        seeded pressure windows."""
        if limit is not None and limit < 2:
            raise ValueError(f"pressure limit must be >= 2, got {limit}")
        self._pressure_limit = limit

    def _effective_limit(self, step_idx: int) -> int:
        limit = self.max_len
        if self._pressure_limit is not None:
            limit = min(limit, self._pressure_limit)
        if self.faults is not None:
            fp = self.faults.pressure(step_idx, self.max_len)
            if fp is not None:
                limit = min(limit, fp)
        return limit

    def _victim_order(self) -> List[Request]:
        """Preemption order: lowest priority first, youngest (largest uid)
        within a priority — the cheapest work to redo."""
        return sorted(self.active.values(),
                      key=lambda r: (r.priority, -r.uid))

    def _preempt(self, victims: List[Request], reason: str) -> None:
        """Evict ``victims`` from their slots and re-queue them (front,
        bound-exempt) for a bit-identical resume.  The freed slots are
        cleared with ONE jitted masked rollback — victim lengths pinned to
        0 (K/V zeroed, fill rewound), surviving slots pinned at their true
        fill (a no-op for them) — reusing `_rollback_tail`'s leaf
        classification, so a recycled slot is indistinguishable from a
        never-used one."""
        if self.telemetry is not None:
            self.telemetry.on_preempt([(r.uid, r.slot) for r in victims],
                                      reason, self.engine_steps)
        for req in victims:
            req.transition(RequestState.PREEMPTED)
            req.preemptions += 1
            self.preemptions += 1
            del self.active[req.uid]
            self._release_pages(req)
            self.free.append(req.slot)
            req.slot = -1
        lens = np.zeros((self.n_slots,), np.int32)
        for r in self.active.values():
            lens[r.slot] = self._fill(r)
        lens_j = jnp.asarray(lens)
        with self._mesh_scope():
            self.cache = self._rollback(self.cache, lens_j)
            if self.spec is not None:
                self.draft_cache = self._rollback(self.draft_cache, lens_j)
        self._repin_cache()
        for req in victims:
            req.transition(RequestState.QUEUED)
            self.queue.push_front(req)

    def _alloc_decode_page(self, req: Request) -> int:
        """One fresh page for a running request's next K/V write: evict
        registry entries first, then preempt victims (policy and family
        permitting) until the pool yields a page; ``PoolExhausted``
        propagates when nothing preemptible remains."""
        while True:
            try:
                page = self._alloc_evicting(1)[0]
                self._note_page_peaks()
                return page
            except PoolExhausted:
                # kv_int8 admissions are excluded: preempting one would
                # force an fp prefix replay at resume, which cannot
                # reproduce the quantized cache history
                victims = [r for r in self._victim_order()
                           if r.uid != req.uid and not r.kv_int8]
                if (victims and self._preemptible
                        and self.on_pressure == "preempt"):
                    self._preempt(victims[:1], reason="pool_exhausted")
                    continue
                raise

    def _reserve_blocks(self, req: Request, horizon: int,
                        cow: List, fresh: List) -> None:
        """Make every table block the next ``horizon`` K/V writes of this
        request touch PRIVATE and allocated: scratch blocks get fresh
        pages (queued in ``fresh`` for zeroing), shared blocks (refcount >
        1) are replaced by private copies (queued in ``cow``) with the
        shared reference dropped — copy-on-write at the first write into
        shared territory.  Entries are uid-tagged: a preemption triggered
        by a LATER allocation may free and recycle pages queued earlier,
        and the caller filters stale entries by current ownership."""
        ps, scratch = self.page_size, self.allocator.scratch
        fill = self._fill(req)
        lo = fill // ps
        hi = min(fill + horizon - 1, self.max_len - 1) // ps
        s = req.slot
        pages = self._req_pages.setdefault(req.uid, [])
        for b in range(lo, hi + 1):
            pid = int(self._tables[s, b])
            if pid == scratch:
                new = self._alloc_decode_page(req)
                self._tables[s, b] = new
                pages.append(new)
                fresh.append((req.uid, new))
                self._tables_dirty = True
            elif self.allocator.refcount(pid) > 1:
                new = self._alloc_decode_page(req)
                self.allocator.free([pid])
                self._tables[s, b] = new
                pages[pages.index(pid)] = new
                cow.append((req.uid, pid, new))
                self.cow_copies += 1
                self._tables_dirty = True
        self._note_page_peaks(req)

    def _ensure_capacity(self, horizon: int) -> None:
        """Pre-step page reservation: every block the next ``horizon`` K/V
        writes touch must be private and allocated BEFORE the jit runs (the
        jit routes out-of-table writes to the scratch page — data loss, not
        corruption, but still loss).  Under exhaustion the starved request
        is retired TRUNCATED with diagnostics — typed, observable
        backpressure, never a silent clamp."""
        if not self._paged or not self.active:
            return
        cow: List = []
        fresh: List = []
        for uid in sorted(self.active):
            req = self.active.get(uid)
            if req is None:      # preempted by an earlier iteration's alloc
                continue
            try:
                self._reserve_blocks(req, horizon, cow, fresh)
            except PoolExhausted:
                self._retire(req, RequestState.TRUNCATED, diagnostics={
                    "kind": "pool_exhausted",
                    "pages_in_use": self.allocator.pages_in_use,
                    "n_pages": self.allocator.n_pages,
                    "engine_step": self.engine_steps})
        # a preemption mid-loop may have freed (and recycled) queued pages;
        # only zero/copy pages their planner still owns
        own = {u: set(p) for u, p in self._req_pages.items()}
        zs = [p for u, p in fresh if p in own.get(u, ())]
        pairs = [(o, n) for u, o, n in cow if n in own.get(u, ())]
        if zs:
            self._zero_pages(zs)
        if pairs:
            self._copy_pages(pairs)

    def _admissible(self, req: Request, limit: int) -> bool:
        """A queued request may take a slot only if its (prospective) fill
        sits below the effective cache limit — admitting it under pressure
        would just preempt it right back (admission churn)."""
        fill = len(req.prompt) + max(len(req.tokens), 1) - 1
        return fill < limit

    def pump(self) -> None:
        """Lifecycle housekeeping without decoding: abandon deadline-expired
        work (queued AND running — partial tokens are kept), apply cache
        pressure (preempt, or truncate under the opt-in policy), then fill
        free slots from the queue — resumed work first, then fresh work,
        highest priority first; strictly-higher-priority queued work may
        preempt the lowest-priority/youngest running victim.  ``step()``
        calls this first, so a driver that only ever calls ``step()``
        still drives every request to a terminal state."""
        step_idx = self.engine_steps
        now = self._clock()
        for req in list(self.active.values()):
            if req.deadline is not None and now >= req.deadline:
                self._retire(req, RequestState.ABANDONED, diagnostics={
                    "kind": "deadline", "where": "running",
                    "engine_step": step_idx})
        for g in self._prefill_groups:
            for i in g.live_rows():
                req = g.reqs[i]
                if req.deadline is not None and now >= req.deadline:
                    g.cancel(req.uid)
                    self._retire(req, RequestState.ABANDONED, diagnostics={
                        "kind": "deadline", "where": "prefilling",
                        "engine_step": step_idx})
        limit = self._effective_limit(step_idx)
        # cache pressure reaches mid-PREFILLING work too: a prompt that no
        # longer fits under the effective limit is cancelled from its group
        # (preempt-to-queue when policy and numerics allow — free, since
        # the slot row was never written — else typed truncation)
        for g in self._prefill_groups:
            for i in g.live_rows():
                req = g.reqs[i]
                if len(req.prompt) >= limit:
                    g.cancel(req.uid)
                    if (self.on_pressure == "preempt" and self._preemptible
                            and not req.kv_int8):
                        self._preempt_prefilling(req, "cache_pressure")
                    else:
                        self._retire(req, RequestState.TRUNCATED,
                                     diagnostics={
                                         "kind": "cache_pressure",
                                         "limit": limit,
                                         "engine_step": step_idx})
        victims: List[Request] = []
        for req in self._victim_order():
            fill = self._fill(req)
            if fill >= self.max_len:
                # the slot cache is genuinely full before the budget
                # (mutated mid-flight): resume is physically impossible
                # (the replayed prefix itself would not fit), so this is
                # terminal truncation regardless of policy
                self._retire(req, RequestState.TRUNCATED)
            elif fill >= limit:
                if (self.on_pressure == "preempt" and self._preemptible
                        and not req.kv_int8):
                    victims.append(req)
                else:
                    # kv_int8 admissions are non-resumable (an fp prefix
                    # replay cannot reproduce the quantized cache history),
                    # so pressure retires them like the truncate policy
                    self._retire(req, RequestState.TRUNCATED, diagnostics={
                        "kind": "cache_pressure", "limit": limit,
                        "engine_step": step_idx})
        if victims:
            self._preempt(victims, reason="cache_pressure")
        if self.controller is not None:
            # the controller decides BEFORE admission: rung moves and
            # shedding apply to the queue this pump is about to drain
            self.controller.on_step(self)
        self._pump_queue(now, limit)

    def _pump_queue(self, now: float, limit: int) -> None:
        # deadline-based abandonment of queued work
        for req in self.queue.expire(now):
            self._retire(req, RequestState.ABANDONED, diagnostics={
                "kind": "deadline", "where": "queued",
                "engine_step": self.engine_steps})
        # strictly-higher-priority queued work evicts the lowest-priority/
        # youngest running request when no slot is free
        while (len(self.queue) and not self.free and self._preemptible
               and self.on_pressure == "preempt"):
            best = self.queue.peek_best(lambda r: self._admissible(r, limit))
            victims = [r for r in self._victim_order() if not r.kv_int8]
            if (best is None or not victims
                    or best.priority <= victims[0].priority):
                break
            self._preempt([victims[0]], reason="priority")
        # admit: resumed requests one by one (each replays its own prefix),
        # fresh requests collected and admitted in one bucketed batch.
        # Under controller deferral only resumed work passes (its slot
        # debt already exists; deferring it would strand generated tokens).
        allow_fresh = (self.controller.allow_fresh(self)
                       if self.controller is not None else True)
        fresh: List[Request] = []
        while len(self.free) - len(fresh) > 0:
            req = self.queue.pop_best(
                lambda r: self._admissible(r, limit)
                and (allow_fresh or r.tokens))
            if req is None:
                break
            if req.tokens:
                try:
                    self._admit_resume(req)
                except PoolExhausted:
                    # page-pool backpressure: the resume waits its turn at
                    # the queue front; pages drain as running work retires
                    self.queue.push_front(req)
                    break
            else:
                fresh.append(req)
        if fresh:
            try:
                self._admit(fresh)
            except PoolExhausted:
                for r in reversed(fresh):
                    self.queue.push_front(r)
        if self.controller is not None and not allow_fresh and self.free:
            blocked = sum(1 for r in self.queue.requests()
                          if not r.tokens and self._admissible(r, limit))
            if blocked:
                self.controller.note_defer(self, blocked)

    def _tick(self) -> None:
        """Per-step lifecycle prologue.  A planned transient fault raises
        BEFORE any state mutation, so a driver's retry of ``step()`` is
        idempotent."""
        if (self.faults is not None
                and self.faults.should_fail_step(self.engine_steps)):
            raise EngineFault(
                f"injected transient step failure at engine step "
                f"{self.engine_steps}", transient=True, diagnostics={
                    "kind": "transient_step_failure",
                    "engine_step": self.engine_steps})
        self.pump()

    def _inject_vec(self):
        """The fault injector's additive per-slot logit vector for this
        step (zeros outside planned faults), or None when no injector is
        wired — the jit signature is stable per engine configuration."""
        if self.faults is None:
            return None
        occupied = sorted(r.slot for r in self.active.values())
        return jnp.asarray(self.faults.inject_vector(
            self.engine_steps, self.n_slots, occupied))

    # ------------------------------------------------------------------- step
    def step(self) -> Dict[int, Any]:
        """One engine step for all active slots.

        Runs the lifecycle prologue first (deadlines, cache pressure,
        queue admission — see ``pump()``); a planned transient fault
        raises ``EngineFault(transient=True)`` before any mutation.

        Vanilla: one batched decode, returns ``{uid: new_token}``.  With
        speculation (``spec=``): one propose/verify/rollback window,
        returns ``{uid: [tokens]}`` — between 1 and gamma+1 tokens per
        still-active request, every one of them exactly what vanilla
        greedy decode would have emitted (greedy speculation is
        lossless).  Quarantined (guard-failed) requests emit nothing and
        are absent from the returned dict — drain them via
        ``take_finished()``.

        With a ``cost_model``, the step's deterministic virtual cost is
        published as ``last_step_cost_ms`` (the replayer advances its
        ``StepClock`` by it, so chunking actually buys tail latency
        under virtual time instead of being free)."""
        self._step_prefill_tokens = 0
        self._step_decode_calls = 0
        self._step_draft_calls = 0
        self._step_verify_tokens = 0
        out = self._step_inner()
        if self.cost_model is not None:
            self.last_step_cost_ms = self.cost_model.cost_ms(
                prefill_tokens=self._step_prefill_tokens,
                decode_calls=self._step_decode_calls,
                draft_calls=self._step_draft_calls,
                verify_tokens=self._step_verify_tokens)
        return out

    def _step_inner(self) -> Dict[int, Any]:
        self._tick()
        if self.chunked is not None:
            # interleave pending prefill chunks BEFORE decode: completed
            # groups join `active` and decode this very step
            self._process_chunks()
        tel = self.telemetry
        if tel is not None:
            # per-step occupancy gauges (same-step samples overwrite, so
            # an idle driver loop cannot grow the series)
            tel.sample("queue_depth", self.engine_steps, len(self.queue))
            tel.sample("active_slots", self.engine_steps, len(self.active))
            if self._paged:
                tel.sample("pages_in_use", self.engine_steps,
                           self.allocator.pages_in_use)
        if not self.active:
            if len(self.queue) or self._prefill_groups:
                # idle step with pending work: step-indexed fault plans
                # (pressure windows, planned failures) must still elapse,
                # or queued-but-inadmissible work would livelock
                self.engine_steps += 1
            return {}
        if self._paged:
            # reserve (zeroed, private) pages for every K/V write this
            # step will issue — one for vanilla decode, the whole window
            # for speculation — then push the dirty table mirror
            self._ensure_capacity(
                self._gamma_eff + 1
                if self.spec is not None and self._spec_enabled else 1)
            if not self.active:
                if len(self.queue) or self._prefill_groups:
                    self.engine_steps += 1
                return {}
            self._sync_tables()
        if self.spec is not None and self._spec_enabled:
            return self._spec_step()
        step_idx = self.engine_steps
        slot_of = {uid: r.slot for uid, r in self.active.items()}
        t0 = tel.now() if tel is not None else 0.0
        toks = jnp.asarray(self.last_token, jnp.int32)
        iv = self._inject_vec()
        self.sentinel.observe("decode", (self.n_slots, iv is not None))
        with self._mesh_scope():
            logits, self.cache, nf = self._decode(self.params, toks,
                                                  self.cache, iv)
            if self.spec is not None:
                # spec-off degradation rung keep-warm: advance the draft
                # cache with the SAME token so both caches stay uniformly
                # filled and re-enabling speculation is seamless
                self.sentinel.observe("draft_decode", (self.n_slots,))
                _, self.draft_cache = self._draft_decode(
                    self.draft_params, toks, self.draft_cache)
                self._step_draft_calls += 1
        self._step_decode_calls += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        nf_h = np.asarray(nf) if nf is not None else None
        emitted = {}
        for uid, req in list(self.active.items()):
            s = req.slot
            if nf_h is not None and nf_h[s] > 0:
                # non-finite row: quarantine ONLY this request; the other
                # rows of the same batched decode are unaffected
                self._quarantine(req, "decode", int(nf_h[s]))
                continue
            t = int(nxt[s])
            emitted[uid] = t
            self._append_token(req, t)
        self.engine_steps += 1
        self.emitted_tokens += len(emitted)
        if tel is not None:
            tel.on_step("decode", {u: 1 for u in emitted}, slot_of,
                        tel.now() - t0, step_idx)
        return emitted

    def _spec_step(self) -> Dict[int, List[int]]:
        """One speculation window: γ+1 draft decode steps (the last one
        write-only, so both caches advance uniformly to fill+γ+1), ONE
        target span verify, greedy acceptance, then a batched per-slot
        rollback of both caches to fill+accepted.  Retirement (EOS /
        max_new_tokens / cache-full) applies token by token in emission
        order, so a request retires at exactly the token vanilla decode
        would have retired it at.  A non-finite verify row (guards on)
        emits nothing: the slot rolls back to empty and the request is
        quarantined — rollback, then quarantine."""
        if not self.active:
            return {}
        # γ_eff: the controller's spec_half rung halves the window without
        # re-tracing (verify is compiled per distinct γ at build time)
        gamma = self._gamma_eff
        tel = self.telemetry
        step_idx = self.engine_steps
        slot_of = {uid: r.slot for uid, r in self.active.items()}
        accepted_ks: List[int] = []
        t0 = tel.now() if tel is not None else 0.0
        # per-slot fill BEFORE the window: prompt + appended tokens minus
        # the pending last_token (whose K/V the window itself writes)
        base_fill = {uid: self._fill(r) for uid, r in self.active.items()}

        cur = jnp.asarray(self.last_token, jnp.int32)
        d_cols = []                                     # device-resident
        iv = self._inject_vec()
        with self._mesh_scope():
            for j in range(gamma):
                self.sentinel.observe("draft_decode", (self.n_slots,))
                dlogits, self.draft_cache = self._draft_decode(
                    self.draft_params, cur, self.draft_cache)
                cur = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)
                d_cols.append(cur)
            # write-only catch-up: feed d_γ so the draft cache holds it if
            # the whole window is accepted (logits discarded).  The whole
            # propose chain stays on device — no host sync until the
            # verify logits are read below.
            self.sentinel.observe("draft_decode", (self.n_slots,))
            _, self.draft_cache = self._draft_decode(
                self.draft_params, cur, self.draft_cache)
            drafts_j = jnp.stack(d_cols, axis=1)        # (n_slots, γ)
            span = jnp.concatenate(
                [jnp.asarray(self.last_token, jnp.int32)[:, None],
                 drafts_j], axis=1)                     # (n_slots, γ+1)
            self.sentinel.observe(
                "verify", (self.n_slots, gamma + 1, iv is not None))
            vlogits, self.cache, nf = self._verify(self.params, span,
                                                   self.cache, iv)
        self._step_draft_calls += gamma + 1
        self._step_verify_tokens += gamma + 1
        drafts = np.asarray(drafts_j)
        greedy = np.asarray(jnp.argmax(vlogits, axis=-1), np.int32)
        nf_h = np.asarray(nf) if nf is not None else None

        emitted: Dict[int, List[int]] = {}
        lens = np.zeros((self.n_slots,), np.int32)   # 0 = free/retired slot
        for uid, req in list(self.active.items()):
            s = req.slot
            if nf_h is not None and nf_h[s] > 0:
                # mid-window quarantine: no token from this window can be
                # trusted, so emit nothing; lens[s]=0 makes the rollback
                # below clear the slot entirely before it is recycled
                self._quarantine(req, "verify", int(nf_h[s]))
                lens[s] = 0
                continue
            k, toks = speculative.accept_greedy(drafts[s], greedy[s])
            appended: List[int] = []
            for t in toks:
                if self._fill(req) >= self.max_len:
                    # the slot cache is full before the budget (mutated
                    # mid-flight) — later span rows fall past the cache
                    # end, so stop at exactly the token vanilla would
                    self._retire(req, RequestState.TRUNCATED)
                    break
                self._append_token(req, t)
                appended.append(t)
                if req.done:
                    break
            emitted[uid] = appended
            self.spec_drafted += gamma
            self.spec_accepted += k
            self.emitted_tokens += len(appended)
            accepted_ks.append(k)
            lens[s] = 0 if req.done else base_fill[uid] + len(appended)
        self.engine_steps += 1

        lens_j = jnp.asarray(lens)
        with self._mesh_scope():
            self.cache = self._rollback(self.cache, lens_j)
            self.draft_cache = self._rollback(self.draft_cache, lens_j)
        self._repin_cache()
        if tel is not None:
            tel.on_step("spec", {u: len(v) for u, v in emitted.items()},
                        slot_of, tel.now() - t0, step_idx,
                        window=speculative.window_summary(gamma,
                                                          accepted_ks))
            for k in accepted_ks:
                tel.registry.histogram(
                    "spec_accepted_per_window", lo=0.5,
                    hi=float(max(gamma + 1, 2)), per_decade=16).observe(k)
        return emitted

    def run_to_completion(self, max_steps: int = 256, strict: bool = True,
                          retry: Optional[RetryPolicy] = None) -> List[int]:
        """Step until every submitted request reaches a terminal state
        (the queue drains through ``pump()`` inside ``step()``).  Returns
        the uids still in flight when max_steps runs out ([] == all
        finished); with strict=True (default) exhausting max_steps raises
        ``IncompleteRun`` carrying the partial outputs and lifecycle
        states of every unfinished request, so a truncated run cannot be
        mistaken for completion AND already-generated work survives the
        error.  ``retry=RetryPolicy(...)`` absorbs transient
        ``EngineFault``s (bounded attempts, backoff); without it they
        propagate."""
        consecutive_faults = 0
        steps = 0
        while steps < max_steps:
            if (not self.active and not len(self.queue)
                    and not self._prefill_groups):
                return []
            try:
                self.step()
            except EngineFault as e:
                if retry is None or not e.transient:
                    raise
                consecutive_faults += 1
                if consecutive_faults >= retry.max_attempts:
                    raise
                backoff = (retry.backoff_s
                           * retry.multiplier ** (consecutive_faults - 1))
                if backoff > 0:
                    retry.sleep(backoff)
                continue
            consecutive_faults = 0
            steps += 1
        prefilling = [r for g in self._prefill_groups for r in g.live()]
        unfinished = sorted(set(self.active) | set(self.queue.uids())
                            | {r.uid for r in prefilling})
        if unfinished and strict:
            reqs = dict(self.active)
            reqs.update({r.uid: r for r in self.queue.requests()})
            reqs.update({r.uid: r for r in prefilling})
            raise IncompleteRun(
                f"run_to_completion: max_steps={max_steps} exhausted with "
                f"{len(unfinished)} requests not terminal (uids "
                f"{unfinished}); partial outputs and lifecycle states "
                f"attached to this error",
                partial={u: list(reqs[u].tokens) for u in unfinished},
                states={u: reqs[u].state for u in unfinished})
        return unfinished

    # ------------------------------------------------------------------ stats
    def take_finished(self) -> Dict[int, Request]:
        """Drain and return retired requests (bounds engine memory)."""
        out, self.finished = self.finished, {}
        return out

    def stats(self) -> Dict[str, Any]:
        s = self.bucketing.stats
        out = {
            "prefill_traces": self.prefill_traces,
            "decode_traces": self.decode_traces,
            "buckets": list(self.bucketing.buckets()),
            "bucket_hits": s.hits,
            "bucket_misses": s.misses,
            "bucket_hit_rate": s.hit_rate,
            "mesh": dict(self.mesh.shape) if self.mesh is not None else None,
            "act_dtype": self.act_dtype or "f32",
            # decode-loop emission: tokens appended by step() over engine
            # steps (decode steps vanilla; speculation windows with spec)
            "emitted_tokens": self.emitted_tokens,
            "engine_steps": self.engine_steps,
            "tokens_per_step": (self.emitted_tokens / self.engine_steps
                                if self.engine_steps else 0.0),
            # lifecycle: queue + terminal-state + preemption accounting
            "queued": len(self.queue),
            "queue_depth": self.queue.depth,
            "queue_peak_depth": self.queue.peak_depth,
            "guards": self.guards,
            "on_pressure": self.on_pressure,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "admission_rejections": self.admission_rejections,
            "lifecycle": {st.value: self.state_counts.get(st.value, 0)
                          for st in sorted(TERMINAL_STATES,
                                           key=lambda s: s.value)},
        }
        if self._paged:
            # HBM accounting straight off the live pool leaves: bytes per
            # page (all layers, pools + scales) x pool occupancy, next to
            # what the contiguous fp layout would have pinned per slot.
            ps = self.page_size
            per_page = 0
            per_tok_fp = 0
            fp_size = jnp.zeros((), self._cache_dtype).dtype.itemsize
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    self.cache)[0]:
                name = getattr(path[-1], "name", None)
                if name in _POOL_SRC:
                    feat = int(np.prod(leaf.shape[3:])) if leaf.ndim > 3 else 1
                    per_page += (leaf.shape[0] * ps * feat
                                 * leaf.dtype.itemsize)
                    per_tok_fp += leaf.shape[0] * feat * fp_size
                elif name in _SCALE_SRC:
                    per_page += leaf.shape[0] * ps * leaf.dtype.itemsize
            out["paged"] = {
                "page_size": ps,
                "n_pages": self.allocator.n_pages,
                "pages_in_use": self.allocator.pages_in_use,
                "pages_free": self.allocator.n_free,
                "pool_utilization": (self.allocator.pages_in_use
                                     / self.allocator.n_pages),
                "peak_pages_in_use": self.peak_pages_in_use,
                "peak_pages_per_request": self.peak_pages_per_request,
                "kv_dtype": self.kv_dtype or str(self._cache_dtype),
                "bytes_per_page": per_page,
                "bytes_resident": self.allocator.pages_in_use * per_page,
                "bytes_pool": self.allocator.n_pages * per_page,
                "bytes_contiguous_fp": (self.n_slots * self.max_len
                                        * per_tok_fp),
                "prefix_hits": self.prefix_hits,
                "prefix_shared_tokens": self.prefix_shared_tokens,
                "cow_copies": self.cow_copies,
                "page_evictions": self.page_evictions,
                "pages_allocated_total": self.allocator.pages_allocated_total,
                "pages_freed_total": self.allocator.pages_freed_total,
                "registry_entries": (len(self.prefix_registry)
                                     if self.prefix_registry is not None
                                     else 0),
            }
        if self.spec is not None:
            out.update({
                "spec_gamma": self.spec.gamma,
                "spec_gamma_eff": self._gamma_eff,
                "spec_enabled": self._spec_enabled,
                "spec_drafted": self.spec_drafted,
                "spec_accepted": self.spec_accepted,
                # fraction of proposed draft tokens the target kept
                "acceptance_rate": (self.spec_accepted / self.spec_drafted
                                    if self.spec_drafted else 0.0),
                "draft_prefill_traces": self.draft_prefill_traces,
                "draft_decode_traces": self.draft_decode_traces,
                "verify_traces": self.verify_traces,
            })
        if self.chunked is not None:
            out["chunked"] = {
                "chunk_tokens": self.chunked.chunk_tokens,
                "budget_tokens": self.chunked.budget_tokens,
                "chunk_prefill_traces": self.chunk_prefill_traces,
                "draft_chunk_prefill_traces": self.draft_chunk_prefill_traces,
                "chunks_processed": self.chunks_processed,
                "groups_pending": len(self._prefill_groups),
                "prefilling": self.pending_prefills,
            }
        if self.last_step_cost_ms is not None:
            out["last_step_cost_ms"] = self.last_step_cost_ms
        if self.controller is not None:
            out["controller"] = self.controller.stats()
        return out

    def reset_peaks(self) -> None:
        """Drop high-water marks (queue depth, page peaks) to CURRENT
        occupancy.  Back-to-back A/B replays share one process; without
        this the second run's report inherits the first run's peaks."""
        self.queue.reset_peaks()
        if self._paged:
            self.peak_pages_in_use = self.allocator.pages_in_use
            self.peak_pages_per_request = max(
                (len(p) for p in self._req_pages.values()), default=0)

    def metrics(self) -> MetricsRegistry:
        """The ONE uniform metrics surface: every ``stats()`` number —
        spec counters, paged byte ladder, lifecycle tallies — projected
        onto the telemetry registry as ``serve.*`` gauges (joining the
        span-derived histograms/timelines when a recorder is attached).
        ``launch/serve.py --stats`` renders this."""
        reg = (self.telemetry.registry if self.telemetry is not None
               else MetricsRegistry())
        return registry_from_stats(self.stats(), reg)
