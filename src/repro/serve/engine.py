"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch, with per-request prefill inserted into free slots.

Weights may be dense or CLAQ-quantized (QuantizedTensor leaves) — the model
dispatches per leaf, so the same engine serves fp and 2/3/4-bit models.

Flow: add_request() prefills (batch-1, bucketed lengths to bound compiles)
and writes the per-layer cache fragment into a free slot of the batched
cache; step() decodes every active slot in one batched serve_step, emits
one token per active request, and retires finished ones.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.plan import prepare_tree
from repro.models import api

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg, n_slots: int = 8, max_len: int = 1024,
                 dtype=jnp.float32, prepare: bool = True):
        # Compile every QuantizedTensor leaf into its ahead-of-time
        # inference plan ONCE; the prepared leaves then flow through the
        # jitted steps with zero per-trace layout work and one kernel
        # launch per distinct stripe bit-width.
        self.params = prepare_tree(params) if prepare else params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = api.make_cache(cfg, n_slots, max_len, dtype=dtype)
        self.free = list(range(n_slots))
        self.active: Dict[int, Request] = {}
        self.last_token = np.zeros((n_slots,), np.int32)
        self._uid = 0

        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, cfg, t, c))
        # One stable jitted prefill: repeated admissions at the same
        # bucketed prompt length hit the compile cache instead of
        # re-tracing through a fresh lambda per request.
        self._prefill = jax.jit(
            lambda p, t, c: api.prefill_step(p, cfg, {"tokens": t}, c))

    # ------------------------------------------------------------------ admit
    def add_request(self, prompt: List[int], max_new_tokens: int = 16,
                    eos_id: Optional[int] = None) -> int:
        if not self.free:
            raise RuntimeError("no free slots")
        slot = self.free.pop(0)
        req = Request(self._uid, list(prompt), max_new_tokens, eos_id,
                      slot=slot)
        self._uid += 1

        n = len(prompt)
        cache1 = api.make_cache(self.cfg, 1, self.max_len,
                                dtype=jax.tree_util.tree_leaves(self.cache)[0].dtype)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill(self.params, toks, cache1)
        first = int(jnp.argmax(logits[0]))
        req.tokens.append(first)
        self.last_token[slot] = first

        # insert the fragment into the batched cache at `slot`
        def insert(full, frag):
            if frag.ndim == 1:          # per-slot scalars, e.g. enc_len
                return full.at[slot].set(frag[0])
            return full.at[:, slot].set(frag[:, 0])

        self.cache = jax.tree_util.tree_map(insert, self.cache, cache1)
        self.active[req.uid] = req
        return req.uid

    # ------------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One decode step for all active slots; returns {uid: new_token}."""
        if not self.active:
            return {}
        toks = jnp.asarray(self.last_token, jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        emitted = {}
        for uid, req in list(self.active.items()):
            t = int(nxt[req.slot])
            req.tokens.append(t)
            self.last_token[req.slot] = t
            emitted[uid] = t
            if (len(req.tokens) >= req.max_new_tokens
                    or (req.eos_id is not None and t == req.eos_id)):
                req.done = True
                self.free.append(req.slot)
                del self.active[uid]
        return emitted

    def run_to_completion(self, max_steps: int = 256) -> None:
        for _ in range(max_steps):
            if not self.active:
                break
            self.step()
