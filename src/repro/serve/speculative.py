"""Self-speculative decoding: a low-bit CLAQ draft proposes, the high-bit
target verifies — lossless, from ONE checkpoint and ONE calibration pass.

CLAQ's premise is that extreme low-bit models stay usable; quantizing the
same fp weights twice from the same tapped Hessians
(`launch.quantize.claq_quantize_with_draft`) therefore yields a free
draft/target pair whose distributions track each other closely — exactly
the regime where speculative decoding pays.  Greedy speculation is
mathematically lossless: every emitted token is the TARGET's greedy
continuation of the previously emitted tokens, regardless of draft
quality (the draft only sets how many tokens one verify call retires).

Window protocol (γ = SpecConfig.gamma, per engine step):

  propose   γ+1 draft decode steps — feed last_token, then each proposed
            token; the final step is write-only (it advances the draft
            cache past d_γ so both caches end the window at fill+γ+1 and
            one rollback length serves both).
  verify    ONE target span decode over [last_token, d_1..d_γ]
            (`models.api.decode_span`, bitwise γ+1 successive decodes).
  accept    per slot: longest prefix with d_i == g_i (g = target greedy
            from the verify logits), then the target's correction token
            g_{k+1} — between 1 and γ+1 tokens per window.
  rollback  both caches rewind to fill + accepted (masked K/V tail
            zeroing + fill-counter rewind, `engine._rollback_tail`).
            Paged caches rewind by fill counter alone — page tables and
            pool rows are untouched (the rejected tail's rows stay in
            their pages, hidden by the mask and overwritten by the next
            window), which is why the same rollback jit serves both
            layouts.

Quarantine inside a window (engine ``guards=True``): a non-finite verify
row means NO token of that window can be trusted for that slot — the
accept phase is skipped for the row, its rollback length is set to 0 (the
slot is cleared, not rewound), and the request is retired FAILED with
diagnostics; the other rows of the same window accept and roll back
normally.  Rollback first, then quarantine — the cleared slot is
indistinguishable from a free one when it is recycled.

Every phase has a FIXED operand shape — (n_slots,) draft steps,
(n_slots, γ+1) verify, whole-cache rollback with traced lengths — so
speculation adds a constant number of XLA traces (draft decode, verify,
rollback, plus the draft's bucketed prefill) independent of how many
windows run.  DESIGN.md §8 records the invariants.

Only families whose caches are position-indexed and fill-masked can roll
back a rejected window; `validate_spec_support` gates the rest out.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.models import api as model_api


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculation knobs: window length and the draft's code bit-width
    (the latter consumed by the quantization side — see
    `launch.quantize.claq_quantize_with_draft` / `core.draft_config`)."""
    gamma: int = 4
    draft_bits: int = 2

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")
        if self.draft_bits < 1:
            raise ValueError(
                f"draft_bits must be >= 1, got {self.draft_bits}")


def validate_spec_support(cfg) -> None:
    """Reject configs that cannot serve as a speculation target.

    Delegates to the models layer's ``validate_span_support`` — the
    single source of truth shared with the `decode_span` primitive, so
    the engine gate and the model capability can never drift.  The gated
    properties mirror the bucketing family gates (DESIGN.md §5): the
    same cache structure that makes right-padding safe (position-indexed
    storage, fill-counter masking) is what makes a rejected speculation
    window reversible.
    """
    model_api.validate_span_support(cfg)


def accept_greedy(draft: Sequence[int],
                  target: Sequence[int]) -> Tuple[int, List[int]]:
    """Greedy acceptance for one slot.

    ``draft``: the γ proposed tokens d_1..d_γ.  ``target``: the γ+1
    target-greedy tokens from the verify logits (g_i = argmax after the
    history ending in d_i; g_0 after last_token).  Returns
    ``(n_accepted, emitted)`` where emitted = the accepted prefix plus the
    target's correction/bonus token — each emitted token is exactly what
    vanilla greedy decode would have produced (lossless)."""
    gamma = len(draft)
    if len(target) != gamma + 1:
        raise ValueError(
            f"verify returned {len(target)} tokens for gamma={gamma}")
    k = 0
    while k < gamma and int(draft[k]) == int(target[k]):
        k += 1
    return k, [int(t) for t in draft[:k]] + [int(target[k])]


def window_summary(gamma: int, accepted: Sequence[int]) -> dict:
    """Aggregate one speculation window's per-slot acceptance counts for
    the telemetry `step` event: proposed/accepted totals, the window's
    acceptance rate, and the full-window count (slots that kept all γ
    draft tokens).  Pure arithmetic — host-side, JSON-able."""
    acc = [int(k) for k in accepted]
    proposed = gamma * len(acc)
    return {
        "gamma": gamma,
        "slots": len(acc),
        "proposed": proposed,
        "accepted": sum(acc),
        "accept_rate": (sum(acc) / proposed) if proposed else 0.0,
        "full_windows": sum(1 for k in acc if k == gamma),
    }
