"""Seeded, deterministic fault injection for the serving engine.

A robustness claim is only testable if the faults are reproducible: a
``FaultInjector`` derives its entire fault plan from one seed at
construction time, so the same seed plus the same workload replays the
same outcomes bit-for-bit (asserted in tests/test_lifecycle.py).  Four
fault families, mirroring what low-bit serving actually meets in
production:

  * **non-finite logits** — NaN/Inf injected into the decode (or
    speculative-verify) logits of one occupied slot at a planned engine
    step.  Injection rides a traced ``(n_slots,)`` operand ADDED to the
    logits INSIDE the jitted step, so the engine's ``--guards`` finite
    check (also folded into the jit) sees injected faults exactly as it
    would see a genuine 2-bit-layer blowup — and the operand never mints
    a retrace.
  * **cache pressure** — windows of engine steps during which the
    effective slot-cache limit drops below ``max_len``, forcing the
    engine's preemption (or opt-in truncation) path.
  * **transient step failures** — planned ``step()`` calls raise a
    transient ``EngineFault`` BEFORE any state mutation (so a retry is
    idempotent); each planned step fails a bounded number of consecutive
    attempts and then succeeds, which is what a bounded-retry driver
    must survive.
  * **bursty arrivals** — a Poisson arrival process with periodic bursts
    layered on top, consumed by the load driver (benchmarks/serve_bench
    robustness scenario) to exercise admission backpressure and
    deadline abandonment.

``nonfinite_rows`` is the numeric guard itself: one ``jnp.isfinite``
all-reduce over the trailing axes, returning a per-slot non-finite count
the engine reads alongside the sampled tokens — a non-finite row
quarantines only the offending request while the rest of the batch
proceeds.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def nonfinite_rows(logits):
    """Per-slot count of non-finite logit entries, reduced over every
    axis but the batch — one cheap all-reduce folded into the decode /
    verify jit when the engine runs with ``guards=True``.  Shape (B,)
    int32; a zero row is clean, a positive row quarantines its request."""
    axes = tuple(range(1, logits.ndim))
    return jnp.sum(jnp.logical_not(jnp.isfinite(logits)),
                   axis=axes).astype(jnp.int32)


class FaultInjector:
    """One seed -> one immutable fault plan (see module docstring).

    ``horizon`` bounds the engine-step indices faults are planned at; the
    plan is fixed at construction, so two injectors with equal arguments
    behave identically.  The only mutable state is the per-step attempt
    counter behind ``should_fail_step`` (bounded consecutive failures);
    ``reset()`` rewinds it for an exact replay.
    """

    def __init__(self, seed: int = 0, horizon: int = 64,
                 nan_faults: int = 1, inf_faults: int = 1,
                 pressure_windows: int = 1,
                 pressure_len: Tuple[int, int] = (3, 8),
                 pressure_frac: Tuple[float, float] = (0.25, 0.5),
                 transient_failures: int = 2,
                 max_consecutive_failures: int = 2,
                 arrival_lambda: float = 0.6,
                 burst_every: int = 12, burst_size: int = 3):
        if horizon < 8:
            raise ValueError(f"horizon must be >= 8, got {horizon}")
        self.seed = seed
        self.horizon = horizon
        rng = np.random.default_rng(seed)
        span = np.arange(2, horizon)

        # non-finite logit injections: step -> [(slot_hint, kind)]
        kinds = ["nan"] * nan_faults + ["inf"] * inf_faults
        steps = rng.choice(span, size=min(len(kinds), len(span)),
                           replace=False)
        self.logit_faults: Dict[int, List[Tuple[int, str]]] = {}
        for step, kind in zip(steps, kinds):
            self.logit_faults.setdefault(int(step), []).append(
                (int(rng.integers(0, 1 << 16)), kind))

        # cache-pressure windows: (start, end, frac of max_len)
        self.pressure_spans: List[Tuple[int, int, float]] = []
        for _ in range(pressure_windows):
            start = int(rng.integers(4, max(5, horizon - 8)))
            length = int(rng.integers(pressure_len[0], pressure_len[1] + 1))
            frac = float(rng.uniform(*pressure_frac))
            self.pressure_spans.append((start, start + length, frac))

        # transient step failures: step -> consecutive attempts that fail
        fsteps = rng.choice(span, size=min(transient_failures, len(span)),
                            replace=False)
        self.fail_steps: Dict[int, int] = {
            int(s): int(rng.integers(1, max_consecutive_failures + 1))
            for s in fsteps}

        # bursty Poisson arrivals per driver step
        counts = rng.poisson(arrival_lambda, size=horizon)
        if burst_every > 0:
            for s in range(0, horizon, burst_every):
                counts[s] += burst_size
        self.arrival_counts: Dict[int, int] = {
            i: int(c) for i, c in enumerate(counts) if c > 0}

        self._fail_attempts: Dict[int, int] = {}

    # ------------------------------------------------------------- consumers
    def should_fail_step(self, step: int) -> bool:
        """True while engine step ``step`` has planned failures left; each
        call consumes one attempt, so a bounded retry eventually passes
        (transient by construction)."""
        planned = self.fail_steps.get(step, 0)
        if planned == 0:
            return False
        seen = self._fail_attempts.get(step, 0)
        self._fail_attempts[step] = seen + 1
        return seen < planned

    def inject_vector(self, step: int, n_slots: int,
                      occupied: Sequence[int] = ()) -> np.ndarray:
        """(n_slots,) f32 additive fault vector for this step's logits:
        zeros normally; NaN/Inf at one OCCUPIED slot per planned fault
        (the hint picks deterministically among occupied slots, so a
        planned fault always lands on a live request when one exists)."""
        vec = np.zeros((n_slots,), np.float32)
        for hint, kind in self.logit_faults.get(step, ()):
            if not occupied:
                continue
            slot = occupied[hint % len(occupied)]
            vec[slot] = np.nan if kind == "nan" else np.inf
        return vec

    def pressure(self, step: int, max_len: int) -> Optional[int]:
        """Effective slot-cache limit at this step (< max_len inside a
        pressure window), or None when no window is active."""
        for start, end, frac in self.pressure_spans:
            if start <= step < end:
                return max(2, int(frac * max_len))
        return None

    def arrivals(self, step: int) -> int:
        """Requests the load driver should submit at this driver step."""
        return self.arrival_counts.get(step, 0)

    def reset(self) -> None:
        """Rewind the transient-failure attempt counters for replay."""
        self._fail_attempts = {}

    def describe(self) -> dict:
        """JSON-able plan summary for diagnostics / bench output."""
        return {
            "seed": self.seed,
            "horizon": self.horizon,
            "logit_faults": {
                str(s): [k for _, k in v]
                for s, v in sorted(self.logit_faults.items())},
            "pressure_spans": [
                {"start": s, "end": e, "frac": round(f, 3)}
                for s, e, f in self.pressure_spans],
            "fail_steps": {str(s): n
                           for s, n in sorted(self.fail_steps.items())},
            "total_arrivals": sum(self.arrival_counts.values()),
        }
