"""Request lifecycle for the serving engine: an explicit state machine,
typed serving errors, and a bounded admission queue with deadlines.

A production serving claim needs a failure model, not just a fast path.
This module gives every request an explicit lifecycle,

    QUEUED -> RUNNING -> {FINISHED, TRUNCATED, ABANDONED, FAILED, PREEMPTED}
    QUEUED -> PREFILLING -> RUNNING   (chunked prefill: slot reserved, cache
    PREFILLING -> {PREEMPTED, ...}     filling chunk by chunk)
    PREEMPTED -> QUEUED            (preempted work re-queues and resumes)

with transitions enforced (an illegal transition is a bug and raises
``ValueError``), and splits the error surface in two:

  * **bug class** — misuse and engine defects keep raising bare
    ``ValueError`` (constructor misconfiguration, illegal transitions);
  * **serving class** — expected runtime outcomes raise typed
    ``ServeError`` subclasses so callers can distinguish backpressure
    from bugs: ``AdmissionRejected`` (queue full / request cannot fit),
    ``DeadlineExceeded`` (SLO already blown at submission),
    ``EngineFault`` (a step failed; ``transient`` marks retryable
    faults), ``IncompleteRun`` (``run_to_completion`` exhausted its step
    budget — carries the partial outputs and lifecycle states of every
    unfinished request, so callers never lose already-generated work).

``ServeError`` derives from ``RuntimeError`` (and ``AdmissionRejected``
additionally from ``ValueError``) so pre-lifecycle callers that caught
the bare builtins keep working.

``AdmissionQueue`` is the backpressure point: a bounded FIFO with
priority-aware pop (highest priority first, FIFO within a priority) and
deadline expiry.  Preempted requests re-enter at the FRONT and are exempt
from the bound — preemption frees a slot, so re-queueing can never grow
the system's total admitted work.

Deadlines are absolute timestamps from an injectable ``clock`` (defaults
to ``time.monotonic``); ``StepClock`` is a deterministic virtual clock
for tests and the fault-injection bench, advanced explicitly by the
driver so abandonment outcomes replay bit-identically.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Dict, List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    RUNNING = "running"
    FINISHED = "finished"
    TRUNCATED = "truncated"
    ABANDONED = "abandoned"
    FAILED = "failed"
    PREEMPTED = "preempted"


TERMINAL_STATES = frozenset({
    RequestState.FINISHED, RequestState.TRUNCATED,
    RequestState.ABANDONED, RequestState.FAILED,
})

# Legal lifecycle transitions; anything else is an engine bug.
_TRANSITIONS: Dict[RequestState, frozenset] = {
    RequestState.QUEUED: frozenset({
        RequestState.RUNNING, RequestState.PREFILLING,
        RequestState.ABANDONED, RequestState.FAILED}),
    RequestState.PREFILLING: frozenset({
        RequestState.RUNNING, RequestState.PREEMPTED,
        RequestState.TRUNCATED,
        RequestState.ABANDONED, RequestState.FAILED}),
    RequestState.RUNNING: frozenset({
        RequestState.FINISHED, RequestState.TRUNCATED,
        RequestState.ABANDONED, RequestState.FAILED,
        RequestState.PREEMPTED}),
    RequestState.PREEMPTED: frozenset({RequestState.QUEUED}),
    RequestState.FINISHED: frozenset(),
    RequestState.TRUNCATED: frozenset(),
    RequestState.ABANDONED: frozenset(),
    RequestState.FAILED: frozenset(),
}


def transition(obj, new_state: RequestState) -> None:
    """Advance ``obj.state`` to ``new_state``, enforcing the machine.
    Illegal transitions are bugs (``ValueError``), not serving outcomes."""
    cur = obj.state
    if new_state not in _TRANSITIONS[cur]:
        raise ValueError(
            f"illegal lifecycle transition {cur.name} -> {new_state.name} "
            f"for request {getattr(obj, 'uid', '?')}")
    obj.state = new_state


# --------------------------------------------------------------------- errors

class ServeError(RuntimeError):
    """Base of the serving-outcome error class (vs. bug-class ValueError)."""


class AdmissionRejected(ServeError, ValueError):
    """Backpressure / will-never-fit: the queue is full, the engine lacks
    free slots for a direct admission, or the request cannot fit its slot
    cache.  Also a ``ValueError`` for pre-lifecycle callers."""


class DeadlineExceeded(ServeError):
    """The request's SLO deadline is already in the past at submission."""


class EngineFault(ServeError):
    """A step-level failure.  ``transient=True`` marks faults a driver may
    retry (bounded, with backoff — see ``RetryPolicy``); ``diagnostics``
    carries structured context (fault kind, engine step)."""

    def __init__(self, message: str, transient: bool = False,
                 diagnostics: Optional[dict] = None):
        super().__init__(message)
        self.transient = transient
        self.diagnostics = diagnostics or {}


class IncompleteRun(ServeError):
    """``run_to_completion`` exhausted ``max_steps`` with work in flight.
    Unlike a bare error, the partial outputs survive: ``partial`` maps
    uid -> tokens generated so far, ``states`` maps uid -> RequestState."""

    def __init__(self, message: str, partial: Dict[int, List[int]],
                 states: Dict[int, RequestState]):
        super().__init__(message)
        self.partial = partial
        self.states = states


# ---------------------------------------------------------------- retry/clock

@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with exponential backoff for transient EngineFaults
    at the step() driver level.  ``sleep`` is injectable so tests and the
    deterministic bench never wall-sleep."""
    max_attempts: int = 3
    backoff_s: float = 0.05
    multiplier: float = 2.0
    sleep: Callable[[float], None] = time.sleep

    def run(self, fn):
        """Call ``fn`` retrying transient EngineFaults; returns
        ``(result, retries_used)``.  Non-transient faults and exhausted
        budgets re-raise."""
        delay = self.backoff_s
        for attempt in range(self.max_attempts):
            try:
                return fn(), attempt
            except EngineFault as e:
                if not e.transient or attempt + 1 >= self.max_attempts:
                    raise
                if delay > 0:
                    self.sleep(delay)
                delay *= self.multiplier
        raise AssertionError("unreachable")


class StepClock:
    """Deterministic virtual clock: the driver advances it explicitly, so
    deadline abandonment replays bit-identically under a seeded fault
    plan (a wall clock would make outcomes load-dependent)."""

    def __init__(self, step_ms: float = 10.0):
        self.step_ms = step_ms
        self._t = 0.0

    def __call__(self) -> float:
        return self._t

    def advance(self, ms: Optional[float] = None) -> None:
        self._t += (self.step_ms if ms is None else ms) / 1e3


# -------------------------------------------------------------------- queue

class AdmissionQueue:
    """Bounded admission queue with priority-aware pop and deadline expiry.

    ``push`` raises ``AdmissionRejected`` at the bound (the backpressure
    signal); ``push_front`` re-queues preempted work ahead of everything
    at its priority and is exempt from the bound (preemption freed a slot,
    so total admitted work never grows).  Pop order: highest priority
    first, FIFO within a priority, preempted-first within both.
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.depth = depth
        self.peak_depth = 0               # high-water mark (telemetry)
        self._items: List[tuple] = []     # (order, request)
        self._next_order = 0
        self._front_order = -1

    def __len__(self) -> int:
        return len(self._items)

    def uids(self) -> List[int]:
        return [r.uid for _, r in self._ranked()]

    def requests(self) -> List:
        return [r for _, r in self._ranked()]

    def _ranked(self) -> List[tuple]:
        return sorted(self._items, key=lambda it: (-it[1].priority, it[0]))

    def push(self, req) -> None:
        if len(self._items) >= self.depth:
            raise AdmissionRejected(
                f"admission queue full ({self.depth} deep): request "
                f"rejected — backpressure, retry later or raise queue_depth")
        self._items.append((self._next_order, req))
        self._next_order += 1
        self.peak_depth = max(self.peak_depth, len(self._items))

    def push_front(self, req) -> None:
        self._items.append((self._front_order, req))
        self._front_order -= 1
        self.peak_depth = max(self.peak_depth, len(self._items))

    def expire(self, now: float) -> List:
        """Remove and return every queued request whose deadline passed —
        deadline-based abandonment of queued work."""
        expired = [r for _, r in self._items
                   if r.deadline is not None and now >= r.deadline]
        if expired:
            dead = {id(r) for r in expired}
            self._items = [(o, r) for o, r in self._items
                           if id(r) not in dead]
        return expired

    def peek_best(self, admissible=None):
        """Highest-ranked request passing ``admissible`` (or any), without
        removing it; None if none qualifies."""
        for _, r in self._ranked():
            if admissible is None or admissible(r):
                return r
        return None

    def pop_best(self, admissible=None):
        """Remove and return the highest-ranked admissible request."""
        best = self.peek_best(admissible)
        if best is not None:
            self._items = [(o, r) for o, r in self._items if r is not best]
        return best

    def pop_worst(self, admissible=None):
        """Remove and return the LOWEST-ranked admissible request — the
        load-shedding victim.  Rank order is the exact reverse of
        ``pop_best``, so fresh low-priority work sheds before anything
        preempted (preempted entries carry negative order and outrank
        fresh arrivals at the same priority)."""
        worst = None
        for _, r in reversed(self._ranked()):
            if admissible is None or admissible(r):
                worst = r
                break
        if worst is not None:
            self._items = [(o, r) for o, r in self._items if r is not worst]
        return worst

    def reset_peaks(self) -> None:
        """Drop the high-water mark to the CURRENT depth.  Back-to-back A/B
        replays reuse one process; without an explicit reset the second
        run's report inherits the first run's peak."""
        self.peak_depth = len(self._items)
