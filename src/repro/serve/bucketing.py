"""Prefill length-bucketing policy for the serving engine.

Every distinct prefill operand shape costs one XLA trace+compile, so an
engine that prefills prompts at their exact length pays one compile per
distinct prompt length — fatal at serving scale.  The policy here rounds
each prompt length up to a power-of-2 bucket in ``[min_bucket, max_len]``
(the final bucket is clamped to ``max_len`` even when it is not a
power-of-2 multiple), bounding the number of distinct prefill shapes —
and therefore traces — at ``ceil(log2(max_len / min_bucket)) + 1``.

The policy also keeps compile-cache statistics mirroring jit's cache key:
the first admission at a given ``(batch, bucket)`` shape is a miss (a
fresh trace), every later admission at that shape is a hit.  The engine's
``prefill_traces`` counter (a Python side effect inside the jitted
function, executed once per trace) is the ground truth these stats are
checked against in tests/test_serving.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple


@dataclasses.dataclass
class BucketStats:
    """Compile-cache accounting: one miss per distinct (batch, bucket)."""
    hits: int = 0
    misses: int = 0
    per_shape: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict)

    @property
    def total(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.total if self.total else 0.0


class BucketingPolicy:
    """Power-of-2 length buckets between ``min_bucket`` and ``max_len``.

    ``enabled=False`` degrades to the identity policy (bucket == length):
    admission still groups equal-length prompts for batched prefill, but
    every distinct length is its own compile.  The engine disables padding
    for recurrent families (rwkv / hybrid) this way, since a padded
    suffix would flow into their state.
    """

    def __init__(self, min_bucket: int = 16, max_len: int = 1024,
                 enabled: bool = True):
        if max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {max_len}")
        if min_bucket < 1:
            raise ValueError(f"min_bucket must be >= 1, got {min_bucket}")
        self.min_bucket = min(min_bucket, max_len)
        self.max_len = max_len
        self.enabled = enabled
        sizes = []
        b = self.min_bucket
        while b < max_len:
            sizes.append(b)
            b *= 2
        sizes.append(max_len)
        self._buckets = tuple(sizes)
        self.stats = BucketStats()

    def buckets(self) -> Tuple[int, ...]:
        return self._buckets

    def max_traces(self) -> int:
        """Upper bound on distinct batch-1 prefill shapes (== bucket count,
        == ceil(log2(max_len / min_bucket)) + 1)."""
        return (int(math.ceil(math.log2(self.max_len / self.min_bucket))) + 1
                if self.max_len > self.min_bucket else 1)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket holding a prompt of length n (identity when
        disabled).  Raises if the prompt cannot fit any bucket."""
        if not 1 <= n <= self.max_len:
            raise ValueError(
                f"prompt length {n} outside [1, max_len={self.max_len}]")
        if not self.enabled:
            return n
        for b in self._buckets:
            if n <= b:
                return b
        return self.max_len  # unreachable: last bucket is max_len

    def record(self, batch: int, bucket: int) -> bool:
        """Account one prefill at shape (batch, bucket); True = the shape
        was seen before, i.e. this admission hits the compile cache."""
        key = (batch, bucket)
        hit = key in self.stats.per_shape
        self.stats.per_shape[key] = self.stats.per_shape.get(key, 0) + 1
        if hit:
            self.stats.hits += 1
        else:
            self.stats.misses += 1
        return hit
