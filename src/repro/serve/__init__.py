from .admission import (AdmissionController,  # noqa: F401
                        ControllerDecision, SLOConfig, StepCostModel)
from .bucketing import BucketingPolicy, BucketStats  # noqa: F401
from .chunked_prefill import (ChunkedPrefillConfig,  # noqa: F401
                              PrefillGroup)
from .engine import ServingEngine, Request  # noqa: F401
from .faults import FaultInjector, nonfinite_rows  # noqa: F401
from .lifecycle import (AdmissionQueue, AdmissionRejected,  # noqa: F401
                        DeadlineExceeded, EngineFault, IncompleteRun,
                        RequestState, RetryPolicy, StepClock,
                        TERMINAL_STATES)
from .paging import (PageAllocator, PoolExhausted,  # noqa: F401
                     PrefixRegistry)
from .replay import (Arrival, Replayer, build_report,  # noqa: F401
                     load_trace, save_trace, synthesize_trace,
                     validate_report)
from .speculative import SpecConfig  # noqa: F401
from .telemetry import (Histogram, MetricsRegistry,  # noqa: F401
                        Telemetry, perfetto_trace, registry_from_stats,
                        write_perfetto)
