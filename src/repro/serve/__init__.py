from .bucketing import BucketingPolicy, BucketStats  # noqa: F401
from .engine import ServingEngine, Request  # noqa: F401
from .speculative import SpecConfig  # noqa: F401
