"""Serving telemetry: metrics registry, per-request span recorder, and
Chrome/Perfetto trace export (DESIGN.md §13).

Everything here is pure-Python, host-side, and dependency-free.  The
engine calls into a ``Telemetry`` object (when one is wired via
``ServingEngine(telemetry=...)``) strictly OUTSIDE its jitted functions
— recording an event is a list append plus a couple of dict writes, and
a disabled engine (``telemetry=None``) pays a single ``is not None``
check per call site, so the hot path is untouched either way and the
PR 8 contract rules (no jit side effects, serve-path determinism) stay
green.

Clocking: the recorder reads time exclusively through an injectable
monotonic clock.  By default it binds the ENGINE's clock at attach time
(``lifecycle.StepClock`` in deterministic runs, ``time.monotonic`` in
production), so seeded tests produce byte-identical event streams —
every ``t`` is virtual-clock time and every duration collapses to 0.0.
This module is the ONE sanctioned wall-clock source on serve paths:
``repro.analysis.ast_rules`` carves ``repro/serve/telemetry.py`` out of
the AST-DT1 determinism lint, and a direct ``time.time()`` /
``perf_counter()`` anywhere else under ``repro/serve`` still fires.

Metrics model (all pure counters/lists — snapshots are plain JSON):

* ``Counter``   — monotonically increasing int.
* ``Gauge``     — last-written value.
* ``Timeline``  — (step, t, value) samples; one per engine step (same-
  step samples overwrite, so an idle driver loop cannot grow it).
* ``Histogram`` — fixed-bucket log-scale: bucket ``i`` covers
  ``(lo * 10**((i-1)/per_decade), lo * 10**(i/per_decade)]`` with an
  explicit zero/underflow bucket below ``lo`` and an overflow bucket
  above ``hi``.  Percentiles walk the cumulative counts and report the
  geometric bucket midpoint clamped to the observed [min, max] — exact
  to a bucket's relative width (~33% per bucket at the default 8
  buckets/decade), deterministic, O(1) memory regardless of sample
  count.

Span model: per-request lifecycle events (``submit``, ``admit``,
``first_token``, ``step`` (decode/spec), ``resume``, ``preempt``,
``retire``) each carry the clock time ``t`` AND the engine step index,
plus a per-uid record (submit/admit/first/last timestamps, tokens_out,
preemptions, terminal state) from which TTFT/TPOT are derived at
retirement and fed into the ``ttft_ms`` / ``tpot_ms`` /
``queue_wait_ms`` histograms.

``perfetto_trace`` renders the event list as Chrome ``trace_event``
JSON — one track (tid) per engine slot plus a queue track, "X" complete
spans for prefill/decode/spec/resume work, instants for
submit/preempt/retire, and "C" counter tracks for the sampled
queue-depth / active-slot / page-occupancy timelines — loadable
directly in ui.perfetto.dev.
"""
from __future__ import annotations

import json
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


def monotonic() -> float:
    """The sanctioned serve-path wall clock (AST-DT1 carve-out): every
    serve module reads time through an injected clock that defaults to
    this.  Tests inject ``lifecycle.StepClock`` instead."""
    return time.monotonic()


# ------------------------------------------------------------------ metrics

class Counter:
    """Monotonically increasing integer metric."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value metric (set, not accumulated)."""

    kind = "gauge"

    def __init__(self) -> None:
        self.value: Any = None

    def set(self, v) -> None:
        self.value = v

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Timeline:
    """Per-engine-step samples of a scalar (queue depth, pool occupancy).
    Same-step samples overwrite the previous one, so a driver idling on
    an empty engine cannot grow the series."""

    kind = "timeline"

    def __init__(self) -> None:
        self.samples: List[Tuple[int, float, float]] = []  # (step, t, value)

    def sample(self, step: int, t: float, value) -> None:
        v = (int(step), float(t), float(value))
        if self.samples and self.samples[-1][0] == v[0]:
            self.samples[-1] = v
        else:
            self.samples.append(v)

    def snapshot(self) -> Dict[str, Any]:
        vals = [v for _, _, v in self.samples]
        return {
            "type": "timeline",
            "n": len(vals),
            "last": vals[-1] if vals else None,
            "max": max(vals) if vals else None,
            "mean": (sum(vals) / len(vals)) if vals else None,
            "steps": [s for s, _, _ in self.samples],
            "values": vals,
        }


class Histogram:
    """Fixed-bucket log-scale histogram with O(1) memory and
    deterministic percentiles.

    Bucket 0 holds zeros/underflow (values <= ``lo``); bucket ``i >= 1``
    covers ``(lo * 10**((i-1)/per_decade), lo * 10**(i/per_decade)]``;
    the last bucket absorbs overflow (> ``hi``).  ``percentile`` walks
    the cumulative counts and returns the geometric midpoint of the
    selected bucket, clamped to the observed [min, max] — so reported
    percentiles are always within the data range and exact min/max/mean
    are tracked separately."""

    kind = "histogram"

    def __init__(self, lo: float = 1e-3, hi: float = 1e5,
                 per_decade: int = 8) -> None:
        if lo <= 0 or hi <= lo or per_decade < 1:
            raise ValueError(
                f"histogram needs 0 < lo < hi and per_decade >= 1, got "
                f"lo={lo} hi={hi} per_decade={per_decade}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.per_decade = int(per_decade)
        n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
        self.counts = [0] * (n + 1)     # [zero/underflow, ..., overflow]
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if v <= self.lo:
            self.counts[0] += 1
            return
        i = int(math.ceil(math.log10(v / self.lo) * self.per_decade))
        self.counts[min(max(i, 1), len(self.counts) - 1)] += 1

    def _bucket_mid(self, i: int) -> float:
        if i == 0:
            return 0.0
        lo_e = self.lo * 10.0 ** ((i - 1) / self.per_decade)
        hi_e = self.lo * 10.0 ** (i / self.per_decade)
        return math.sqrt(lo_e * hi_e)

    def percentile(self, q: float) -> float:
        """q in [0, 1]; 0.0 for an empty histogram."""
        if self.count == 0:
            return 0.0
        rank = max(1, int(math.ceil(q * self.count)))
        cum = 0
        val = 0.0
        for i, c in enumerate(self.counts):
            cum += c
            if rank <= cum:
                val = self._bucket_mid(i)
                break
        else:
            val = self.max if self.max is not None else 0.0
        return min(max(val, self.min or 0.0), self.max or 0.0)

    def summary(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "mean": (self.total / self.count) if self.count else 0.0,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
        }

    def snapshot(self) -> Dict[str, Any]:
        return dict({"type": "histogram"}, **self.summary())


class MetricsRegistry:
    """Named metrics with create-on-first-use accessors.  A name is
    bound to one metric type for the registry's lifetime — re-requesting
    it as a different type is a bug and raises."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(**kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested as {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def timeline(self, name: str) -> Timeline:
        return self._get(name, Timeline)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def get(self, name: str):
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Any]:
        return {n: self._metrics[n].snapshot() for n in self.names()}

    def render(self, prefix: str = "", title: str = "metrics") -> str:
        """One uniform human-readable report (the ``--stats`` output):
        one line per metric, optionally restricted to a name prefix."""
        lines = [f"[{title}]"]
        for n in self.names():
            if prefix and not n.startswith(prefix):
                continue
            m = self._metrics[n]
            if isinstance(m, Histogram):
                s = m.summary()
                lines.append(
                    f"  {n}: n={s['count']} mean={s['mean']:.3f} "
                    f"p50={s['p50']:.3f} p90={s['p90']:.3f} "
                    f"p99={s['p99']:.3f} max={s['max']:.3f}")
            elif isinstance(m, Timeline):
                s = m.snapshot()
                if s["n"]:
                    lines.append(
                        f"  {n}: n={s['n']} last={s['last']:g} "
                        f"max={s['max']:g} mean={s['mean']:.3f}")
            else:
                v = m.value
                lines.append(f"  {n}: {v:g}" if isinstance(v, float)
                             else f"  {n}: {v}")
        return "\n".join(lines)


def registry_from_stats(stats: Dict[str, Any],
                        reg: Optional[MetricsRegistry] = None,
                        prefix: str = "serve") -> MetricsRegistry:
    """Project an engine ``stats()`` dict onto a registry as dotted-name
    gauges (nested dicts recurse: ``serve.paged.pages_in_use``), so the
    ad-hoc stats surfaces — spec counters, paged byte ladder, lifecycle
    tallies — render through the ONE uniform report."""
    reg = reg if reg is not None else MetricsRegistry()
    def put(name: str, v) -> None:
        if isinstance(v, dict):
            for k in sorted(v):
                put(f"{name}.{k}", v[k])
        elif isinstance(v, bool):
            reg.gauge(name).set(int(v))
        elif isinstance(v, (int, float)):
            reg.gauge(name).set(v)
        elif isinstance(v, str):
            reg.gauge(name).set(v)
        # lists (bucket ladders) and None are not scalar metrics: skip
    put(prefix, stats)
    return reg


# ------------------------------------------------------------------- spans

class Telemetry:
    """Per-request span recorder + metrics registry for one engine.

    Construct one, pass it as ``ServingEngine(telemetry=...)``; the
    engine attaches it at init (binding its injectable clock unless one
    was given explicitly) and invokes the ``on_*`` hooks host-side at
    each lifecycle edge.  All state is plain Python: ``events`` is the
    ordered structured event stream, ``records`` maps uid -> span record,
    ``registry`` holds the histograms/timelines the end-of-run report
    reads.  One Telemetry serves ONE engine — re-attaching is a bug."""

    def __init__(self, clock: Optional[Callable[[], float]] = None) -> None:
        self.clock = clock
        self.registry = MetricsRegistry()
        self.events: List[Dict[str, Any]] = []
        self.records: Dict[int, Dict[str, Any]] = {}
        self.n_slots = 0
        self._attached = False

    # -- wiring ---------------------------------------------------------
    def attach(self, n_slots: int, clock: Callable[[], float]) -> None:
        if self._attached:
            raise ValueError(
                "Telemetry is already attached to an engine — construct "
                "one recorder per ServingEngine")
        self._attached = True
        self.n_slots = n_slots
        if self.clock is None:
            self.clock = clock

    def now(self) -> float:
        return self.clock() if self.clock is not None else 0.0

    def _emit(self, kind: str, step: int, **data) -> Dict[str, Any]:
        ev = {"t": self.now(), "step": int(step), "kind": kind}
        ev.update(data)
        self.events.append(ev)
        self.registry.counter(f"events.{kind}").inc()
        return ev

    # -- lifecycle hooks (called by the engine, host-side only) ---------
    def on_submit(self, req, step: int) -> None:
        t = self.now()
        self.records[req.uid] = {
            "uid": req.uid, "n_prompt": len(req.prompt),
            "max_new_tokens": req.max_new_tokens,
            "priority": req.priority,
            "submit_t": t, "submit_step": int(step),
            "admit_t": None, "admit_step": None,
            "first_token_t": None, "first_token_step": None,
            "last_token_t": None, "tokens_out": 0,
            "preemptions": 0, "slot": None, "state": None,
        }
        self._emit("submit", step, uid=req.uid, n_prompt=len(req.prompt),
                   max_new_tokens=req.max_new_tokens, priority=req.priority)

    def on_admit(self, uids: Sequence[int], slots: Sequence[int],
                 bucket: int, batch: int, dur: float, step: int) -> None:
        t = self.now()
        for uid, slot in zip(uids, slots):
            r = self.records.get(uid)
            if r is not None:
                if r["admit_t"] is None:
                    r["admit_t"] = t
                    r["admit_step"] = int(step)
                r["slot"] = int(slot)
        self._emit("admit", step, uids=[int(u) for u in uids],
                   slots=[int(s) for s in slots], bucket=int(bucket),
                   batch=int(batch), dur=float(dur))
        self.registry.histogram("prefill_ms").observe(dur * 1e3)

    def on_resume(self, uid: int, slot: int, replayed: int, dur: float,
                  step: int) -> None:
        r = self.records.get(uid)
        if r is not None:
            r["slot"] = int(slot)
        self._emit("resume", step, uid=int(uid), slot=int(slot),
                   replayed=int(replayed), dur=float(dur))

    def on_token(self, req, step: int) -> None:
        r = self.records.get(req.uid)
        if r is None:
            return
        t = self.now()
        r["tokens_out"] = len(req.tokens)
        r["last_token_t"] = t
        if r["first_token_t"] is None:
            r["first_token_t"] = t
            r["first_token_step"] = int(step)
            self._emit("first_token", step, uid=req.uid)

    def on_step(self, mode: str, emitted: Dict[int, int],
                slots: Dict[int, int], dur: float, step: int,
                **extra) -> None:
        uids = sorted(emitted)
        self._emit("step", step, mode=mode, uids=uids,
                   tokens=[int(emitted[u]) for u in uids],
                   slots=[int(slots[u]) for u in uids], dur=float(dur),
                   **extra)
        self.registry.histogram(f"{mode}_step_ms").observe(dur * 1e3)

    def on_preempt(self, victims: Sequence[Tuple[int, int]], reason: str,
                   step: int) -> None:
        """``victims``: (uid, slot) pairs captured BEFORE the slots are
        cleared, so the Perfetto instants land on the right track."""
        for uid, _ in victims:
            r = self.records.get(uid)
            if r is not None:
                r["preemptions"] += 1
                r["slot"] = None
        self._emit("preempt", step, uids=[int(u) for u, _ in victims],
                   slots=[int(s) for _, s in victims], reason=reason)

    def on_chunk(self, uids: Sequence[int], slots: Sequence[int],
                 start: int, chunk_tokens: int, batch: int,
                 step: int) -> None:
        """One chunked-prefill chunk advanced: ``uids``/``slots`` are the
        group's LIVE rows, ``start`` the chunk's base position."""
        self._emit("chunk", step, uids=[int(u) for u in uids],
                   slots=[int(s) for s in slots], start=int(start),
                   chunk_tokens=int(chunk_tokens), batch=int(batch))

    def on_controller(self, kind: str, step: int, rung: int,
                      rung_name: str, **details) -> None:
        """A typed admission-controller decision (rung move, shed,
        defer) — the replayable record of the degradation ladder."""
        self._emit("controller", step, decision=kind, rung=int(rung),
                   rung_name=rung_name, **details)

    def on_retire(self, req, state, step: int) -> None:
        r = self.records.get(req.uid)
        slot = req.slot if req.slot is not None and req.slot >= 0 else None
        # the engine sets diagnostics BEFORE this hook, so shed/deadline/
        # pressure retirements carry their reason into the event stream
        reason = (req.diagnostics or {}).get("kind")
        extra = {"reason": reason} if reason else {}
        self._emit("retire", step, uid=req.uid, state=state.value,
                   tokens_out=len(req.tokens),
                   slot=slot if slot is not None else -1, **extra)
        if r is None:
            return
        r["state"] = state.value
        r["tokens_out"] = len(req.tokens)
        r["slot"] = None
        if r["first_token_t"] is not None and r["submit_t"] is not None:
            self.registry.histogram("ttft_ms").observe(
                (r["first_token_t"] - r["submit_t"]) * 1e3)
        if r["admit_t"] is not None and r["submit_t"] is not None:
            self.registry.histogram("queue_wait_ms").observe(
                (r["admit_t"] - r["submit_t"]) * 1e3)
        if (r["first_token_t"] is not None and r["last_token_t"] is not None
                and r["tokens_out"] >= 2):
            self.registry.histogram("tpot_ms").observe(
                (r["last_token_t"] - r["first_token_t"]) * 1e3
                / (r["tokens_out"] - 1))

    def sample(self, name: str, step: int, value) -> None:
        self.registry.timeline(name).sample(step, self.now(), value)


# ----------------------------------------------------------------- perfetto

# Track ids: tid 0 is engine metadata, 1..n_slots the slot tracks,
# n_slots+1 the queue track, n_slots+2 the admission controller (the
# controller metadata row appears only when controller events exist, so
# uncontrolled traces are byte-stable).  Span names by event kind/mode.
_SPAN_NAMES = {"admit": "prefill", "resume": "resume",
               "decode": "decode", "spec": "spec"}


def perfetto_trace(tel: Telemetry) -> Dict[str, Any]:
    """Render a recorded event stream as Chrome ``trace_event`` JSON
    (the dict form: ``{"traceEvents": [...]}``) — drop the output of
    ``write_perfetto`` onto ui.perfetto.dev / chrome://tracing.

    Layout: one thread track per engine slot (named ``slot N``) plus a
    ``queue`` track; "X" complete events for prefill/resume/decode/spec
    work with their host-measured duration (0-length under a StepClock);
    instant events for submit (queue track), preempt and retire (slot
    track); "C" counter events for every sampled timeline."""
    pid = 1
    qtid = tel.n_slots + 1
    ctid = tel.n_slots + 2
    evs: List[Dict[str, Any]] = [
        {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
         "args": {"name": "repro.serve"}},
        {"ph": "M", "pid": pid, "tid": qtid, "name": "thread_name",
         "args": {"name": "queue"}},
    ]
    for s in range(tel.n_slots):
        evs.append({"ph": "M", "pid": pid, "tid": s + 1,
                    "name": "thread_name", "args": {"name": f"slot {s}"}})
    if any(ev["kind"] == "controller" for ev in tel.events):
        evs.append({"ph": "M", "pid": pid, "tid": ctid,
                    "name": "thread_name", "args": {"name": "controller"}})

    def us(t: float) -> float:
        return round(t * 1e6, 3)

    for ev in tel.events:
        t, step, kind = ev["t"], ev["step"], ev["kind"]
        if kind == "admit":
            for uid, slot in zip(ev["uids"], ev["slots"]):
                evs.append({"ph": "X", "pid": pid, "tid": slot + 1,
                            "name": "prefill", "cat": "serve",
                            "ts": us(t - ev["dur"]), "dur": us(ev["dur"]),
                            "args": {"uid": uid, "step": step,
                                     "bucket": ev["bucket"]}})
        elif kind == "resume":
            evs.append({"ph": "X", "pid": pid, "tid": ev["slot"] + 1,
                        "name": "resume", "cat": "serve",
                        "ts": us(t - ev["dur"]), "dur": us(ev["dur"]),
                        "args": {"uid": ev["uid"], "step": step,
                                 "replayed": ev["replayed"]}})
        elif kind == "step":
            name = _SPAN_NAMES.get(ev["mode"], ev["mode"])
            for uid, slot, ntok in zip(ev["uids"], ev["slots"],
                                       ev["tokens"]):
                evs.append({"ph": "X", "pid": pid, "tid": slot + 1,
                            "name": name, "cat": "serve",
                            "ts": us(t - ev["dur"]), "dur": us(ev["dur"]),
                            "args": {"uid": uid, "step": step,
                                     "tokens": ntok}})
        elif kind == "preempt":
            for uid, slot in zip(ev["uids"], ev["slots"]):
                evs.append({"ph": "i", "pid": pid, "tid": slot + 1,
                            "name": "preempt", "cat": "serve", "s": "t",
                            "ts": us(t),
                            "args": {"uid": uid, "reason": ev["reason"]}})
        elif kind == "submit":
            evs.append({"ph": "i", "pid": pid, "tid": qtid,
                        "name": "submit", "cat": "serve", "s": "t",
                        "ts": us(t), "args": {"uid": ev["uid"]}})
        elif kind == "chunk":
            for uid, slot in zip(ev["uids"], ev["slots"]):
                evs.append({"ph": "X", "pid": pid, "tid": slot + 1,
                            "name": "chunk", "cat": "serve",
                            "ts": us(t), "dur": 0.0,
                            "args": {"uid": uid, "step": step,
                                     "start": ev["start"],
                                     "chunk_tokens": ev["chunk_tokens"]}})
        elif kind == "controller":
            evs.append({"ph": "i", "pid": pid, "tid": ctid,
                        "name": f"ctl:{ev['decision']}:{ev['rung_name']}",
                        "cat": "serve", "s": "t", "ts": us(t),
                        "args": {k: v for k, v in ev.items()
                                 if k not in ("t", "kind")}})
        elif kind == "retire":
            tid = ev["slot"] + 1 if ev["slot"] >= 0 else qtid
            name = (f"retire:{ev['state']}:{ev['reason']}"
                    if ev.get("reason") else f"retire:{ev['state']}")
            evs.append({"ph": "i", "pid": pid, "tid": tid,
                        "name": name, "cat": "serve",
                        "s": "t", "ts": us(t),
                        "args": {"uid": ev["uid"],
                                 "tokens_out": ev["tokens_out"]}})
    for name in tel.registry.names():
        m = tel.registry.get(name)
        if isinstance(m, Timeline):
            for _, t, v in m.samples:
                evs.append({"ph": "C", "pid": pid, "name": name,
                            "ts": us(t), "args": {name: v}})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def write_perfetto(path: str, tel: Telemetry) -> None:
    with open(path, "w") as f:
        json.dump(perfetto_trace(tel), f)
