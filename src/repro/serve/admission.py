"""SLO-guarded admission control and the graceful-degradation ladder
(DESIGN.md §14).

``AdmissionController`` closes the loop PR 9 opened: the replayer can
MEASURE tail latency under a seeded trace; this module lets the engine
DEFEND a latency target on the same trace.  Each engine step the
controller evaluates a deterministic pressure signal and drives three
levers:

* **prefill budget** — how many padded prefill tokens the engine's
  chunked-prefill machinery may process this step (halved per rung,
  floored at ``min_prefill_tokens``);
* **admit / defer / shed** — fresh admissions are deferred while the
  prefill backlog exceeds its bound (and while shedding under an active
  breach); at the top rung, queued fresh work beyond
  ``shed_target_depth`` is ABANDONED through the existing typed
  retirement machinery (``diagnostics={"kind": "shed", ...}``);
* **operating point** — a deterministic ladder of cheaper modes,
  stepped one rung at a time:

      nominal -> spec_half -> spec_off -> kv_int8 -> shed

  Rungs are CUMULATIVE (rung i implies every cheaper degradation below
  it) and capability-gated at attach: the spec rungs exist only on a
  speculative engine (γ > 1 for spec_half), kv_int8 only when resident
  pages aren't already int8; ``mode="admission"`` keeps just
  ``[nominal, shed]``.  spec_half shrinks the effective window to
  ``max(1, γ//2)`` (greedy speculation is lossless at ANY γ, so emitted
  tokens never change); spec_off falls back to vanilla decode while
  feeding the same tokens through the draft so both caches stay
  uniformly filled and re-enabling is seamless; kv_int8 admits NEW
  requests with their prefill K/V quantize-dequantized through the
  int8-resident-page numerics (such requests are non-preemptible, like
  an int8-paged engine's — an fp resume replay cannot reproduce the
  quantized history).

The pressure signal is LIVE, so it recovers when pressure clears (the
report-side p99 histograms never forget, which would latch the
controller at the top rung): a breach is (a) any fresh queued request
already waiting ``queue_wait_frac`` of the TTFT target, or (b) the last
step's modeled cost exceeding the TPOT target.  Hysteresis makes
flapping impossible: stepping up needs ``up_patience`` consecutive
breached steps, stepping down ``down_patience`` consecutive clear ones,
and every change starts a ``min_dwell_steps`` refractory window.  Every
decision is a typed ``ControllerDecision`` (and a telemetry event +
counter-track sample), so an overload episode replays byte-identically
and renders on the Perfetto timeline.

``StepCostModel`` makes the control problem REAL under the virtual
``StepClock``: a fixed per-step clock advance would invert the actual
tradeoffs (a monolithic 512-token prefill would be free; chunking would
look slower).  The model prices each step from what the engine actually
ran — padded prefill tokens, decode/draft calls, verify span tokens —
as pure host arithmetic (bit-deterministic, platform-independent), and
the replayer advances the StepClock by ``engine.last_step_cost_ms``, so
virtual TTFT/TPOT percentiles respond to scheduling decisions exactly
as wall-clock ones would.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from .lifecycle import RequestState

RUNG_NOMINAL = "nominal"
RUNG_SPEC_HALF = "spec_half"
RUNG_SPEC_OFF = "spec_off"
RUNG_KV_INT8 = "kv_int8"
RUNG_SHED = "shed"


@dataclasses.dataclass(frozen=True)
class StepCostModel:
    """Deterministic per-step cost (ms) from the work the step ran.

    Coefficients are a smoke-scale stand-in for a measured roofline:
    prefill is priced per PADDED token row (batch_bucket x bucket for a
    monolithic admission, batch_bucket x chunk_tokens per chunk), decode
    and draft per batched call, verify per span position.  The absolute
    scale is arbitrary — control behavior depends only on ratios."""

    base_ms: float = 1.0
    prefill_ms_per_token: float = 0.05
    decode_ms: float = 4.0
    draft_ms: float = 1.0
    verify_ms_per_token: float = 1.0

    def cost_ms(self, prefill_tokens: int = 0, decode_calls: int = 0,
                draft_calls: int = 0, verify_tokens: int = 0) -> float:
        return (self.base_ms
                + prefill_tokens * self.prefill_ms_per_token
                + decode_calls * self.decode_ms
                + draft_calls * self.draft_ms
                + verify_tokens * self.verify_ms_per_token)


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Targets and controller tuning.

    ``ttft_p99_ms`` is the controlled objective; ``tpot_p99_ms``
    optionally adds a per-step cost bound (needs a ``StepCostModel`` on
    the engine to be meaningful).  ``queue_wait_frac`` sets the leading
    indicator: a fresh request queued longer than this fraction of the
    TTFT target counts as a breach NOW (waiting for the blown retirement
    would react a full request-lifetime late).  Patience/dwell are the
    hysteresis: flapping would retrace jits (spec_half's verify shape)
    and thrash admissions."""

    ttft_p99_ms: float
    tpot_p99_ms: Optional[float] = None
    prefill_budget_tokens: int = 512
    min_prefill_tokens: int = 32
    queue_wait_frac: float = 0.5
    defer_backlog_tokens: Optional[int] = None   # default: 4x budget
    shed_target_depth: Optional[int] = None      # default: engine n_slots
    up_patience: int = 2
    down_patience: int = 8
    min_dwell_steps: int = 4

    def __post_init__(self):
        if self.ttft_p99_ms <= 0:
            raise ValueError(
                f"ttft_p99_ms must be > 0, got {self.ttft_p99_ms}")
        if not (0 < self.queue_wait_frac <= 1):
            raise ValueError(
                f"queue_wait_frac must be in (0, 1], got "
                f"{self.queue_wait_frac}")
        if self.min_prefill_tokens < 1 or self.prefill_budget_tokens < 1:
            raise ValueError("prefill budgets must be >= 1")
        if self.up_patience < 1 or self.down_patience < 1:
            raise ValueError("patience values must be >= 1")
        if self.min_dwell_steps < 0:
            raise ValueError("min_dwell_steps must be >= 0")


@dataclasses.dataclass(frozen=True)
class ControllerDecision:
    """One replayable controller decision: rung changes, sheds, defers.
    The stream of these (``controller.decisions``) is the byte-exact
    record the overload-storm test pins across runs."""

    step: int
    t: float
    kind: str          # "rung_up" | "rung_down" | "shed" | "defer"
    rung: int
    rung_name: str
    details: Dict[str, Any]

    def as_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "t": self.t, "kind": self.kind,
                "rung": self.rung, "rung_name": self.rung_name,
                "details": dict(self.details)}


class AdmissionController:
    """Per-engine SLO controller.  Construct with an ``SLOConfig`` and
    pass as ``ServingEngine(controller=...)``; the engine attaches it at
    init (building the capability-gated ladder) and calls ``on_step``
    from ``pump()`` once per engine step.  One controller serves ONE
    engine."""

    def __init__(self, slo: SLOConfig, mode: str = "full"):
        if mode not in ("admission", "full"):
            raise ValueError(
                f"mode must be 'admission' or 'full', got {mode!r}")
        self.slo = slo
        self.mode = mode
        self.engine = None
        self.ladder: List[str] = [RUNG_NOMINAL, RUNG_SHED]
        self.rung = 0
        self.decisions: List[ControllerDecision] = []
        self.rung_changes = 0
        self.sheds = 0
        self.defers = 0
        self._hot = 0              # consecutive breached steps
        self._cool = 0             # consecutive clear steps
        self._breached = False     # last evaluated breach state
        self._last_change = -10**9
        self._last_step = -1
        self._last_defer_step = -1

    # -- wiring ----------------------------------------------------------
    def attach(self, engine) -> None:
        if self.engine is not None:
            raise ValueError(
                "AdmissionController is already attached to an engine — "
                "construct one controller per ServingEngine")
        self.engine = engine
        ladder = [RUNG_NOMINAL]
        if self.mode == "full":
            if engine.spec is not None and engine.spec.gamma > 1:
                ladder.append(RUNG_SPEC_HALF)
                # the shrunk window mints ONE extra verify trace for the
                # engine lifetime; artifacts.compile_budgets reads this
                engine.verify_gammas.add(max(1, engine.spec.gamma // 2))
            if engine.spec is not None:
                ladder.append(RUNG_SPEC_OFF)
            if engine.kv_dtype != "int8":
                ladder.append(RUNG_KV_INT8)
        ladder.append(RUNG_SHED)
        self.ladder = ladder
        self._apply(engine)

    @property
    def rung_name(self) -> str:
        return self.ladder[self.rung]

    def prefill_budget(self) -> int:
        """Padded prefill tokens the engine may chunk this step: halved
        per rung, floored — deeper degradation trades TTFT of admitted
        work for TPOT of running work."""
        return max(self.slo.min_prefill_tokens,
                   self.slo.prefill_budget_tokens >> self.rung)

    # -- signal ----------------------------------------------------------
    def _breach(self, eng) -> bool:
        now = eng._clock()
        lim_s = self.slo.queue_wait_frac * self.slo.ttft_p99_ms / 1e3
        for r in eng.queue.requests():
            if not r.tokens and now - r.submitted_at >= lim_s:
                return True
        if (self.slo.tpot_p99_ms is not None
                and eng.last_step_cost_ms is not None
                and eng.last_step_cost_ms > self.slo.tpot_p99_ms):
            return True
        return False

    # -- per-step evaluation --------------------------------------------
    def on_step(self, eng) -> None:
        step = eng.engine_steps
        if step == self._last_step:      # pump() may run twice a step
            return
        self._last_step = step
        self._breached = breach = self._breach(eng)
        if breach:
            self._hot += 1
            self._cool = 0
        else:
            self._cool += 1
            self._hot = 0
        dwell_ok = step - self._last_change >= self.slo.min_dwell_steps
        if (breach and self._hot >= self.slo.up_patience and dwell_ok
                and self.rung < len(self.ladder) - 1):
            self.rung += 1
            self._step_changed(eng, "rung_up", step)
        elif (not breach and self._cool >= self.slo.down_patience
                and dwell_ok and self.rung > 0):
            self.rung -= 1
            self._step_changed(eng, "rung_down", step)
        if self.rung_name == RUNG_SHED:
            self._shed(eng, step)
        tel = eng.telemetry
        if tel is not None:
            tel.sample("controller_rung", step, self.rung)
            tel.sample("controller_prefill_budget", step,
                       self.prefill_budget())

    def _step_changed(self, eng, kind: str, step: int) -> None:
        self._last_change = step
        self._hot = 0
        self._cool = 0
        self.rung_changes += 1
        self._apply(eng)
        self._decide(eng, kind, step,
                     prefill_budget=self.prefill_budget())

    def _apply(self, eng) -> None:
        """Project the current rung onto the engine's knobs.  Rungs are
        cumulative: every degradation at or below the current rung is
        active."""
        active = set(self.ladder[:self.rung + 1])
        if eng.spec is not None:
            eng._gamma_eff = (max(1, eng.spec.gamma // 2)
                              if RUNG_SPEC_HALF in active
                              else eng.spec.gamma)
            eng._spec_enabled = RUNG_SPEC_OFF not in active
        eng._kv_int8_admission = RUNG_KV_INT8 in active

    def _shed(self, eng, step: int) -> None:
        """Top rung: ABANDON queued fresh work beyond the target depth,
        worst-ranked first (``pop_worst``).  Previously-preempted work is
        never shed — not by emitted tokens alone (a mid-``PREFILLING``
        preempt holds none) but by its preemption count: its slot debt is
        already paid."""
        target = (self.slo.shed_target_depth
                  if self.slo.shed_target_depth is not None
                  else eng.n_slots)
        while len(eng.queue) > target:
            victim = eng.queue.pop_worst(
                lambda r: not r.tokens and r.preemptions == 0)
            if victim is None:
                break
            self.sheds += 1
            self._decide(eng, "shed", step, uid=victim.uid,
                         queued=len(eng.queue))
            eng._retire(victim, RequestState.ABANDONED, diagnostics={
                "kind": "shed", "rung": self.rung,
                "rung_name": self.rung_name, "engine_step": step})

    # -- admission gating (called by the engine's _pump_queue) -----------
    def allow_fresh(self, eng) -> bool:
        """May fresh (never-run) queued work admit this step?  Resumes
        are ALWAYS admitted — preempted work must drain or preemption
        would leak slots of progress."""
        if not eng.active and not eng.pending_prefills:
            # nothing running to protect — deferring fresh work on an
            # idle engine is a livelock, not load shedding (the deferred
            # requests' own queue wait IS the breach signal)
            return True
        if self.rung_name == RUNG_SHED and self._breached:
            return False
        lim = self.slo.defer_backlog_tokens
        if lim is None:
            lim = 4 * self.slo.prefill_budget_tokens
        return eng.prefill_backlog_tokens <= lim

    def note_defer(self, eng, blocked: int) -> None:
        step = eng.engine_steps
        if step != self._last_defer_step:   # one event per step, not per pump
            self._last_defer_step = step
            self.defers += 1                # counter mirrors the event stream
            self._decide(eng, "defer", step, blocked=blocked,
                         backlog=eng.prefill_backlog_tokens)

    # -- record ----------------------------------------------------------
    def _decide(self, eng, kind: str, step: int, **details) -> None:
        d = ControllerDecision(step=step, t=eng._clock(), kind=kind,
                               rung=self.rung, rung_name=self.rung_name,
                               details=details)
        self.decisions.append(d)
        tel = eng.telemetry
        if tel is not None:
            tel.on_controller(kind, step, self.rung, self.rung_name,
                              **details)

    def decision_log(self) -> List[Dict[str, Any]]:
        return [d.as_dict() for d in self.decisions]

    def stats(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "ladder": list(self.ladder),
            "rung": self.rung,
            "rung_name": self.rung_name,
            "rung_changes": self.rung_changes,
            "sheds": self.sheds,
            "defers": self.defers,
            "decisions": len(self.decisions),
            "ttft_p99_ms_target": self.slo.ttft_p99_ms,
        }
