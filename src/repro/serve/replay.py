"""Trace-driven workload replay: load (or synthesize) an arrival trace,
drive ``submit()`` against the engine's clock, and produce the
end-of-run scheduling report (DESIGN.md §13).

The trace format is JSONL — one arrival per line:

    {"t": 0.02, "prompt": [5, 17, 3], "max_new_tokens": 8,
     "priority": 0, "deadline_ms": 150.0}

``t`` is seconds since trace start; ``deadline_ms``/``priority`` are
optional.  ``synthesize_trace`` derives a trace from the PR 6 fault
injector's Poisson+burst arrival plan (one seed -> one byte-identical
trace), so CI and the bench replay a seeded storm with no fixture file.

The ``Replayer`` releases arrivals when the engine clock passes each
``t`` and steps the engine until every request reaches a terminal
state.  Under a ``lifecycle.StepClock`` it advances the clock one step
per ``step()`` (fully deterministic — the bit-identical-replay tests
ride this); under a wall clock it free-runs.  Backpressure
(``AdmissionRejected``) parks the arrival until the next step;
``DeadlineExceeded`` at submission is counted as expired-at-submit.

The report is plain JSON (schema ``replay-report/v1``): TTFT / TPOT /
queue-wait p50/p90/p99 from the telemetry histograms, tokens/s and
tokens/s/slot, queue-depth / active-slot / page-occupancy timelines,
preemption/resume/abandonment accounting, and a per-request span table.
``validate_report`` is the jsonschema-free structural check CI runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .faults import FaultInjector
from .lifecycle import (AdmissionRejected, DeadlineExceeded, RetryPolicy,
                        ServeError, StepClock)
from .telemetry import Telemetry, Timeline, write_perfetto


# ------------------------------------------------------------------- trace

@dataclasses.dataclass(frozen=True)
class Arrival:
    """One trace line: a request arriving ``t`` seconds into the run."""
    t: float
    prompt: Tuple[int, ...]
    max_new_tokens: int = 8
    priority: int = 0
    deadline_ms: Optional[float] = None

    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"t": self.t, "prompt": list(self.prompt),
                             "max_new_tokens": self.max_new_tokens}
        if self.priority:
            d["priority"] = self.priority
        if self.deadline_ms is not None:
            d["deadline_ms"] = self.deadline_ms
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "Arrival":
        return cls(t=float(d["t"]), prompt=tuple(int(x) for x in d["prompt"]),
                   max_new_tokens=int(d.get("max_new_tokens", 8)),
                   priority=int(d.get("priority", 0)),
                   deadline_ms=(float(d["deadline_ms"])
                                if d.get("deadline_ms") is not None else None))


def save_trace(path: str, trace: Sequence[Arrival]) -> None:
    with open(path, "w") as f:
        for a in trace:
            f.write(json.dumps(a.to_json(), sort_keys=True) + "\n")


def load_trace(path: str) -> List[Arrival]:
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(Arrival.from_json(json.loads(line)))
            except (ValueError, KeyError, TypeError) as e:
                raise ValueError(
                    f"{path}:{i + 1}: bad trace line ({e}): {line[:80]}")
    out.sort(key=lambda a: a.t)
    return out


def synthesize_trace(seed: int = 0, steps: int = 24, vocab: int = 64,
                     step_ms: float = 10.0,
                     arrival_lambda: float = 0.6,
                     burst_every: int = 12, burst_size: int = 3,
                     prompt_len: Tuple[int, int] = (3, 10),
                     max_new: Tuple[int, int] = (4, 10),
                     deadline_frac: float = 0.25,
                     deadline_ms: float = 200.0) -> List[Arrival]:
    """Seeded Poisson+burst trace off the fault injector's arrival plan:
    arrivals at driver step ``s`` land at ``t = s * step_ms / 1e3``;
    prompt contents / lengths / budgets come from a derived seeded rng;
    a ``deadline_frac`` fraction carries a tight SLO so abandonment
    accounting is exercised.  Same seed -> byte-identical trace."""
    inj = FaultInjector(seed=seed, horizon=max(8, steps),
                        nan_faults=0, inf_faults=0, pressure_windows=0,
                        transient_failures=0,
                        arrival_lambda=arrival_lambda,
                        burst_every=burst_every, burst_size=burst_size)
    rng = np.random.default_rng(seed + 1)
    out: List[Arrival] = []
    for s in range(steps):
        for _ in range(inj.arrivals(s)):
            n = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
            prompt = tuple(int(x) for x in rng.integers(1, vocab, size=n))
            mn = int(rng.integers(max_new[0], max_new[1] + 1))
            dl = deadline_ms if float(rng.random()) < deadline_frac else None
            out.append(Arrival(t=s * step_ms / 1e3, prompt=prompt,
                               max_new_tokens=mn, deadline_ms=dl))
    return out


# ----------------------------------------------------------------- replayer

class Replayer:
    """Drive one engine through one arrival trace to full drain.

    Arrivals are released when ``engine.clock() - t0`` passes their
    ``t``; each loop iteration submits everything due, absorbs
    backpressure, runs one ``step()`` (through ``retry`` when given, so
    seeded transient faults don't abort the run), and — when the engine
    clock is a ``StepClock`` — advances it by one step.  Returns the
    scheduling report (None when the engine has no telemetry: the run
    still drains, which is what the telemetry-on/off parity check
    drives)."""

    def __init__(self, engine, trace: Sequence[Arrival],
                 retry: Optional[RetryPolicy] = None,
                 max_steps: Optional[int] = None) -> None:
        self.engine = engine
        self.trace = sorted(trace, key=lambda a: a.t)
        self.retry = retry
        self.max_steps = (max_steps if max_steps is not None
                          else 64 * max(len(self.trace), 1) + 256)

    def run(self) -> Optional[Dict[str, Any]]:
        eng = self.engine
        # back-to-back A/B replays may reuse one engine/process: peaks
        # (queue depth, page occupancy) must not leak across runs
        eng.reset_peaks()
        clock = eng.clock
        step_clock = isinstance(clock, StepClock)
        t0 = clock()
        i = 0
        pending: List[Arrival] = []
        counts = {"backpressure_waits": 0, "expired_at_submit": 0,
                  "rejected_unfittable": 0, "transient_retries": 0}
        steps = 0
        while True:
            now = clock() - t0
            while i < len(self.trace) and self.trace[i].t <= now + 1e-12:
                pending.append(self.trace[i])
                i += 1
            blocked = False
            while pending and not blocked:
                a = pending[0]
                try:
                    eng.submit(list(a.prompt),
                               max_new_tokens=a.max_new_tokens,
                               priority=a.priority,
                               deadline_ms=a.deadline_ms)
                except DeadlineExceeded:
                    counts["expired_at_submit"] += 1
                except AdmissionRejected:
                    if len(eng.queue) or eng.active:
                        # queue full behind live work: wait a step
                        counts["backpressure_waits"] += 1
                        blocked = True
                        continue
                    # rejected by an EMPTY engine: it can never fit
                    counts["rejected_unfittable"] += 1
                pending.pop(0)
            if self.retry is not None:
                _, r = self.retry.run(eng.step)
                counts["transient_retries"] += r
            else:
                eng.step()
            if step_clock:
                # with a StepCostModel the virtual clock advances by the
                # step's modeled cost, so scheduling decisions (chunking,
                # degradation) move the TTFT/TPOT percentiles exactly as
                # wall time would; without one, the PR 9 fixed advance
                clock.advance(eng.last_step_cost_ms)
            steps += 1
            drained = (i >= len(self.trace) and not pending
                       and not eng.active and not len(eng.queue)
                       and not eng.pending_prefills)
            if drained:
                break
            if steps >= self.max_steps:
                raise ServeError(
                    f"replay did not drain in {self.max_steps} driver "
                    f"steps: {len(eng.active)} active, {len(eng.queue)} "
                    f"queued, {len(pending) + len(self.trace) - i} "
                    f"arrivals not yet admitted")
        elapsed = clock() - t0
        if eng.telemetry is None:
            return None
        span = self.trace[-1].t - self.trace[0].t if self.trace else 0.0
        return build_report(
            eng, elapsed=elapsed, driver_steps=steps, extra=counts,
            trace_meta={"n_arrivals": len(self.trace),
                        "span_s": round(span, 6)})


# ------------------------------------------------------------------- report

def build_report(engine, elapsed: float, driver_steps: Optional[int] = None,
                 extra: Optional[Dict[str, int]] = None,
                 trace_meta: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
    """The end-of-run scheduling report, straight off the engine's
    telemetry spans + stats().  Works after any driven run, not just a
    ``Replayer`` one (``launch/serve.py --report-json`` uses it too)."""
    tel = engine.telemetry
    if tel is None:
        raise ValueError("build_report needs ServingEngine(telemetry=...)")
    st = engine.stats()
    reg = tel.registry
    by_state: Dict[str, int] = {}
    per_request = []
    total_out = 0
    for uid in sorted(tel.records):
        r = tel.records[uid]
        total_out += r["tokens_out"]
        if r["state"] is not None:
            by_state[r["state"]] = by_state.get(r["state"], 0) + 1
        ttft = (None if r["first_token_t"] is None
                else (r["first_token_t"] - r["submit_t"]) * 1e3)
        tpot = None
        if (r["first_token_t"] is not None and r["tokens_out"] >= 2
                and r["last_token_t"] is not None):
            tpot = ((r["last_token_t"] - r["first_token_t"]) * 1e3
                    / (r["tokens_out"] - 1))
        per_request.append({
            "uid": uid, "state": r["state"], "n_prompt": r["n_prompt"],
            "tokens_out": r["tokens_out"],
            "preemptions": r["preemptions"],
            "submit_step": r["submit_step"],
            "admit_step": r["admit_step"],
            "first_token_step": r["first_token_step"],
            "ttft_ms": None if ttft is None else round(ttft, 6),
            "tpot_ms": None if tpot is None else round(tpot, 6),
        })
    n_slots = engine.n_slots
    per_s = total_out / elapsed if elapsed > 0 else 0.0
    scheduling = {
        "preemptions": st["preemptions"],
        "resumes": st["resumes"],
        "admission_rejections": st["admission_rejections"],
        "queue_peak_depth": st["queue_peak_depth"],
    }
    scheduling.update(extra or {})
    timelines = {}
    for name in reg.names():
        m = reg.get(name)
        if isinstance(m, Timeline):
            timelines[name] = m.snapshot()
    report: Dict[str, Any] = {
        "schema": "replay-report/v1",
        "trace": trace_meta or {},
        "n_slots": n_slots,
        "elapsed_s": round(elapsed, 6),
        "driver_steps": driver_steps,
        "engine_steps": st["engine_steps"],
        "requests": {"submitted": len(tel.records), "by_state": by_state},
        "ttft_ms": reg.histogram("ttft_ms").summary(),
        "tpot_ms": reg.histogram("tpot_ms").summary(),
        "queue_wait_ms": reg.histogram("queue_wait_ms").summary(),
        "tokens": {
            "total_out": total_out,
            "per_step": st["tokens_per_step"],
            "per_s": round(per_s, 6),
            "per_s_per_slot": round(per_s / n_slots, 6) if n_slots else 0.0,
        },
        "scheduling": scheduling,
        "timelines": timelines,
        "per_request": per_request,
    }
    if "paged" in st:
        report["paged"] = {k: st["paged"][k] for k in (
            "n_pages", "pages_in_use", "peak_pages_in_use",
            "prefix_hits", "cow_copies", "page_evictions")}
    if "spec_gamma" in st:
        report["spec"] = {k: st[k] for k in (
            "spec_gamma", "spec_drafted", "spec_accepted",
            "acceptance_rate")}
    if "chunked" in st:
        report["chunked"] = dict(st["chunked"])
    if "controller" in st:
        report["controller"] = dict(st["controller"])
        ctl = getattr(engine, "controller", None)
        if ctl is not None:
            report["controller"]["decision_log"] = ctl.decision_log()
    return report


_PCT_KEYS = ("count", "mean", "min", "max", "p50", "p90", "p99")


def validate_report(report: Dict[str, Any]) -> Dict[str, Any]:
    """Structural (jsonschema-free) validation of a replay report —
    raises ``ValueError`` listing every problem; returns the report so
    callers can chain it."""
    errs: List[str] = []

    def need(key: str, typ) -> Any:
        v = report.get(key)
        if not isinstance(v, typ):
            errs.append(f"{key}: expected {typ}, got {type(v).__name__}")
            return None
        return v

    if report.get("schema") != "replay-report/v1":
        errs.append(f"schema: expected 'replay-report/v1', got "
                    f"{report.get('schema')!r}")
    for k in ("elapsed_s",):
        if not isinstance(report.get(k), (int, float)):
            errs.append(f"{k}: missing or non-numeric")
    for k in ("requests", "tokens", "scheduling", "timelines", "trace"):
        need(k, dict)
    for k in ("ttft_ms", "tpot_ms", "queue_wait_ms"):
        h = need(k, dict)
        if h is None:
            continue
        missing = [p for p in _PCT_KEYS if not isinstance(h.get(p),
                                                          (int, float))]
        if missing:
            errs.append(f"{k}: missing/non-numeric {missing}")
        elif not (h["p50"] <= h["p90"] <= h["p99"]):
            errs.append(f"{k}: percentiles not monotone: {h}")
        elif h["count"] > 0 and not (h["min"] - 1e-9 <= h["p50"]
                                     <= h["max"] + 1e-9):
            errs.append(f"{k}: p50 outside [min, max]: {h}")
    toks = report.get("tokens")
    if isinstance(toks, dict):
        for k in ("total_out", "per_step", "per_s", "per_s_per_slot"):
            if not isinstance(toks.get(k), (int, float)):
                errs.append(f"tokens.{k}: missing or non-numeric")
    reqs = report.get("requests")
    if isinstance(reqs, dict):
        by_state = reqs.get("by_state")
        if not isinstance(by_state, dict):
            errs.append("requests.by_state: missing")
        elif sum(by_state.values()) != reqs.get("submitted"):
            errs.append(
                f"requests.by_state sums to {sum(by_state.values())}, "
                f"submitted={reqs.get('submitted')}")
    pr = report.get("per_request")
    if not isinstance(pr, list):
        errs.append("per_request: expected list")
    else:
        for j, row in enumerate(pr):
            for k in ("uid", "state", "tokens_out"):
                if k not in row:
                    errs.append(f"per_request[{j}]: missing {k!r}")
                    break
    if errs:
        raise ValueError("invalid replay report:\n  " + "\n  ".join(errs))
    return report


# ---------------------------------------------------------------------- cli

def _smoke_engine(telemetry: Optional[Telemetry], seed: int,
                  verify_contracts: bool, n_slots: int, max_len: int,
                  faults: bool, chunk_tokens: Optional[int] = None,
                  controller=None, cost_model=None,
                  queue_depth: Optional[int] = None):
    """A small fp dense engine for the CI replay-smoke step — jax is
    imported here, not at module load, so trace tooling stays cheap."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api
    from .engine import ServingEngine
    import dataclasses as dc
    cfg = dc.replace(get_smoke_config("llama1_7b"), vocab=128, n_layers=2)
    params = api.init_params(jax.random.PRNGKey(seed), cfg)
    inj = None
    if faults:
        # pressure_frac tuned so the windows' page/position limit falls
        # BELOW running fills (prompt 3-10 + decode) — a window that
        # never preempts anything exercises nothing
        inj = FaultInjector(seed=seed, horizon=64, nan_faults=0,
                            inf_faults=0, transient_failures=0,
                            pressure_windows=2, pressure_len=(3, 6),
                            pressure_frac=(0.12, 0.22))
    return ServingEngine(
        params, cfg, n_slots=n_slots, max_len=max_len, min_bucket=8,
        clock=StepClock(10.0), telemetry=telemetry, faults=inj,
        on_pressure="preempt", verify_contracts=verify_contracts,
        chunked_prefill=chunk_tokens, controller=controller,
        cost_model=cost_model, queue_depth=queue_depth)


def overload_trace(seed: int, steps: int = 32,
                   vocab: int = 128) -> List[Arrival]:
    """The seeded burst trace the overload-smoke / bench A/B rides:
    long prompts arriving in bursts, no deadlines (abandonment must be
    the CONTROLLER's decision, not the trace's)."""
    return synthesize_trace(seed=seed, steps=steps, vocab=vocab,
                            arrival_lambda=1.4, burst_every=4,
                            burst_size=7, prompt_len=(20, 36),
                            max_new=(4, 8), deadline_frac=0.0)


def _overload_ab(args) -> int:
    """--slo-ttft-p99-ms: replay the SAME seeded burst trace twice —
    uncontrolled baseline vs SLO-guarded (chunked prefill + degradation
    ladder) — under the SAME step-cost model, and hold the guarded run
    to the target the baseline blows."""
    from .admission import AdmissionController, SLOConfig, StepCostModel
    target = args.slo_ttft_p99_ms
    trace = overload_trace(args.seed, steps=max(args.steps, 40))
    cost = StepCostModel()
    # the bounded default queue (2*n_slots) would cap queue wait — and
    # therefore TTFT — via submit backpressure, hiding the overload the
    # controller exists to manage; both sides get the same deep queue
    depth = 16 * args.slots
    tel_base = Telemetry()
    base = _smoke_engine(tel_base, args.seed, False, args.slots,
                         args.max_len, False, cost_model=cost,
                         queue_depth=depth)
    base_report = Replayer(base, trace,
                           retry=RetryPolicy(backoff_s=0.0)).run()
    tel = Telemetry()
    ctl = AdmissionController(
        SLOConfig(ttft_p99_ms=target), mode=args.controller_mode)
    eng = _smoke_engine(tel, args.seed, args.verify_contracts, args.slots,
                        args.max_len, False,
                        chunk_tokens=args.chunk_tokens, controller=ctl,
                        cost_model=cost, queue_depth=depth)
    report = Replayer(eng, trace, retry=RetryPolicy(backoff_s=0.0)).run()
    validate_report(report)
    base_p99 = base_report["ttft_ms"]["p99"]
    ctl_p99 = report["ttft_ms"]["p99"]
    cstats = report["controller"]
    report["slo"] = {"ttft_p99_ms_target": target,
                     "baseline_ttft_p99_ms": base_p99,
                     "guarded_ttft_p99_ms": ctl_p99}
    print(f"[overload] baseline ttft p99={base_p99:.1f}ms "
          f"(n={base_report['ttft_ms']['count']}) vs guarded "
          f"p99={ctl_p99:.1f}ms (n={report['ttft_ms']['count']}), "
          f"target={target:.1f}ms")
    print(f"[overload] controller: rung_changes={cstats['rung_changes']} "
          f"sheds={cstats['sheds']} defers={cstats['defers']} "
          f"final rung={cstats['rung_name']}")
    errs = []
    if base_p99 <= target:
        errs.append(f"baseline p99 TTFT {base_p99:.1f}ms already meets the "
                    f"{target:.1f}ms target: the storm is not a storm")
    if ctl_p99 > target:
        errs.append(f"guarded p99 TTFT {ctl_p99:.1f}ms misses the "
                    f"{target:.1f}ms target")
    if ctl_p99 >= base_p99:
        errs.append(f"guarded p99 TTFT {ctl_p99:.1f}ms does not beat the "
                    f"baseline {base_p99:.1f}ms")
    if cstats["rung_changes"] == 0 or (cstats["sheds"] == 0
                                       and cstats["defers"] == 0):
        errs.append(f"vacuous controller run: rung_changes="
                    f"{cstats['rung_changes']} sheds={cstats['sheds']} "
                    f"defers={cstats['defers']}")
    if errs:
        raise SystemExit("[overload] FAIL:\n  " + "\n  ".join(errs))
    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"[overload] report -> {args.report_json}")
    if args.perfetto:
        write_perfetto(args.perfetto, tel)
        print(f"[overload] perfetto trace -> {args.perfetto}")
    print("[overload] OK: SLO-guarded replay beats the uncontrolled "
          "baseline and meets the target")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.replay",
        description="Replay a JSONL arrival trace (or a seeded synthetic "
                    "one) against a smoke serving engine and emit the "
                    "scheduling report.")
    ap.add_argument("--trace", help="JSONL arrival trace; omit to "
                                    "synthesize one from --seed")
    ap.add_argument("--smoke", action="store_true",
                    help="small synthesized trace + small engine (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=24,
                    help="synthesized-trace driver steps")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--faults", action="store_true",
                    help="seeded pressure window (preempt/resume storm)")
    ap.add_argument("--verify-contracts", action="store_true",
                    help="run the PR 8 contract gate on the engine "
                         "(with telemetry attached) before replaying")
    ap.add_argument("--report-json", help="write the replay report here")
    ap.add_argument("--perfetto", help="write a Chrome/Perfetto "
                                       "trace_event JSON here")
    ap.add_argument("--slo-ttft-p99-ms", type=float, default=None,
                    help="overload A/B mode: replay a seeded burst trace "
                         "uncontrolled vs SLO-guarded and hold the "
                         "guarded run to this p99 TTFT target")
    ap.add_argument("--chunk-tokens", type=int, default=8,
                    help="prefill chunk size for the SLO-guarded run "
                         "(must divide --max-len)")
    ap.add_argument("--controller-mode", choices=("admission", "full"),
                    default="full",
                    help="degradation ladder for the SLO-guarded run")
    args = ap.parse_args(argv)

    if args.slo_ttft_p99_ms is not None:
        return _overload_ab(args)

    tel = Telemetry()
    eng = _smoke_engine(tel, args.seed, args.verify_contracts,
                        args.slots, args.max_len, args.faults or args.smoke)
    if args.trace:
        trace = load_trace(args.trace)
    else:
        trace = synthesize_trace(seed=args.seed, steps=args.steps,
                                 vocab=eng.cfg.vocab)
    report = Replayer(eng, trace, retry=RetryPolicy(backoff_s=0.0)).run()
    validate_report(report)
    if report["ttft_ms"]["count"] == 0:
        raise SystemExit("vacuous replay: no request produced a first "
                         "token — grow the trace")
    print(f"[replay] {report['requests']['submitted']} arrivals, "
          f"{report['engine_steps']} engine steps, "
          f"states={report['requests']['by_state']}")
    print(f"[replay] ttft_ms p50={report['ttft_ms']['p50']:.2f} "
          f"p90={report['ttft_ms']['p90']:.2f} "
          f"p99={report['ttft_ms']['p99']:.2f} "
          f"(n={report['ttft_ms']['count']})")
    print(f"[replay] tpot_ms p50={report['tpot_ms']['p50']:.2f} "
          f"p99={report['tpot_ms']['p99']:.2f} "
          f"(n={report['tpot_ms']['count']})")
    print(f"[replay] tokens/s/slot={report['tokens']['per_s_per_slot']:.1f} "
          f"preemptions={report['scheduling']['preemptions']} "
          f"resumes={report['scheduling']['resumes']}")
    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"[replay] report -> {args.report_json}")
    if args.perfetto:
        write_perfetto(args.perfetto, tel)
        print(f"[replay] perfetto trace -> {args.perfetto}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
