"""Host-side page bookkeeping for the paged KV cache.

The device side of the paged cache is a global page pool per layer
(``(n_pages + 1, page_size, ...)`` — the extra row is the SCRATCH page
that absorbs writes from free slots and dropped span positions) plus a
per-slot page table that is mirrored on the host.  This module owns the
host half of the contract:

``PageAllocator``
    The single authority over which physical pages are live.  Free pages
    are recycled FIFO, so an admit/retire/admit cycle with identical
    requests reproduces identical page tables (determinism is load-bearing
    for the parity tests).  Pages are refcounted: a page shared by N
    requests is freed only when the last holder releases it, and a holder
    that wants to WRITE a shared page must go through ``writable`` first
    (copy-on-write — the allocator hands back a fresh page and drops one
    reference from the shared one; the device copy is the caller's job).

``PoolExhausted``
    Typed backpressure.  It subclasses ``AdmissionRejected`` so the
    engine's existing admission-rejection path (push the request back on
    the queue, stop pumping) and the lifecycle preemption machinery apply
    unchanged when the pool — rather than a slot — is the scarce resource.

``PrefixRegistry``
    Maps prompt prefixes to resident pages so requests sharing a system
    prompt share physical pages.  Sharing is only ever whole-page and
    only covers tokens the donor actually prefilled; because prefill is
    bitwise invariant to right-padding (DESIGN.md §5), the donor's page
    contents are bit-identical to what the sharer's own prefill would
    have produced, which keeps paged-vs-contiguous parity exact even
    across sharing.  The registry holds one reference per registered
    page; eviction (oldest-first) releases those references so the pool
    can reclaim pages that no live request pins.

No JAX in this file — everything is pure Python/numpy bookkeeping, unit
tested in tests/test_paging.py.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serve.lifecycle import AdmissionRejected


class PoolExhausted(AdmissionRejected):
    """The page pool cannot satisfy an allocation.

    Subclasses ``AdmissionRejected`` so pool pressure rides the same
    backpressure path as slot pressure: at admission time the engine
    pushes the request back on the queue; at decode time it preempts or
    retires a victim and retries.
    """


class PageAllocator:
    """Refcounted FIFO allocator over a fixed pool of ``n_pages`` pages.

    Page ids are ints in ``[0, n_pages)``.  The device pool has one extra
    row (index ``n_pages``) — the scratch page — which is NOT managed
    here; callers use ``allocator.scratch`` as the sentinel table entry.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: Deque[int] = deque(range(self.n_pages))
        self._refs: Dict[int, int] = {}
        # lifetime churn counters (telemetry/report surface): allocations
        # and releases of page REFERENCES, monotone over the engine's life
        self.pages_allocated_total = 0
        self.pages_freed_total = 0

    # -- introspection -------------------------------------------------
    @property
    def scratch(self) -> int:
        """Sentinel page id: the pool row that absorbs masked writes."""
        return self.n_pages

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs.get(int(page), 0)

    # -- alloc / retain / free ----------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` pages off the free list (refcount 1 each).

        All-or-nothing: raises ``PoolExhausted`` without side effects if
        fewer than ``n`` pages are free.
        """
        if n < 0:
            raise ValueError(f"alloc count must be >= 0, got {n}")
        if n > len(self._free):
            raise PoolExhausted(
                f"page pool exhausted: requested {n} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        self.pages_allocated_total += n
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        """Add one reference to each page (prefix sharing)."""
        for p in pages:
            p = int(p)
            if self._refs.get(p, 0) < 1:
                raise ValueError(f"retain of unallocated page {p}")
            self._refs[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; refcount-0 pages rejoin the free
        list in the order given (FIFO reuse → deterministic tables)."""
        for p in pages:
            p = int(p)
            rc = self._refs.get(p, 0)
            if rc < 1:
                raise ValueError(f"free of unallocated page {p}")
            if rc == 1:
                del self._refs[p]
                self._free.append(p)
                self.pages_freed_total += 1
            else:
                self._refs[p] = rc - 1

    def writable(self, page: int) -> Tuple[int, bool]:
        """Make ``page`` safe to write for ONE holder (copy-on-write).

        Returns ``(page_id, fresh)``.  If the caller is the sole holder
        the page itself is returned (``fresh=False``).  Otherwise a fresh
        page is allocated, one reference is dropped from the shared page,
        and ``fresh=True`` signals the caller to copy the device rows
        ``pool[page] -> pool[new]`` before writing.
        """
        page = int(page)
        rc = self._refs.get(page, 0)
        if rc < 1:
            raise ValueError(f"writable() on unallocated page {page}")
        if rc == 1:
            return page, False
        new = self.alloc(1)[0]
        self._refs[page] = rc - 1
        return new, True


class PrefixRegistry:
    """Prompt-prefix → resident-pages map for system-prompt sharing.

    Entries are keyed by the full prompt tuple of the donor request and
    record the donor's page list plus its prompt length.  ``lookup``
    returns the longest usable shared prefix for a new prompt:

    * an exact prompt match may share ALL the donor's pages (including a
      trailing partially-filled page — the sharer's first write lands
      past the donor's fill, and copy-on-write intervenes first anyway);
    * otherwise the best common prefix rounded DOWN to whole pages, and
      never beyond the donor's own prompt (shared tokens must have been
      actually prefilled by the donor for bitwise parity to hold).

    The registry holds one reference per page per entry.  ``evict_one``
    (oldest entry first) releases those references — pages still pinned
    by live requests survive, unpinned ones return to the free list.
    """

    def __init__(self, allocator: PageAllocator, min_tokens: Optional[int] = None):
        self.allocator = allocator
        # Below one full page there is nothing shareable.
        self.min_tokens = (allocator.page_size if min_tokens is None
                           else int(min_tokens))
        self._entries: "OrderedDict[Tuple[int, ...], Tuple[List[int], int]]" = (
            OrderedDict())

    def __len__(self) -> int:
        return len(self._entries)

    def register(self, prompt: Sequence[int], pages: Sequence[int]) -> bool:
        """Record ``prompt`` as resident in ``pages`` (one ref per page).

        Skipped (returns False) when the prompt is too short to ever
        share a whole page or is already registered.
        """
        key = tuple(int(t) for t in prompt)
        if len(key) < self.min_tokens or key in self._entries:
            return False
        pages = [int(p) for p in pages]
        self.allocator.retain(pages)
        self._entries[key] = (pages, len(key))
        return True

    def lookup(self, prompt: Sequence[int],
               exact_ok: bool = True) -> Tuple[int, List[int]]:
        """Best shareable prefix for ``prompt``.

        Returns ``(shared_tokens, pages)`` — the caller must
        ``allocator.retain(pages)`` to actually pin them.  ``(0, [])``
        when nothing is shareable.  ``exact_ok=False`` restricts the
        result to whole pages even on an exact match (used by resume
        replay, which rewrites the tail page itself).
        """
        key = tuple(int(t) for t in prompt)
        ps = self.allocator.page_size
        best_tokens, best_pages = 0, []  # type: int, List[int]
        for donor, (pages, n) in self._entries.items():
            if exact_ok and donor == key:
                return n, list(pages)
            lcp = 0
            for a, b in zip(donor, key):
                if a != b:
                    break
                lcp += 1
            # Whole pages only, and only pages the donor fully prefilled.
            shared = min(lcp, n) // ps * ps
            if shared > best_tokens:
                best_tokens = shared
                best_pages = list(pages[: shared // ps])
        return best_tokens, best_pages

    def evict_one(self) -> bool:
        """Release the oldest entry's page references. False if empty."""
        if not self._entries:
            return False
        _, (pages, _) = self._entries.popitem(last=False)
        self.allocator.free(pages)
        return True

    def clear(self) -> None:
        while self.evict_one():
            pass
