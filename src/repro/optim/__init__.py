from .adamw import (  # noqa: F401
    OptimConfig, OptState, init_opt_state, apply_updates, schedule,
    global_norm, clip_by_global_norm, compress_int8, decompress_int8,
    compressed_psum,
)
