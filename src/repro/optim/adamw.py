"""AdamW + schedules + global-norm clipping (self-contained, no optax),
plus int8 error-feedback gradient compression for DP all-reduces.

Mixed-precision convention: params live in the model dtype (bf16 at scale),
optimizer moments in f32; updates are computed in f32 and cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    schedule: str = "cosine"       # cosine | linear | constant
    grad_compression: bool = False  # int8 error-feedback DP all-reduce


class OptState(NamedTuple):
    m: Any
    v: Any
    step: Array
    err: Any   # error-feedback residual (only when compression on)


def init_opt_state(params, cfg: OptimConfig) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    err = (jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if cfg.grad_compression else None)
    return OptState(m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32), err=err)


def schedule(step: Array, cfg: OptimConfig) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - jnp.clip(
            (s - cfg.warmup_steps)
            / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gn


def apply_updates(params, grads, state: OptState, cfg: OptimConfig):
    """One AdamW step. Returns (params, state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step, state.err), {
        "grad_norm": gn, "lr": lr}


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (beyond-paper: the paper's
# quantization idea applied to the distributed-training communication layer)
# ---------------------------------------------------------------------------

def compress_int8(g: Array) -> Tuple[Array, Array]:
    """Symmetric per-tensor int8 quantization: g ~ q * scale."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, err, axis_name: str):
    """Error-feedback compressed DP all-reduce (use inside shard_map):
    each shard quantizes (grad + residual) to int8, psums the int8 payload
    (lowered as a cheap integer all-reduce), and keeps the quantization
    error as residual for the next step — SGD-convergence-preserving
    (Karimireddy et al. 2019)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # agree on a GLOBAL scale first so the int8 payloads are additive
        local = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        scale = jax.lax.pmax(local, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
        g_hat = qs.astype(jnp.float32) * scale / jax.lax.psum(1, axis_name)
        new_e = gf - q.astype(jnp.float32) * scale
        return g_hat, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
