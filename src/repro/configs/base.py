"""ModelConfig schema + input-shape cells shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rotary_dim: int = 0            # 0 -> full head_dim
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_window: Optional[int] = None
    mlp_type: str = "swiglu"       # swiglu | gelu

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1            # dispatch groups (launcher sets = DP shards)

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    use_mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn every N ssm layers

    # --- RWKV6 -----------------------------------------------------------------
    rwkv_head_dim: int = 64
    decay_lora: int = 64
    rwkv_chunk: int = 64

    # --- enc-dec -----------------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0

    # --- modality stub (vlm / audio) -----------------------------------------------
    modality: str = "text"         # text | vision | audio
    prefix_frac: float = 0.25      # fraction of seq_len taken by the frontend stub

    # --- runtime ------------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    q_block: int = 512
    kv_block: int = 1024

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid-with-window / linear)."""
        return self.family in ("rwkv", "hybrid")

    @property
    def n_sites(self) -> int:
        if self.attn_every <= 0:
            return 0
        return (self.n_layers + self.attn_every - 1) // self.attn_every


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell; else reason for skip."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Scale a config down to a CPU-runnable smoke variant of the same family."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2 if cfg.attn_every <= 0 else max(cfg.attn_every, 2)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        rotary_dim=16 if cfg.rotary_dim else 0,
        n_experts=min(cfg.n_experts, 8),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        q_lora=64 if cfg.q_lora else 0,
        kv_lora=32 if cfg.kv_lora else 0,
        rope_head_dim=16 if cfg.rope_head_dim else 0,
        v_head_dim=32 if cfg.v_head_dim else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=16,
        rwkv_head_dim=16 if cfg.family == "rwkv" else cfg.rwkv_head_dim,
        decay_lora=16 if cfg.family == "rwkv" else cfg.decay_lora,
        rwkv_chunk=8,
        enc_layers=min(cfg.enc_layers, 2),
        dec_layers=min(cfg.dec_layers, 2),
        attn_every=2 if cfg.attn_every else 0,
        attn_window=min(cfg.attn_window, 64) if cfg.attn_window else None,
        dtype="float32",
        q_block=64,
        kv_block=64,
    )
