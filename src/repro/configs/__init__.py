"""Architecture configs (published shapes) + smoke variants + shape cells."""
from .base import ModelConfig, ShapeCell, SHAPES, SHAPES_BY_NAME, cell_applicable, reduce_for_smoke  # noqa: F401
from .registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
