"""Zamba2-1.2B: 38 Mamba2 layers (d=2048, state=64) + a shared transformer
block (attn+MLP d_ff=8192, per-site LoRA) applied every 6 layers
[arXiv:2411.15242].  attn_window=4096 makes the shared block sub-quadratic
at 500k context (see DESIGN.md §8)."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_1p2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=32000,
        ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=64,
        attn_every=6, attn_window=4096, rope_theta=1e4,
    )
