"""DeepSeek-V2 236B: 60L d=5120, MLA (q_lora=1536, kv_lora=512, rope=64,
128 heads x 128), MoE 2 shared + 160 routed experts (d_ff=1536) top-6,
vocab=102400 [arXiv:2405.04434].  Simplification: all layers MoE (the
published model keeps layer 0 dense); noted in DESIGN.md §8."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v2_236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=12288, vocab=102400,
        use_mla=True, q_lora=1536, kv_lora=512, rope_head_dim=64,
        v_head_dim=128,
        n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
        rope_theta=1e4,
    )
