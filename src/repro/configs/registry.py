"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

from .base import ModelConfig, reduce_for_smoke

# assigned pool (10) + the paper's own model
ARCH_IDS = (
    "llama1_7b",
    "zamba2_1p2b",
    "seamless_m4t_medium",
    "glm4_9b",
    "qwen3_32b",
    "qwen2_1p5b",
    "granite_8b",
    "phi3_vision_4p2b",
    "rwkv6_7b",
    "deepseek_v2_236b",
    "qwen3_moe_30b_a3b",
)

_ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "glm4-9b": "glm4_9b",
    "qwen3-32b": "qwen3_32b",
    "qwen2-1.5b": "qwen2_1p5b",
    "granite-8b": "granite_8b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "rwkv6-7b": "rwkv6_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama1-7b": "llama1_7b",
}


def _resolve(name: str) -> str:
    name = _ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_resolve(name)}")
    return mod.config()


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_resolve(name)}")
    if hasattr(mod, "smoke_config"):
        return mod.smoke_config()
    return reduce_for_smoke(mod.config())
