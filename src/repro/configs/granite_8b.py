"""Granite-8B (code): llama-arch 36L d=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 [arXiv:2405.04324]."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite_8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=49152, rope_theta=1e4, tie_embeddings=True,
        mlp_type="swiglu",
    )
