"""RWKV6-7B ("Finch"): attention-free, 32L d=4096 d_ff=14336 vocab=65536,
data-dependent per-channel decay [arXiv:2404.05892]."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_7b", family="rwkv",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab=65536, rwkv_head_dim=64, decay_lora=64,
        rwkv_chunk=64,
    )
