"""GLM-4 9B: 40L d=4096 32H (GQA kv=2) d_ff=13696 vocab=151552; RoPE over
half the head dim, QKV bias [hf:THUDM/glm-4-9b]."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4_9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab=151552, qkv_bias=True, rotary_dim=64,
        rope_theta=1e4, mlp_type="swiglu",
    )
