"""SeamlessM4T-medium backbone: enc-dec, 12L encoder + 12L decoder,
d=1024 16H (kv=16) d_ff=4096 vocab=256206; speech frontend stubbed as
precomputed frame embeddings [arXiv:2308.11596].

vocab is padded 256206 -> 256224 (multiple of 32) so the vocab axis is
TP-shardable on the production mesh - standard framework practice; the 18
pad tokens are never emitted by the data pipeline."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_medium", family="encdec",
        n_layers=24, enc_layers=12, dec_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab=256224, rope_theta=1e4, mlp_type="gelu",
        modality="audio",
    )
