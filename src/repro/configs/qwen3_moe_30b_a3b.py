"""Qwen3-30B-A3B: 48L d=2048 32H (GQA kv=4), MoE 128 experts top-8
(d_ff=768), vocab=151936, qk-norm [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_moe_30b_a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=6144, vocab=151936, qk_norm=True, rope_theta=1e6,
        n_experts=128, n_shared_experts=0, top_k=8, d_ff_expert=768,
    )
