"""LLaMA-1 7B — the paper's own evaluation model [arXiv:2302.13971]."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama1_7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=11008, vocab=32000, rope_theta=1e4, mlp_type="swiglu",
    )
