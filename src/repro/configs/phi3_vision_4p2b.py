"""Phi-3-Vision 4.2B: phi3-mini backbone 32L d=3072 32H (kv=32) d_ff=8192
vocab=32064 + CLIP frontend (stubbed: precomputed patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3_vision_4p2b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192, vocab=32064, rope_theta=1e4, mlp_type="swiglu",
        modality="vision", prefix_frac=0.25,
    )
