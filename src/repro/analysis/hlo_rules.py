"""Compiled-artifact rules: invariants checked on the lowered/compiled HLO
of the serving engine's actual jits (decode, prefill buckets, spec verify,
rollback).

Context keys consumed (all optional unless a rule says otherwise; a rule
whose keys are absent returns None = skipped):

  * ``hlo``: {name: compiled_hlo_text} — the artifacts under test.
  * ``dense_hlo``: {name: text} — dense-baseline artifacts for the
    gather-parity rule (same jit lowered over the dequantized twin).
  * ``plan``: plan-tree stats from ``artifacts.plan_stats``:
    {"has_plans", "n_permuted_groups", "max_bk", "bm", "itemsize"}.
  * ``weight_shard_bytes``: largest sharded plan-plane payload in bytes
    (None / absent on single-device engines -> collective rules skip).
  * ``collective_budget_bytes``: per-instruction collective result budget
    (defaults to ``weight_shard_bytes``).
  * ``pool_slice_elems``: one layer's int8 page-pool slice element count
    (absent unless the engine holds int8 resident pages).
  * ``cache_leaf_bytes``: largest cache leaf in bytes (whole-cache-copy
    audit).
  * ``donation_expected``: bool — platform supports buffer donation and
    the engine intends to donate its cache into the step jits.

The HLO parsing itself lives in ``repro.dist.hlo_analysis`` — these rules
only interpret its structured output, so tests and the engine share one
parser.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.dist import hlo_analysis as H

from .core import Finding, Rule, Severity, register


class NoWeightAllGather(Rule):
    id = "HLO-AG1"
    severity = Severity.ERROR
    invariant = ("no all-gather in a compiled serving step has a "
                 "weight-shard-sized result: decode moves activations "
                 "between shards, never the sharded CLAQ plan payload")
    origin = "PR 3"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        threshold = ctx.get("weight_shard_bytes")
        hlo = ctx.get("hlo")
        if not hlo or threshold is None:
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            big = [b for kind, b in H.collective_instructions(text)
                   if kind == "all-gather" and b >= threshold]
            if big:
                out.append(self.finding(
                    f"weight-sized all-gather in compiled {name}: "
                    f"{sorted(big, reverse=True)[:4]} B vs largest sharded "
                    f"plane {threshold} B",
                    subject=name, bytes=sorted(big, reverse=True),
                    threshold=threshold))
        return out


class CollectiveBudget(Rule):
    id = "HLO-CB1"
    severity = Severity.ERROR
    invariant = ("every collective instruction in a compiled serving step "
                 "stays under the per-instruction byte budget (activations "
                 "are small; anything bigger is a sharding regression)")
    origin = "PR 3"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        budget = ctx.get("collective_budget_bytes",
                         ctx.get("weight_shard_bytes"))
        hlo = ctx.get("hlo")
        if not hlo or budget is None:
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            over = [(kind, b) for kind, b in H.collective_instructions(text)
                    if b >= budget]
            if over:
                out.append(self.finding(
                    f"collective(s) over the {budget} B budget in compiled "
                    f"{name}: {over[:4]}",
                    subject=name, over=over, budget=budget))
        return out


class NoHostTransfer(Rule):
    id = "HLO-HT1"
    severity = Severity.ERROR
    invariant = ("the compiled step loop contains no host transfer "
                 "(infeed/outfeed/send/recv/host custom-call) — one per "
                 "step serializes decode on PCIe latency")
    origin = "PR 8"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        hlo = ctx.get("hlo")
        if not hlo:
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            hits = H.host_transfer_instructions(text)
            if hits:
                out.append(self.finding(
                    f"host transfer in compiled {name}: {hits[:4]}",
                    subject=name, transfers=hits))
        return out


class DtypeDiscipline(Rule):
    id = "HLO-DT1"
    severity = Severity.ERROR
    invariant = ("int8 resident pages never silently upcast: no s8->f32 "
                 "convert wider than one layer's gathered pool slice "
                 "(dequant happens at the gathered view, never on the "
                 "whole pool)")
    origin = "PRs 5/7"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        limit = ctx.get("pool_slice_elems")
        hlo = ctx.get("hlo")
        if not hlo or limit is None:
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            wide = [(src, dst, n) for src, dst, n
                    in H.convert_instructions(text)
                    if src in ("s8", "u8") and dst in ("f32", "f64")
                    and n > limit]
            if wide:
                out.append(self.finding(
                    f"pool-sized s8->f32 upcast in compiled {name}: "
                    f"{wide[:4]} (limit {limit} elems — one layer's "
                    f"gathered slice)",
                    subject=name, converts=wide, limit_elems=limit))
        return out


class GatherParity(Rule):
    id = "HLO-GA1"
    severity = Severity.ERROR
    invariant = ("kernel-mode decode over CLAQ plans adds at most one "
                 "tile-sized in-kernel take per permuted plan group over "
                 "the dense baseline — and ZERO gathers when every group "
                 "is x-aligned (integer-bit plans)")
    origin = "PR 5"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        hlo = ctx.get("hlo")
        base = ctx.get("dense_hlo")
        plan = ctx.get("plan")
        if not hlo or not base or not plan or not plan.get("has_plans"):
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            if name not in base:
                continue
            quant = sorted(b for op, b in H.gather_instructions(text)
                           if op == "gather")
            dense = sorted(b for op, b in H.gather_instructions(base[name])
                           if op == "gather")
            added = list(quant)
            for b in dense:
                if b in added:
                    added.remove(b)
            n_perm = plan["n_permuted_groups"]
            if n_perm == 0:
                if len(quant) != len(dense):
                    out.append(self.finding(
                        f"x-aligned plans must add ZERO gathers over dense "
                        f"in compiled {name}: dense has {len(dense)}, "
                        f"quantized has {len(quant)}",
                        subject=name, dense=dense, quant=quant))
                continue
            # permuted (mixed-precision) plans: each added gather must be
            # a VMEM-tile-sized in-kernel take, and there is at most one
            # per permuted group per matmul callsite (XLA may dedupe but
            # never multiply them)
            cap = plan["bm"] * plan["max_bk"] * plan["itemsize"]
            big = [b for b in added if b > cap]
            if big:
                out.append(self.finding(
                    f"activation-sized gather on the kernel decode path of "
                    f"{name}: {big} B (tile cap {cap} B)",
                    subject=name, over=big, tile_cap=cap))
            if len(added) > n_perm:
                out.append(self.finding(
                    f"{len(added)} gathers added over dense in {name} but "
                    f"only {n_perm} permuted plan groups exist",
                    subject=name, added=added, n_permuted_groups=n_perm))
        return out


class WholeCacheCopy(Rule):
    id = "HLO-CP1"
    severity = Severity.WARNING
    invariant = ("the compiled step loop contains no cache-sized copy — "
                 "the slot cache updates in place; the one known whole-"
                 "cache copy lives in eager admission, outside the jits")
    origin = "PR 7"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        limit = ctx.get("cache_leaf_bytes")
        hlo = ctx.get("hlo")
        if not hlo or limit is None:
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            big = [b for op, b in H.copy_instructions(text) if b >= limit]
            if big:
                out.append(self.finding(
                    f"cache-sized copy in compiled {name}: "
                    f"{sorted(big, reverse=True)[:4]} B (largest cache "
                    f"leaf {limit} B)",
                    subject=name, bytes=sorted(big, reverse=True),
                    threshold=limit))
        return out


class CacheDonation(Rule):
    id = "HLO-DN1"
    severity = Severity.WARNING
    invariant = ("where the platform supports buffer donation, the step "
                 "jits donate their cache operands (input_output_alias "
                 "present) so decode never holds two live caches")
    origin = "PR 8"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        hlo = ctx.get("hlo")
        if not hlo or not ctx.get("donation_expected"):
            return None
        out: List[Finding] = []
        for name, text in hlo.items():
            if not H.donation_aliases(text):
                out.append(self.finding(
                    f"no input/output alias in compiled {name}: cache "
                    f"buffers are not donated, every step allocates a "
                    f"second cache",
                    subject=name))
        return out


NO_WEIGHT_ALLGATHER = register(NoWeightAllGather())
COLLECTIVE_BUDGET = register(CollectiveBudget())
NO_HOST_TRANSFER = register(NoHostTransfer())
DTYPE_DISCIPLINE = register(DtypeDiscipline())
GATHER_PARITY = register(GatherParity())
WHOLE_CACHE_COPY = register(WholeCacheCopy())
CACHE_DONATION = register(CacheDonation())

HLO_RULES = [NO_WEIGHT_ALLGATHER, COLLECTIVE_BUDGET, NO_HOST_TRANSFER,
             DTYPE_DISCIPLINE, GATHER_PARITY, WHOLE_CACHE_COPY,
             CACHE_DONATION]
