"""Repo-level AST rules: a flake8-style pass over the Python source.

These rules parse files with the stdlib ``ast`` module — no imports of
the code under scan, no new dependencies — and enforce the serving
hygiene contracts that do not show up in any single compiled artifact:

  * AST-IM1: no device work at import time.  Module-scope calls into
    ``jnp.*`` / ``jax.random.*`` / ``jax.device_put`` allocate buffers and
    pick a backend before the launcher configures the mesh.
  * AST-JT1: no Python side effects inside jitted functions, except the
    registered trace counters (``global <name>_traces``-style bumps the
    engine and kernels deliberately use to count retraces).
  * AST-HS1: no host sync inside jitted functions: ``.item()`` /
    ``float()`` / ``int()`` / ``bool()`` on traced values blocks on the
    device and breaks tracing.
  * AST-DT1: deterministic serve/fault paths take no wall-clock and no
    unseeded RNG: replayable scheduling (PR 6) dies the moment a code
    path consults ``time.time()`` or ``random.random()`` directly.

Suppression: a line ending in a comment containing ``contract: ok``
(e.g. ``# contract: ok — eager path``) is exempt from all AST rules;
suppressions are collected per file before the AST walk since ``ast``
drops comments.

Jitted functions are detected syntactically: decorated with ``jax.jit``
/ ``jit`` / ``functools.partial(jax.jit, ...)``, or any function whose
name is later wrapped in a visible ``jax.jit(...)`` call in the same
file.  That is deliberately conservative — rules only fire on code that
is *provably* inside a trace.
"""
from __future__ import annotations

import ast
import io
import tokenize
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from .core import Finding, Rule, Severity, register

# Side-effect counters the engine/kernels legitimately bump inside traced
# Python: tracing counters (run once per *trace*, which is the point) and
# the kernel launch counter.  Names ending in "_traces" are the engine's
# per-jit counters; "launch_count" is the pallas kernel's.
REGISTERED_COUNTERS: Tuple[str, ...] = ("launch_count",)


def _counter_ok(name: str) -> bool:
    return name.endswith("_traces") or name in REGISTERED_COUNTERS


def _dotted(node: ast.AST) -> str:
    """Render an attribute/name chain like ``jax.random.uniform``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _suppressed_lines(source: str) -> Set[int]:
    out: Set[int] = set()
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT and "contract: ok" in tok.string:
                out.add(tok.start[0])
    except tokenize.TokenError:
        pass
    return out


def _is_jit_decorator(dec: ast.AST) -> bool:
    name = _dotted(dec)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(dec, ast.Call):
        callee = _dotted(dec.func)
        if callee in ("jax.jit", "jit"):
            return True
        # functools.partial(jax.jit, static_argnames=...)
        if callee in ("functools.partial", "partial") and dec.args:
            return _dotted(dec.args[0]) in ("jax.jit", "jit")
    return False


def _jit_wrapped_names(tree: ast.Module) -> Set[str]:
    """Function names passed to a visible ``jax.jit(...)`` call anywhere
    in the file (covers ``self._decode = jax.jit(decode_fn, ...)``)."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in (
                "jax.jit", "jit"):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
    return names


def _jitted_functions(tree: ast.Module) -> List[ast.FunctionDef]:
    wrapped = _jit_wrapped_names(tree)
    out: List[ast.FunctionDef] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (any(_is_jit_decorator(d) for d in node.decorator_list)
                    or node.name in wrapped):
                out.append(node)
    return out


class _File:
    """Parsed unit handed to each AST rule."""

    def __init__(self, path: Path, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.suppressed = _suppressed_lines(source)
        self.jitted = _jitted_functions(self.tree)

    def loc(self, node: ast.AST) -> str:
        return f"{self.path}:{getattr(node, 'lineno', '?')}"

    def ok(self, node: ast.AST) -> bool:
        return getattr(node, "lineno", -1) in self.suppressed


def _iter_files(paths: Iterable[Path]) -> List[_File]:
    out: List[_File] = []
    for root in paths:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for p in files:
            try:
                out.append(_File(p, p.read_text()))
            except (SyntaxError, UnicodeDecodeError, OSError):
                continue
    return out


_DEVICE_PREFIXES = ("jnp.", "jax.numpy.", "jax.random.")
_DEVICE_CALLS = ("jax.device_put", "jax.devices", "jax.local_devices")


class NoImportTimeDeviceWork(Rule):
    id = "AST-IM1"
    severity = Severity.ERROR
    invariant = ("no module-scope jnp./jax.random./device work: import "
                 "must not allocate buffers or pick a backend before the "
                 "launcher configures the mesh")
    origin = "PR 3"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        files: Optional[List[_File]] = ctx.get("files")
        if files is None:
            return None
        out: List[Finding] = []
        for f in files:
            for node in self._module_scope_calls(f.tree):
                if f.ok(node):
                    continue
                name = _dotted(node.func)
                if (name.startswith(_DEVICE_PREFIXES)
                        or name in _DEVICE_CALLS):
                    out.append(self.finding(
                        f"device work at import time: {name}(...)",
                        subject=f.loc(node), call=name))
        return out

    @staticmethod
    def _module_scope_calls(tree: ast.Module) -> List[ast.Call]:
        """Calls at module scope, descending into if/try blocks but not
        into function or class-method bodies (class-level constants DO
        execute at import, so descend into ClassDef)."""
        out: List[ast.Call] = []
        stack: List[ast.AST] = list(tree.body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, ast.Call):
                out.append(node)
            stack.extend(ast.iter_child_nodes(node))
        return out


class NoJitSideEffects(Rule):
    id = "AST-JT1"
    severity = Severity.ERROR
    invariant = ("no Python side effects inside jitted fns except "
                 "registered trace counters: a global/nonlocal write, "
                 "print, or list mutation runs per-trace, not per-call")
    origin = "PR 2"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        files: Optional[List[_File]] = ctx.get("files")
        if files is None:
            return None
        out: List[Finding] = []
        for f in files:
            for fn in f.jitted:
                out.extend(self._scan_fn(f, fn))
        return out

    def _scan_fn(self, f: _File, fn: ast.FunctionDef) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(fn):
            if f.ok(node):
                continue
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                bad = [n for n in node.names if not _counter_ok(n)]
                if bad:
                    out.append(self.finding(
                        f"global/nonlocal write to {bad} inside jitted "
                        f"{fn.name}() (only registered trace counters "
                        f"may be bumped)",
                        subject=f.loc(node), names=bad, fn=fn.name))
            elif isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name == "print":
                    out.append(self.finding(
                        f"print() inside jitted {fn.name}() runs only at "
                        f"trace time (use jax.debug.print)",
                        subject=f.loc(node), fn=fn.name))
        return out


_HOST_SYNC_BUILTINS = ("float", "int", "bool")


class NoHostSyncInJit(Rule):
    id = "AST-HS1"
    severity = Severity.ERROR
    invariant = ("no .item()/float()/int()/bool() on traced values inside "
                 "jitted fns: host sync blocks the device and fails under "
                 "tracing")
    origin = "PR 6"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        files: Optional[List[_File]] = ctx.get("files")
        if files is None:
            return None
        out: List[Finding] = []
        for f in files:
            for fn in f.jitted:
                static = self._static_names(fn)
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call) or f.ok(node):
                        continue
                    if (isinstance(node.func, ast.Attribute)
                            and node.func.attr == "item"
                            and not node.args):
                        out.append(self.finding(
                            f".item() inside jitted {fn.name}()",
                            subject=f.loc(node), fn=fn.name))
                        continue
                    name = _dotted(node.func)
                    if (name in _HOST_SYNC_BUILTINS and len(node.args) == 1
                            and self._traced_operand(node.args[0], static)):
                        out.append(self.finding(
                            f"{name}() on a possibly-traced value inside "
                            f"jitted {fn.name}()",
                            subject=f.loc(node), fn=fn.name, builtin=name))
        return out

    @staticmethod
    def _static_names(fn: ast.FunctionDef) -> Set[str]:
        """Names that are static under the jit: any name fed from
        ``.shape``/``len()``/constants within the function, plus args
        named like static config (heuristic: we only need to avoid
        false positives on shape math)."""
        static: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                names: List[str] = []
                if isinstance(tgt, ast.Name):
                    names = [tgt.id]
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    names = [e.id for e in tgt.elts
                             if isinstance(e, ast.Name)]
                if names and NoHostSyncInJit._static_expr(node.value):
                    static.update(names)
        return static

    @staticmethod
    def _static_expr(node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute) and node.attr in (
                "shape", "ndim", "dtype", "size"):
            return True
        if isinstance(node, ast.Call):
            return _dotted(node.func) == "len"
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.BinOp):
            return (NoHostSyncInJit._static_expr(node.left)
                    and NoHostSyncInJit._static_expr(node.right))
        if isinstance(node, ast.Subscript):
            return NoHostSyncInJit._static_expr(node.value)
        return False

    @staticmethod
    def _traced_operand(node: ast.AST, static: Set[str]) -> bool:
        """True when the operand may be traced: not a literal, not shape
        arithmetic, not a name previously assigned from shape math."""
        if NoHostSyncInJit._static_expr(node):
            return False
        if isinstance(node, ast.Name):
            return node.id not in static
        if isinstance(node, ast.BinOp):
            return (NoHostSyncInJit._traced_operand(node.left, static)
                    or NoHostSyncInJit._traced_operand(node.right, static))
        return True


_WALLCLOCK_CALLS = ("time.time", "time.monotonic", "time.perf_counter",
                    "datetime.datetime.now", "datetime.now")
# AST-DT1 carve-out: serve/telemetry.py owns the ONE sanctioned
# wall-clock source on serve paths (its ``monotonic()`` is the default
# behind every injectable clock — see DESIGN.md §13).  Everything else
# under the determinism scope must inject a clock; a direct wall-clock
# call there still fires.  Mutation-tested in BOTH directions
# (tests/test_analysis.py): telemetry.py with time.monotonic() stays
# clean, any sibling serve file with the same call trips the rule.
_DT1_EXEMPT = ("repro/serve/telemetry.py",)
_UNSEEDED_RNG = ("random.random", "random.randint", "random.choice",
                 "random.shuffle", "random.uniform", "np.random.rand",
                 "np.random.randn", "np.random.randint",
                 "numpy.random.rand", "numpy.random.randn")


class ServeDeterminism(Rule):
    id = "AST-DT1"
    severity = Severity.ERROR
    invariant = ("deterministic serve/fault paths call no wall-clock and "
                 "no unseeded global RNG: scheduling must replay from the "
                 "seed alone (injected clocks / named Generators only; "
                 "serve/telemetry.py is the one sanctioned clock source)")
    origin = "PR 6"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        files: Optional[List[_File]] = ctx.get("files")
        scope: Optional[str] = ctx.get("determinism_scope")
        if files is None or scope is None:
            return None
        out: List[Finding] = []
        for f in files:
            fpath = str(f.path).replace("\\", "/")
            if scope not in fpath:
                continue
            if any(ex in fpath for ex in _DT1_EXEMPT):
                continue
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call) or f.ok(node):
                    continue
                name = _dotted(node.func)
                if name in _WALLCLOCK_CALLS:
                    out.append(self.finding(
                        f"wall-clock call {name}() in deterministic "
                        f"serve path (inject a clock instead)",
                        subject=f.loc(node), call=name))
                elif name in _UNSEEDED_RNG:
                    out.append(self.finding(
                        f"unseeded global RNG {name}() in deterministic "
                        f"serve path (use a seeded np.random.Generator)",
                        subject=f.loc(node), call=name))
        return out


NO_IMPORT_DEVICE_WORK = register(NoImportTimeDeviceWork())
NO_JIT_SIDE_EFFECTS = register(NoJitSideEffects())
NO_HOST_SYNC_IN_JIT = register(NoHostSyncInJit())
SERVE_DETERMINISM = register(ServeDeterminism())

AST_RULES = [NO_IMPORT_DEVICE_WORK, NO_JIT_SIDE_EFFECTS,
             NO_HOST_SYNC_IN_JIT, SERVE_DETERMINISM]


def ast_context(paths: Iterable[Path],
                determinism_scope: str = "repro/serve") -> Dict[str, Any]:
    """Build the ctx dict the AST rules consume from a set of paths."""
    return {"files": _iter_files(paths),
            "determinism_scope": determinism_scope}
