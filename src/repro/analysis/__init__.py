"""Hot-path contract checker: rule-based static analysis over compiled
HLO artifacts, jit trace behaviour, and the repo's Python AST.

See DESIGN.md §12 for the rule catalog and how to add a rule.  Importing
this package registers every shipped rule in ``REGISTRY``.
"""
from .core import (ContractViolation, Finding, REGISTRY, Report, Rule,
                   Severity, all_rules, register, run_rules)
from .hlo_rules import HLO_RULES
from .trace_rules import TRACE_RULES, TraceSentinel
from .ast_rules import AST_RULES, ast_context

__all__ = [
    "AST_RULES", "ContractViolation", "Finding", "HLO_RULES", "REGISTRY",
    "Report", "Rule", "Severity", "TRACE_RULES", "TraceSentinel",
    "all_rules", "ast_context", "register", "run_rules",
]
