"""Rule framework for the hot-path contract checker (DESIGN.md §12).

Every serving-path invariant this repo has earned — gather-free fused
decode (PR 5), weight-resident sharded decode (PR 3), bounded compile
counts (PR 2), int8 dtype discipline (PRs 5/7) — is expressed as a
``Rule`` object with a stable id and severity.  Rules `check()` a context
dict and return structured ``Finding``s; ``run_rules`` aggregates them
into a ``Report`` that renders for humans, serializes to JSON for CI,
and answers "is this artifact clean?" with one bit.

Contract for ``Rule.check(ctx)``:

  * return ``None``  -> the rule does not apply to this context (e.g. the
    all-gather rule on a single-device engine); recorded as *skipped*;
  * return ``[]``    -> the rule ran and the invariant holds;
  * return findings  -> violations, each carrying the rule's id/severity.

Rules are registered at import time in a global ``REGISTRY`` keyed by id;
the registry is what the CLI runner, the engine's ``verify_contracts``
hook, and the completeness test ("every rule has a mutation test")
enumerate.  Adding a rule = subclass + ``register()`` + a mutation test
that violates the invariant and asserts the rule fires (see DESIGN.md
§12 for the checklist).
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Any, Dict, List, Optional, Sequence


class Severity(enum.IntEnum):
    """Ordered so max() over findings yields the report's worst level."""
    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:  # pragma: no cover - trivial
        return self.name


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured violation: which rule, how bad, where, and the
    machine-readable details a driver needs to act on it."""
    rule_id: str
    severity: Severity
    message: str
    subject: str = ""                 # e.g. "decode", "src/repro/x.py:12"
    details: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"rule": self.rule_id, "severity": self.severity.name,
                "subject": self.subject, "message": self.message,
                "details": self.details}


class Rule:
    """Base rule: id / severity / one-line invariant + ``check``."""
    id: str = ""
    severity: Severity = Severity.ERROR
    invariant: str = ""               # one line, shown in reports/docs
    origin: str = ""                  # which PR introduced the contract

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        raise NotImplementedError

    def finding(self, message: str, subject: str = "",
                **details: Any) -> Finding:
        return Finding(self.id, self.severity, message, subject, details)


REGISTRY: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if not rule.id:
        raise ValueError(f"rule {rule!r} has no id")
    if rule.id in REGISTRY:
        raise ValueError(f"duplicate rule id {rule.id}")
    REGISTRY[rule.id] = rule
    return rule


def all_rules() -> List[Rule]:
    return [REGISTRY[k] for k in sorted(REGISTRY)]


@dataclasses.dataclass
class Report:
    """Aggregated outcome of one checker pass over one subject."""
    subject: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    rules_run: List[str] = dataclasses.field(default_factory=list)
    rules_skipped: List[str] = dataclasses.field(default_factory=list)

    @property
    def clean(self) -> bool:
        """No ERROR-severity findings (warnings don't gate)."""
        return not self.errors

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity is Severity.ERROR]

    def by_severity(self) -> Dict[str, int]:
        out = {s.name: 0 for s in Severity}
        for f in self.findings:
            out[f.severity.name] += 1
        return out

    def to_json(self) -> Dict[str, Any]:
        return {
            "subject": self.subject,
            "clean": self.clean,
            "rules_run": sorted(self.rules_run),
            "rules_skipped": sorted(self.rules_skipped),
            "summary": self.by_severity(),
            "findings": [f.to_json() for f in self.findings],
        }

    def render(self) -> str:
        """Human report: worst findings first, one line per finding plus
        an indented detail line when there are details to show."""
        lines = [f"contract report [{self.subject}]: "
                 f"{'CLEAN' if self.clean else 'VIOLATIONS'} "
                 f"({len(self.rules_run)} rules run, "
                 f"{len(self.rules_skipped)} skipped, "
                 f"{len(self.findings)} findings)"]
        for f in sorted(self.findings, key=lambda f: -f.severity):
            where = f" [{f.subject}]" if f.subject else ""
            lines.append(f"  {f.severity.name:7s} {f.rule_id}{where}: "
                         f"{f.message}")
            if f.details:
                lines.append(f"          {json.dumps(f.details, default=str)}")
        return "\n".join(lines)


class ContractViolation(ValueError):
    """Raised by ``ServingEngine(verify_contracts=True)`` / the CLI when
    a pass produces ERROR-severity findings; carries the full report."""

    def __init__(self, report: Report):
        self.report = report
        super().__init__(
            f"{len(report.errors)} contract violation(s) on "
            f"{report.subject!r}:\n{report.render()}")


def run_rules(rules: Sequence[Rule], ctx: Dict[str, Any],
              subject: str = "") -> Report:
    """Run ``rules`` over one context; a rule returning None is recorded
    as skipped (not applicable), [] as run-and-clean."""
    rep = Report(subject=subject)
    for rule in rules:
        found = rule.check(ctx)
        if found is None:
            rep.rules_skipped.append(rule.id)
        else:
            rep.rules_run.append(rule.id)
            rep.findings.extend(found)
    return rep
