"""Contract-checker CLI: ``python -m repro.analysis.check``.

Builds a small trained model per named config, quantizes it, stands up a
serving engine, and runs the compiled-artifact + trace rules against its
lowered decode; ``--ast`` additionally (or instead) runs the repo AST
rules over source trees.  Emits a human report per subject and an
aggregate JSON document with ``--json``; exit code 1 iff any subject has
ERROR-severity findings.

Configs are deliberately tiny (the same smoke-scale substrate the test
suite and benchmarks use) — the point is the *compiled artifact shape*,
which does not change with model scale.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Any, Dict, List

from .ast_rules import AST_RULES, ast_context
from .core import Report, run_rules


def _build_engine(config: str):
    """Quantize the smoke model per the named config and wrap it in a
    serving engine (import-heavy, so deferred out of module scope)."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import APConfig, CLAQConfig, ORConfig
    from repro.data import calibration_set
    from repro.launch.quantize import claq_quantize
    from repro.models import api
    from repro.serve.engine import ServingEngine

    if config == "moe":
        cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                                  vocab=64, n_layers=1)
    else:
        cfg = dataclasses.replace(get_smoke_config("llama1_7b"),
                                  vocab=128, n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    if config == "dense":
        return ServingEngine(params, cfg, n_slots=2, max_len=32,
                             prepare=False), None
    if config == "moe":
        return ServingEngine(params, cfg, n_slots=2, max_len=32,
                             prepare=False), None
    if config == "ap_or":
        qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4,
                          gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                          orr=ORConfig(0.1))
    elif config == "int3":
        qcfg = CLAQConfig(bits=3, method="kmeans", kmeans_iters=4,
                          gptq_blocksize=32)
    else:
        raise SystemExit(f"unknown config {config!r} "
                         f"(expected dense | moe | ap_or | int3)")
    calib = calibration_set(vocab=cfg.vocab, n_segments=4, seq_len=32)
    qparams, _ = claq_quantize(params, cfg, calib, qcfg)
    eng = ServingEngine(qparams, cfg, n_slots=2, max_len=32)
    dense_eng = ServingEngine(params, cfg, n_slots=2, max_len=32,
                              prepare=False)
    return eng, dense_eng


def check_config(config: str) -> Report:
    from .artifacts import verify_engine
    eng, dense_eng = _build_engine(config)
    return verify_engine(eng, dense_eng, raise_on_error=False,
                         subject=f"config:{config}")


def check_ast(paths: List[str]) -> Report:
    ctx = ast_context([Path(p) for p in paths])
    return run_rules(AST_RULES, ctx,
                     subject="ast:" + ",".join(str(p) for p in paths))


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Run the hot-path contract checker.")
    ap.add_argument("--config", action="append", default=[],
                    help="engine config to lower and lint "
                         "(dense | moe | ap_or | int3); repeatable")
    ap.add_argument("--ast", action="append", default=[], metavar="PATH",
                    help="run the repo AST rules over this file/dir; "
                         "repeatable")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write the aggregate JSON report here ('-' = "
                         "stdout)")
    args = ap.parse_args(argv)
    if not args.config and not args.ast:
        ap.error("nothing to check: pass --config and/or --ast")

    reports: List[Report] = []
    if args.ast:
        reports.append(check_ast(args.ast))
    for config in args.config:
        reports.append(check_config(config))

    for rep in reports:
        print(rep.render())
    doc: Dict[str, Any] = {
        "clean": all(r.clean for r in reports),
        "reports": [r.to_json() for r in reports],
    }
    if args.json == "-":
        json.dump(doc, sys.stdout, indent=2, default=str)
        print()
    elif args.json:
        Path(args.json).write_text(
            json.dumps(doc, indent=2, default=str) + "\n")
    return 0 if doc["clean"] else 1


if __name__ == "__main__":
    sys.exit(main())
