"""Jaxpr/trace-level rules: recompilation discipline.

The engine's jits are keyed on abstract signatures (shape/dtype buckets).
PR 2's contract is that admission bucketing bounds the number of distinct
signatures — and therefore compiles — by

    compile_budget = (ceil(log2(max_len / min_bucket)) + 1) * n_batch_buckets

per jit family.  The ``TraceSentinel`` below observes the *abstract
signature* of every jit call the engine makes (a cheap host-side hash of
shapes/dtypes plus static args) and these rules cross-check three numbers
that must agree:

  * distinct signatures observed per jit (sentinel),
  * actual Python traces executed per jit (the engine's trace counters —
    a real retrace re-runs the traced Python function),
  * the static budget from the bucketing config.

TRC-CC1 enforces the budget; TRC-SG1 catches *silent* retraces: if a jit
traced more times than it saw distinct signatures (modulo explicit
``.lower()`` calls, which re-run tracing without a new signature), some
non-hashable-by-shape input — a Python scalar, a fresh closure, a
re-prepared weight tree — is thrashing the compile cache.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional, Tuple

from .core import Finding, Rule, Severity, register


class TraceSentinel:
    """Host-side observer of jit call signatures.

    The engine calls ``observe(jit_name, signature)`` right before every
    jit invocation with a hashable signature key that changes exactly when
    jax would retrace (shape/dtype/static-arg changes).  ``lowerings``
    counts explicit ``.lower()`` calls, which re-trace without implying a
    cache miss on the call path.
    """

    def __init__(self) -> None:
        self.signatures: Dict[str, collections.Counter] = (
            collections.defaultdict(collections.Counter))
        self.lowerings: collections.Counter = collections.Counter()

    def observe(self, jit_name: str, signature: Tuple[Any, ...]) -> None:
        self.signatures[jit_name][signature] += 1

    def observe_lowering(self, jit_name: str) -> None:
        self.lowerings[jit_name] += 1

    def distinct(self, jit_name: str) -> int:
        return len(self.signatures.get(jit_name, ()))

    def calls(self, jit_name: str) -> int:
        return sum(self.signatures.get(jit_name,
                                       collections.Counter()).values())

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        return {name: {"distinct": len(ctr), "calls": sum(ctr.values()),
                       "lowerings": self.lowerings.get(name, 0)}
                for name, ctr in sorted(self.signatures.items())}


class CompileCountBudget(Rule):
    id = "TRC-CC1"
    severity = Severity.ERROR
    invariant = ("distinct abstract signatures per jit stay within the "
                 "bucketing compile budget: "
                 "(ceil(log2(max_len/min_bucket))+1) * n_batch_buckets")
    origin = "PR 2"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        sentinel: Optional[TraceSentinel] = ctx.get("sentinel")
        budgets: Optional[Dict[str, int]] = ctx.get("compile_budget")
        if sentinel is None or not budgets:
            return None
        out: List[Finding] = []
        for jit_name, budget in sorted(budgets.items()):
            distinct = sentinel.distinct(jit_name)
            if distinct > budget:
                out.append(self.finding(
                    f"{jit_name} saw {distinct} distinct signatures, "
                    f"budget is {budget}: bucketing is leaking shapes",
                    subject=jit_name, distinct=distinct, budget=budget,
                    calls=sentinel.calls(jit_name)))
        return out


class RetraceSentinel(Rule):
    id = "TRC-SG1"
    severity = Severity.ERROR
    invariant = ("a jit's actual trace count never exceeds distinct "
                 "signatures + explicit lowerings: more means the compile "
                 "cache is thrashing on a non-signature input")
    origin = "PR 8"

    def check(self, ctx: Dict[str, Any]) -> Optional[List[Finding]]:
        sentinel: Optional[TraceSentinel] = ctx.get("sentinel")
        traces: Optional[Dict[str, int]] = ctx.get("trace_counts")
        if sentinel is None or traces is None:
            return None
        out: List[Finding] = []
        for jit_name, n_traces in sorted(traces.items()):
            distinct = sentinel.distinct(jit_name)
            if distinct == 0 and n_traces == 0:
                continue
            allowed = distinct + sentinel.lowerings.get(jit_name, 0)
            if n_traces > allowed:
                out.append(self.finding(
                    f"{jit_name} traced {n_traces}x for only {distinct} "
                    f"distinct signatures (+{allowed - distinct} explicit "
                    f"lowerings): silent retrace",
                    subject=jit_name, traces=n_traces, distinct=distinct,
                    allowed=allowed))
            elif n_traces < distinct:
                out.append(self.finding(
                    f"{jit_name} reports {n_traces} traces for {distinct} "
                    f"distinct signatures: the trace counter itself is "
                    f"broken (traced fn no longer bumps it)",
                    subject=jit_name, traces=n_traces, distinct=distinct))
        return out


COMPILE_COUNT_BUDGET = register(CompileCountBudget())
RETRACE_SENTINEL = register(RetraceSentinel())

TRACE_RULES = [COMPILE_COUNT_BUDGET, RETRACE_SENTINEL]
