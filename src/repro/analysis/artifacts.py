"""Builders that turn a live ``ServingEngine`` into the context dict the
compiled-artifact and trace rules consume, plus ``verify_engine`` — the
one-call gate behind ``ServingEngine(verify_contracts=True)``.

The expensive piece is the HLO: ``engine_context`` AOT-lowers the
engine's decode jit under kernel mode (interpret=True so the pallas
kernels lower off-accelerator) and, for the gather-parity rule, builds a
*dense twin* — the same engine over the dequantized weights — whose
compiled decode is the gather baseline.  Everything else (plan stats,
shard thresholds, pool-slice limits, compile budgets) is cheap host-side
tree walking.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantized import QuantizedTensor
from repro.kernels.plan import PreparedQuantizedTensor
from repro.models import modules as nn

from .core import ContractViolation, Report, Rule, run_rules
from .hlo_rules import HLO_RULES
from .trace_rules import TRACE_RULES

_QUANT_TYPES = (QuantizedTensor, PreparedQuantizedTensor)

# Cache-pool leaf names (mirrors serve.engine._POOL_SRC — duplicated here
# to keep analysis importable without pulling in the engine module).
_POOL_LEAVES = ("kp", "vp", "cp", "pp")


def _is_quant(leaf: Any) -> bool:
    return isinstance(leaf, _QUANT_TYPES)


def _quant_leaves(params) -> List[Any]:
    out: List[Any] = []
    jax.tree_util.tree_map(
        lambda l: out.append(l) if _is_quant(l) else None,
        params, is_leaf=_is_quant)
    return out


def plan_stats(params, n_slots: int = 8) -> Dict[str, Any]:
    """Plan-tree stats for the gather-parity rule: how many permuted
    (x-indexed) groups exist across all prepared leaves, and the worst
    in-kernel take size.  ``bm`` is the decode-row tile the take loads
    (>= 8 even for tiny slot counts: the kernel pads rows to its block)."""
    n_permuted = 0
    max_bk = 0
    has_plans = False
    for leaf in _quant_leaves(params):
        if not isinstance(leaf, PreparedQuantizedTensor):
            continue
        has_plans = True
        permuted = [g for g in leaf.groups if g.x_start is None]
        n_permuted += len(permuted)
        if permuted:
            max_bk = max(max_bk, max(g.bk for g in permuted))
    return {"has_plans": has_plans, "n_permuted_groups": n_permuted,
            "max_bk": max_bk, "bm": max(8, n_slots), "itemsize": 4}


def weight_shard_threshold(params, model_parts: int) -> Optional[int]:
    """Largest sharded plan-plane payload in bytes — the all-gather rule's
    threshold.  None when no quantized unit actually shards (replicated
    plans move at load, not per step, so the rule would be vacuous)."""
    if model_parts <= 1:
        return None
    best: Optional[int] = None
    for leaf in _quant_leaves(params):
        if (isinstance(leaf, PreparedQuantizedTensor)
                and leaf.shards_whole_tiles(model_parts)):
            for g in leaf.groups:
                for p in g.planes:
                    b = int(np.prod(p.shape)) * 4
                    best = b if best is None else max(best, b)
    return best


def _dequant_leaf(leaf):
    """Dequantize one (possibly layer-stacked) quantized leaf into the
    dense kernel slot layout (..., in, out)."""
    if isinstance(leaf, PreparedQuantizedTensor):
        stack = leaf.gather_idx.ndim - 1
    elif isinstance(leaf, QuantizedTensor):
        stack = leaf.col_perm.ndim - 1
    else:
        return leaf
    fn = lambda l: l.dequantize()          # noqa: E731 - vmap target
    for _ in range(stack):
        fn = jax.vmap(fn)
    return jnp.swapaxes(fn(leaf), -1, -2)


def dense_twin_params(params):
    """The engine's params with every quantized leaf replaced by its
    dequantized dense form — the baseline the gather-parity rule lowers."""
    return jax.tree_util.tree_map(_dequant_leaf, params, is_leaf=_is_quant)


def _batch_buckets(n_slots: int) -> int:
    """Distinct bucketed admission batch sizes: next-power-of-2 capped at
    n_slots (mirrors the engine's ``Bb`` computation in ``_admit``)."""
    return len({min(1 << (b - 1).bit_length(), n_slots)
                for b in range(1, n_slots + 1)})


def compile_budgets(engine) -> Dict[str, int]:
    """Per-jit upper bounds on distinct abstract signatures (PR 2's
    contract).  Prefill budgets exist only under bucketing — with it off,
    every distinct prompt length legitimately compiles."""
    out: Dict[str, int] = {}
    if engine.bucketing.enabled:
        shapes = engine.bucketing.max_traces() * _batch_buckets(
            engine.n_slots)
        out["prefill"] = shapes
        if engine.spec is not None:
            out["draft_prefill"] = shapes
    if getattr(engine, "chunked", None) is not None:
        # chunk jits have a fixed token axis (chunk_tokens); only the
        # batch bucket varies, and the chunk position is a traced scalar
        out["chunk_prefill"] = _batch_buckets(engine.n_slots)
        if engine.spec is not None:
            out["draft_chunk_prefill"] = _batch_buckets(engine.n_slots)
    # decode: the batched step shape plus the batch-1 resume replay
    out["decode"] = 2
    if engine.spec is not None:
        out["draft_decode"] = 2
        # one verify span shape per distinct γ the engine may run — the
        # degradation ladder's spec_half rung adds ceil(γ/2)
        out["verify"] = max(1, len(getattr(engine, "verify_gammas",
                                           {engine.spec.gamma})))
    return out


def trace_counts(engine) -> Dict[str, int]:
    out = {"prefill": engine.prefill_traces,
           "decode": engine.decode_traces}
    if engine.spec is not None:
        out.update(draft_prefill=engine.draft_prefill_traces,
                   draft_decode=engine.draft_decode_traces,
                   verify=engine.verify_traces)
    if getattr(engine, "chunked", None) is not None:
        out["chunk_prefill"] = engine.chunk_prefill_traces
        if engine.spec is not None:
            out["draft_chunk_prefill"] = engine.draft_chunk_prefill_traces
    return out


def _pool_slice_elems(engine) -> Optional[int]:
    """Element count of one layer's gathered int8 pool view — the widest
    s8->f32 convert legal on the decode path.  None when the engine holds
    no int8 pages (nothing to upcast)."""
    if getattr(engine, "kv_dtype", None) != "int8":
        return None
    best: Optional[int] = None

    def visit(path, leaf):
        nonlocal best
        name = getattr(path[-1], "name", None)
        if name in _POOL_LEAVES and leaf.dtype == jnp.int8:
            feat = int(np.prod(leaf.shape[3:])) if leaf.ndim > 3 else 1
            n = engine.n_slots * engine.max_len * feat
            best = n if best is None else max(best, n)
        return leaf

    jax.tree_util.tree_map_with_path(visit, engine.cache)
    return best


def _cache_leaf_bytes(engine) -> int:
    best = 0
    for leaf in jax.tree_util.tree_leaves(engine.cache):
        best = max(best, int(leaf.size) * leaf.dtype.itemsize)
    return best


def lowered_decode_text(engine, interpret: bool = True) -> str:
    """Compiled HLO of the engine's decode step under kernel mode (the
    deployment path the gather/dtype contracts guard)."""
    with nn.quant_mode("kernel", interpret=interpret):
        return engine.lower_decode().compile().as_text()


def _mesh_model_parts(engine) -> int:
    if engine.mesh is None:
        return 1
    return int(dict(engine.mesh.shape).get("model", 1))


def engine_context(engine, dense_engine=None, *,
                   interpret: bool = True,
                   collective_budget_bytes: Optional[int] = None,
                   donation_expected: bool = False) -> Dict[str, Any]:
    """Build the full rule context from a live engine (and, optionally, a
    dense twin engine supplying the gather baseline)."""
    ctx: Dict[str, Any] = {
        "hlo": {"decode": lowered_decode_text(engine, interpret)},
        "plan": plan_stats(engine.params, n_slots=engine.n_slots),
        "cache_leaf_bytes": _cache_leaf_bytes(engine),
        "donation_expected": donation_expected,
        "sentinel": getattr(engine, "sentinel", None),
        "compile_budget": compile_budgets(engine),
        "trace_counts": trace_counts(engine),
    }
    thresh = weight_shard_threshold(engine.params, _mesh_model_parts(engine))
    if thresh is not None:
        ctx["weight_shard_bytes"] = thresh
    if collective_budget_bytes is not None:
        ctx["collective_budget_bytes"] = collective_budget_bytes
    pool = _pool_slice_elems(engine)
    if pool is not None:
        ctx["pool_slice_elems"] = pool
    if dense_engine is not None:
        ctx["dense_hlo"] = {
            "decode": lowered_decode_text(dense_engine, interpret)}
    return ctx


def dense_twin_engine(engine):
    """A twin engine over the dequantized weights, matched on everything
    that shapes the decode HLO (slots, cache layout, mesh)."""
    from repro.serve.engine import ServingEngine
    kw: Dict[str, Any] = {}
    if engine._paged:
        kw = dict(kv_layout="paged", page_size=engine.page_size,
                  kv_pages=engine.n_pages, kv_dtype=engine.kv_dtype)
    return ServingEngine(
        dense_twin_params(engine.params), engine.cfg,
        n_slots=engine.n_slots, max_len=engine.max_len,
        dtype=engine._cache_dtype, prepare=False, mesh=engine.mesh,
        guards=engine.guards, **kw)


def verify_engine(engine, dense_engine=None, *,
                  rules: Optional[List[Rule]] = None,
                  with_baseline: bool = True,
                  interpret: bool = True,
                  raise_on_error: bool = True,
                  subject: str = "engine") -> Report:
    """Run the compiled-artifact + trace rules against a live engine;
    raises ``ContractViolation`` on ERROR findings (the
    ``verify_contracts=True`` init hook).  ``with_baseline`` builds the
    dense twin for the gather-parity rule when the caller did not pass
    ``dense_engine`` and the params hold plans."""
    if (dense_engine is None and with_baseline
            and plan_stats(engine.params)["has_plans"]):
        dense_engine = dense_twin_engine(engine)
    ctx = engine_context(engine, dense_engine, interpret=interpret)
    report = run_rules(rules if rules is not None
                       else list(HLO_RULES) + list(TRACE_RULES),
                       ctx, subject=subject)
    if raise_on_error and not report.clean:
        raise ContractViolation(report)
    return report
