"""Mesh context + logical-axis sharding constraints.

Models are written against two *logical* axes — "dp" (data parallel) and
"model" (tensor parallel) — which map onto whatever physical mesh is
active: ("data", "model") single-pod, ("pod", "data", "model") multi-pod
("dp" then spans pod x data).  `constrain` is the single entry point model
code uses; it silently no-ops without an active mesh (eager calibration,
single-device tests) and *drops any logical axis that does not divide the
corresponding array dimension*, so layer code can state its preferred
sharding unconditionally and stay shape-generic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

_STATE = threading.local()

# logical axis name -> physical mesh axes (in priority order; only axes
# present in the active mesh are used)
_LOGICAL = {
    "dp": ("pod", "data"),
    "model": ("model",),
    "dp+model": ("pod", "data", "model"),
}


def get_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[jax.sharding.Mesh]):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def physical_axes(mesh, logical: Optional[str]) -> Tuple[str, ...]:
    """Physical mesh axes backing a logical axis name (may be empty)."""
    if logical is None:
        return ()
    return tuple(a for a in _LOGICAL[logical] if a in mesh.shape)


def _axis_size(mesh, logical: Optional[str]) -> int:
    size = 1
    for a in physical_axes(mesh, logical):
        size *= mesh.shape[a]
    return size


def spec_entry(mesh, logical: Optional[str]):
    """PartitionSpec entry for one logical axis (None / name / tuple)."""
    axes = physical_axes(mesh, logical)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """Apply a sharding constraint given per-dimension logical axis names
    ("dp" | "model" | "dp+model" | None).  Non-divisible axes are dropped;
    with no active mesh this is the identity."""
    mesh = get_mesh()
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    entries = []
    for dim, logical in zip(x.shape, logical_axes):
        size = _axis_size(mesh, logical)
        if logical is None or size <= 1 or dim % size != 0:
            entries.append(None)
        else:
            entries.append(spec_entry(mesh, logical))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*entries)))
