from . import compat, context, hlo_analysis, sharding  # noqa: F401
