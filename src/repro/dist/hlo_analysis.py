"""Loop/fusion-aware cost analysis over compiled HLO text.

XLA's own `compiled.cost_analysis()` counts a `while` body **once** (scan
bodies are the bulk of a transformer step, so it undercounts FLOPs by the
layer count) and reports fusion internals unevenly across backends.  This
analyzer walks the HLO call graph instead:

  * `while` bodies are multiplied by their trip count — taken from XLA's
    `known_trip_count` backend_config when present, else derived from the
    canonical `(iv = const; iv < K; iv += step)` cond/body pattern;
  * `fusion` / `call` / `map` / `reduce` sub-computations are charged once
    at each call site;
  * FLOPs count dot/convolution contractions only (2 * out_elems * K), so
    induction-variable arithmetic never pollutes the figure;
  * fused elementwise cost is tracked SEPARATELY (`elementwise_flops`):
    every arithmetic/transcendental elementwise instruction — including
    those inside fusion bodies, which XLA's cost model reports unevenly —
    charges `result_elems x op_weight` (1 for add/mul-class ops, 4 for
    divides/roots, 8 for transcendentals), times the enclosing trip
    counts.  Memory-bound cells (decode attention softmax, dequant
    select-accumulate chains) are VPU-heavy, so roofline fractions need
    this term once the MXU share stops dominating;
  * HBM bytes are a result-bytes proxy per non-trivial instruction;
  * collective bytes are keyed per kind (`coll_all-reduce`, ...).

`analyze_hlo(text)` -> {"flops", "elementwise_flops", "hbm_bytes",
"collective_bytes", "coll_*"}.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose result bytes are pure bookkeeping, not HBM traffic
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "while", "conditional", "call",
             "partition-id", "replica-id"}

# Elementwise op weights (VPU ops per result element) for the fused
# elementwise cost.  Coarse three-tier model: cheap ALU ops cost 1,
# divides/roots 4, transcendentals 8 — the tiers matter for roofline
# fractions, the exact constants do not.  Data movement (copy, convert,
# broadcast, reshape, slice, ...) is excluded: it is HBM traffic, already
# covered by the result-bytes proxy, not arithmetic.
_ELEMWISE_COST = {}
for _op in ("add", "subtract", "multiply", "negate", "abs", "maximum",
            "minimum", "select", "compare", "and", "or", "xor", "not",
            "clamp", "floor", "ceil", "round-nearest-afz",
            "round-nearest-even", "sign", "shift-left",
            "shift-right-logical", "shift-right-arithmetic"):
    _ELEMWISE_COST[_op] = 1.0
for _op in ("divide", "remainder", "sqrt", "rsqrt", "cbrt"):
    _ELEMWISE_COST[_op] = 4.0
for _op in ("exponential", "exponential-minus-one", "log", "log-plus-one",
            "tanh", "logistic", "sine", "cosine", "tan", "atan2", "power",
            "erf"):
    _ELEMWISE_COST[_op] = 8.0

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
# dims may be dynamic ("<=8"): the bound is the right byte proxy
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,<=]*)\]")
_OP_RE = re.compile(r"^(?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,<=]*\](?:\{[^}]*\})?)"
                    r"\s+([a-z][a-z0-9\-]*)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_CALLEE_RE = re.compile(
    r"(?:condition|body|to_apply|calls|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_GTE_IDX_RE = re.compile(r"index=(\d+)")


class _Instr:
    __slots__ = ("name", "op", "line", "is_root")

    def __init__(self, name: str, op: str, line: str, is_root: bool):
        self.name = name
        self.op = op
        self.line = line
        self.is_root = is_root


def _shape_elems(dims: str) -> int:
    """Element count of one bracketed dim list.  Dynamic dims print as
    `<=N` — the bound is the right proxy for byte accounting.  Malformed
    fragments count as 0 elements rather than raising mid-scan."""
    n = 1
    for d in dims.split(","):
        d = d.strip()
        if not d:
            continue
        if d.startswith("<="):
            d = d[2:]
        if not d.isdigit():
            return 0
    # second pass so a malformed dim voids the whole product
    for d in dims.split(","):
        d = d.strip().lstrip("<=")
        if d:
            n *= int(d)
    return n


def _result_elems(line: str) -> int:
    """Element count of the result type (first shape token after '=')."""
    rhs = line.split("=", 1)[1].lstrip() if "=" in line else line
    m = _SHAPE_RE.search(rhs)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return 0
    return _shape_elems(m.group(2))


def _tuple_region(rhs: str) -> str:
    """The balanced leading tuple-type region of an instruction rhs —
    nested tuples `((f32[2], s32[]), f32[4])` keep every element (the old
    split-at-first-')' dropped everything after the inner close)."""
    depth = 0
    for i, ch in enumerate(rhs):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rhs[:i + 1]
    return rhs


def _result_bytes(line: str) -> int:
    """Bytes of the result type (first type token after '='); a tuple type
    sums its parts (nested tuples included).  `token[]` / `opaque[]` /
    unknown dtypes contribute 0 — bookkeeping types, not HBM traffic."""
    rhs = line.split("=", 1)[1].lstrip() if "=" in line else line
    if rhs.startswith("("):
        region = _tuple_region(rhs)
    else:
        region = rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(region):
        if dt not in _DTYPE_BYTES:
            continue
        total += _shape_elems(dims) * _DTYPE_BYTES[dt]
        if not rhs.startswith("("):
            break
    return total


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                current = m.group(1)
                comps[current] = []
                if line.strip().startswith("ENTRY"):
                    entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        opm = _OP_RE.match(rhs)
        op = opm.group(1) if opm else ""
        comps[current].append(
            _Instr(name, op, line, line.lstrip().startswith("ROOT")))
    comps["__entry__"] = comps.get(entry, [])
    return comps


def _dot_flops(line: str) -> float:
    """2 * out_elems * contracted_size for dot/convolution lines."""
    rhs = line.split("=", 1)[1]
    shapes = _SHAPE_RE.findall(rhs)
    if not shapes:
        return 0.0
    out_elems = _shape_elems(shapes[0][1])
    if "convolution" in rhs:
        # rhs operand (the kernel) fully contracts except its output-feature
        # dim; a robust proxy: 2 * out * (kernel_elems / out_features).
        if len(shapes) >= 3:
            out_feat = max(int(d) for d in shapes[0][1].split(",") if d) \
                if shapes[0][1] else 1
            k_elems = _shape_elems(shapes[2][1])
            return 2.0 * out_elems * max(k_elems // max(out_feat, 1), 1)
        return 2.0 * out_elems
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if not m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in shapes[1][1].split(",") if d] \
        if len(shapes) > 1 else []
    contracted = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(lhs_dims):
            contracted *= lhs_dims[d]
    return 2.0 * out_elems * contracted


def _find_instr(comp: List[_Instr], name: str) -> Optional[_Instr]:
    for ins in comp:
        if ins.name == name:
            return ins
    return None


def _derive_trip_count(comps, parent: List[_Instr], while_line: str,
                       cond_name: str) -> int:
    """Fallback when known_trip_count is absent: match the canonical
    `(iv = c0; iv < K; iv += s)` pattern across cond / init tuple."""
    cond = comps.get(cond_name)
    if not cond:
        return 1
    root = next((i for i in cond if i.is_root), None)
    if root is None or root.op != "compare" or "direction=LT" not in root.line:
        return 1
    # operands of the compare: gte(index=i) and a constant
    ops = re.findall(r"%([\w.\-]+)[,)]", root.line.split("compare(", 1)[1])
    iv_index = bound = None
    for name in ops:
        ins = _find_instr(cond, name)
        if ins is None:
            continue
        if ins.op == "get-tuple-element":
            m = _GTE_IDX_RE.search(ins.line)
            iv_index = int(m.group(1)) if m else None
        elif ins.op == "constant":
            m = _CONST_RE.search(ins.line)
            bound = int(m.group(1)) if m else None
    if iv_index is None or bound is None:
        return 1
    # init: while(%tuple) -> tuple element iv_index in the parent computation
    m = re.search(r"while\([^%]*%([\w.\-]+)\)", while_line)
    start = 0
    if m:
        tup = _find_instr(parent, m.group(1))
        if tup is not None and tup.op == "tuple":
            elems = re.findall(r"%([\w.\-]+)[,)]",
                               tup.line.split("tuple(", 1)[1])
            if iv_index < len(elems):
                src = _find_instr(parent, elems[iv_index])
                # chase one copy
                if src is not None and src.op == "copy":
                    m2 = re.search(r"copy\([^%]*%([\w.\-]+)\)", src.line)
                    src = _find_instr(parent, m2.group(1)) if m2 else src
                if src is not None and src.op == "constant":
                    mc = _CONST_RE.search(src.line)
                    if mc:
                        start = int(mc.group(1))
    return max(bound - start, 0)


def collective_instructions(text: str):
    """Every collective instruction in the module (all computations, loop
    bodies included, each listed ONCE — no trip-count multiplication) as
    ``[(kind, result_bytes), ...]``.

    `analyze_hlo` aggregates collective bytes; this keeps them
    per-instruction so tests can assert *size classes* — e.g. the
    multi-device serving test asserts no single all-gather result is
    weight-sized (decode must move activations between shards, never the
    sharded CLAQ plan payload)."""
    out = []
    for comp, instrs in _parse_computations(text).items():
        if comp == "__entry__":
            continue
        for ins in instrs:
            if ins.op in _COLLECTIVES:
                out.append((ins.op, _result_bytes(ins.line)))
    return out


def gather_instructions(text: str):
    """Every gather / dynamic-slice instruction in the module (all
    computations, fusion and loop bodies included, each listed ONCE) as
    ``[(kind, result_bytes), ...]`` — the indexed-load counterpart of
    `collective_instructions`.

    Tests use this to pin down the decode hot path's indexing cost: the
    fused CLAQ matmul must add ZERO gather instructions over a dense
    model's decode step when its plans are x-aligned (the plan folded the
    stripe permutation away entirely, DESIGN.md §9), and for permuted
    (mixed-precision) plans every added gather must be a VMEM-tile-sized
    in-kernel take — never an activation-sized XLA gather.  `dynamic-slice`
    is reported too (cache reads, in-kernel block fetches) so callers can
    distinguish block fetches from true gathers; note ``all-gather`` is a
    collective, not counted here."""
    out = []
    for comp, instrs in _parse_computations(text).items():
        if comp == "__entry__":
            continue
        for ins in instrs:
            if ins.op in ("gather", "dynamic-slice"):
                out.append((ins.op, _result_bytes(ins.line)))
    return out


def copy_instructions(text: str):
    """Every `copy` / `copy-start` instruction in the module (all
    computations, each listed ONCE) as ``[(op, result_bytes), ...]`` —
    the contract checker's raw material for the whole-cache-copy audit
    (HLO-CP1): a copy whose result is cache-sized inside the decode step
    means the cache round-trips HBM instead of being updated in place."""
    out = []
    for comp, instrs in _parse_computations(text).items():
        if comp == "__entry__":
            continue
        for ins in instrs:
            if ins.op in ("copy", "copy-start"):
                out.append((ins.op, _result_bytes(ins.line)))
    return out


_CONVERT_OPERAND_RE = re.compile(r"convert\(\s*([a-z][a-z0-9]*)\[([0-9,<=]*)\]")


def convert_instructions(text: str):
    """Every `convert` instruction as ``[(src_dtype, dst_dtype, elems),
    ...]`` (each listed once, fusion bodies included) — dtype-discipline
    rules key off widening converts (s8 -> f32 of a pool-sized array means
    an int8 page path silently upcasted, HLO-DT1)."""
    out = []
    for comp, instrs in _parse_computations(text).items():
        if comp == "__entry__":
            continue
        for ins in instrs:
            if ins.op != "convert":
                continue
            rhs = ins.line.split("=", 1)[1].lstrip()
            mdst = _SHAPE_RE.search(rhs)
            msrc = _CONVERT_OPERAND_RE.search(rhs)
            if not mdst or not msrc:
                continue
            out.append((msrc.group(1), mdst.group(1),
                        _shape_elems(mdst.group(2))))
    return out


_HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "send-done", "recv",
                      "recv-done")
_HOST_CUSTOM_CALL_RE = re.compile(
    r'custom_call_target="[^"]*(?:Host|host_compute|PinToHost|Callback'
    r'|callback)[^"]*"')


def host_transfer_instructions(text: str):
    """Every instruction that moves data between device and host inside
    the module — infeed/outfeed/send/recv plus host custom-calls — as
    ``[(op, result_bytes), ...]``.  The compiled decode/verify step loop
    must contain NONE (HLO-HT1): a host transfer per step serializes the
    loop on PCIe latency."""
    out = []
    for comp, instrs in _parse_computations(text).items():
        if comp == "__entry__":
            continue
        for ins in instrs:
            if ins.op in _HOST_TRANSFER_OPS:
                out.append((ins.op, _result_bytes(ins.line)))
            elif (ins.op == "custom-call"
                  and _HOST_CUSTOM_CALL_RE.search(ins.line)):
                out.append(("custom-call", _result_bytes(ins.line)))
    return out


_ALIAS_PAIR_RE = re.compile(
    r"\{([0-9,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{([0-9,\s]*)\}")


def donation_aliases(text: str):
    """Input/output aliases from the module header's `input_output_alias`
    attribute as ``[(param_index, output_index_tuple), ...]`` — empty when
    nothing is donated.  The attribute's value nests braces
    (``{ {0}: (1, {}, must-alias) }``), so the region is taken by balanced
    scan, not regex.  The donation audit (HLO-DN1) checks that cache
    buffers are donated into the step jits where the platform supports
    buffer donation (otherwise every step allocates a second cache)."""
    head = text.split("\n\n", 1)[0]
    start = head.find("input_output_alias=")
    if start < 0:
        return []
    region = head[head.index("{", start):]
    depth = 0
    for i, ch in enumerate(region):
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                region = region[:i + 1]
                break
    out = []
    for mo in _ALIAS_PAIR_RE.finditer(region):
        out_idx = tuple(int(v) for v in mo.group(1).split(",") if v.strip())
        out.append((int(mo.group(2)), out_idx))
    return out


def analyze_hlo(text: str) -> Dict[str, float]:
    comps = _parse_computations(text)
    entry = comps.get("__entry__", [])

    def walk(comp: List[_Instr]) -> Dict[str, float]:
        acc: Dict[str, float] = {"flops": 0.0, "elementwise_flops": 0.0,
                                 "hbm_bytes": 0.0, "collective_bytes": 0.0}
        for ins in comp:
            mult = 1
            callees = _CALLEE_RE.findall(ins.line)
            if ins.op == "while":
                mtc = _TRIP_RE.search(ins.line)
                if mtc:
                    mult = int(mtc.group(1))
                else:
                    cond = next((c for c in callees if c in comps), None)
                    # condition= is listed first in HLO text
                    cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                    cond = cm.group(1) if cm else cond
                    mult = _derive_trip_count(comps, comp, ins.line, cond)
            if ins.op in ("dot", "convolution"):
                acc["flops"] += _dot_flops(ins.line)
            cost = _ELEMWISE_COST.get(ins.op)
            if cost is not None:
                acc["elementwise_flops"] += cost * _result_elems(ins.line)
            if ins.op not in _FREE_OPS:
                acc["hbm_bytes"] += _result_bytes(ins.line)
            if ins.op in _COLLECTIVES:
                b = _result_bytes(ins.line)
                acc[f"coll_{ins.op}"] = acc.get(f"coll_{ins.op}", 0.0) \
                    + b * mult
                acc["collective_bytes"] += b * mult
            for callee in callees:
                sub = comps.get(callee)
                if sub is None:
                    continue
                inner = walk(sub)
                for k, v in inner.items():
                    # fusion internals execute their flops/collectives but
                    # materialize only the fusion root — the root's bytes
                    # were already charged at this call site
                    if ins.op == "fusion" and k == "hbm_bytes":
                        continue
                    acc[k] = acc.get(k, 0.0) + v * mult
        return acc

    return walk(entry)
