"""Named sharding rules for params / batches / caches.

A *rule* is `rule(name, shape, cfg, ax) -> PartitionSpec`, applied per leaf
by `with_shardings` (ShapeDtypeStruct trees, dry-run lowering) or
`tree_shardings` (concrete trees, device_put).  Rules are divisibility-
guarded so the same rule set covers every arch family: a dimension is only
sharded when the mesh axis divides it, otherwise it stays replicated.

CLAQ quantized leaves are NOT per-leaf shardable: a
`PreparedQuantizedTensor` is a *unit* — packed code planes, per-group
codebooks, outlier tables, and one fused gather index whose layouts are
coupled (kernels/plan.py).  Splitting its leaves independently (the
generic largest-dim pick) would shard planes along K, the gather index
along its only axis, and codebooks along the centroid axis — tearing the
plan apart.  `spec_for_quantized` shards the unit along N instead: planes
split on their packed-row axis (whole (bn, bk) tiles per shard, guarded by
`PreparedQuantizedTensor.shards_whole_tiles`), everything K-indexed or
row-index-valued replicated.  `tree_shardings` / `with_shardings` route
quantized units through this rule automatically.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from . import context as dctx


class MeshAxes:
    """Resolved logical axes of a mesh ("dp" spans pod x data when present)."""

    def __init__(self, mesh):
        self.mesh = mesh
        self.dp_axes: Tuple[str, ...] = dctx.physical_axes(mesh, "dp")
        self.model_axes: Tuple[str, ...] = dctx.physical_axes(mesh, "model")
        self.dp_size: int = dctx._axis_size(mesh, "dp")
        self.model_size: int = dctx._axis_size(mesh, "model")

    @property
    def dp(self):
        return dctx.spec_entry(self.mesh, "dp")

    @property
    def model(self):
        return dctx.spec_entry(self.mesh, "model")


def _shardable(dim: int, size: int) -> bool:
    return size > 1 and dim >= size and dim % size == 0


def _quantized_types():
    """Lazy import: dist must stay importable before kernels/core finish
    initializing (core pulls dist.compat for the sharded quantizer)."""
    from repro.core.quantized import QuantizedTensor
    from repro.kernels.plan import PreparedQuantizedTensor
    return QuantizedTensor, PreparedQuantizedTensor


# leaf fields of QuantizedTensor / PreparedQuantizedTensor / PlanGroup — a
# per-leaf rule must never invent a spec for these (see spec_for_quantized)
_QUANT_LEAF_MARKERS = (".groups[", ".planes[", ".gather_idx", ".codebook",
                      ".out_idx", ".out_val", ".stripes[", ".col_perm",
                      ".out_count", ".packed", ".x_idx")


def spec_for_quantized(q, ax: MeshAxes):
    """Spec *tree* (same pytree structure as `q`) for one quantized unit.

    PreparedQuantizedTensor: sharded as a unit along N over "model" —
      * code planes split on their packed-row axis (axis -2; one packed
        word = 32/width consecutive rows of one column, and bn is a
        multiple of the 32-row word, so a bn-aligned split is word-aligned
        and every shard keeps whole (bn, bk) tiles);
      * `codebook` / `out_idx` / `out_val` are K-indexed (and outlier idx
        *values* are global row numbers), `gather_idx` / the per-group
        `x_idx` block tables index the activation's K axis — all
        replicated;
      * guarded by `shards_whole_tiles(model_size)`: when the tile count
        does not divide, the WHOLE unit stays replicated — never torn;
      * stacked (L, ...) / (L, E, ...) leaves (launch.quantize stacks
        per-layer results; the plan vmaps, so meta is per-matrix) shard
        the same axis -2, leading stack dims untouched.

    Raw QuantizedTensor: replicated as a unit.  It is the pre-deployment
    format (3-bit packs two planes concatenated along packed rows, so no
    row split is tile-clean); serving prepares leaves before sharding, and
    the row-sharded *quantizer* manages its own mesh explicitly.
    """
    QuantizedTensor, PreparedQuantizedTensor = _quantized_types()

    if (isinstance(q, PreparedQuantizedTensor)
            and ax.model_size > 1
            and q.shards_whole_tiles(ax.model_size)):
        model = ax.model

        def one(path, leaf):
            field = getattr(path[-2] if len(path) > 1 else path[-1],
                            "name", None)
            if field == "planes":
                ndim = np.ndim(leaf)
                entries = [None] * ndim
                entries[ndim - 2] = model
                return PartitionSpec(*entries)
            return PartitionSpec()

        return jax.tree_util.tree_map_with_path(one, q)

    if not isinstance(q, (QuantizedTensor, PreparedQuantizedTensor)):
        raise TypeError(f"not a quantized unit: {type(q)}")
    return jax.tree_util.tree_map(lambda _: PartitionSpec(), q)


def spec_for_param(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """Tensor-parallel params: shard the largest model-divisible dimension
    over "model"; everything else replicated.  Covers dense kernels
    (in, out), stacked (L, in, out), and embeddings (vocab, d).  Quantized
    leaves are NOT covered here — `tree_shardings` / `with_shardings`
    route whole QuantizedTensor / PreparedQuantizedTensor units through
    `spec_for_quantized`; if a caller maps this rule over raw quantized
    internals anyway, they are replicated rather than torn."""
    if not shape or ax.model_size <= 1:
        return PartitionSpec()
    if any(m in name for m in _QUANT_LEAF_MARKERS):
        return PartitionSpec()
    candidates = [d for d, dim in enumerate(shape)
                  if _shardable(dim, ax.model_size)]
    if not candidates:
        return PartitionSpec()
    best = max(candidates, key=lambda d: shape[d])
    entries = [None] * len(shape)
    entries[best] = ax.model
    return PartitionSpec(*entries)


def spec_for_param_serve(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """Serving keeps the training TP layout (decode is weight-bound; the
    all-gather of a replicated layout would dominate the step)."""
    return spec_for_param(name, shape, cfg, ax)


def spec_for_batch(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """Batches shard their leading (global batch) dimension over "dp"."""
    if not shape or not _shardable(shape[0], ax.dp_size):
        return PartitionSpec()
    return PartitionSpec(ax.dp)


def spec_for_cache(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """KV/state caches in the engine/dry-run layout: leaves are stacked
    along a leading layer axis — (L, B, ...) data, (L, B) fill counters —
    so the batch (serving slot) axis is axis 1, sharded over "dp".  The KV
    head axis of plain attention caches ((L, B, S, KH, D) leaves named
    k/v, and the encdec cross_k/cross_v banks) additionally shards over
    "model", matching the head-parallel attention constraint; every other
    axis (layer, sequence, feature / state dims that decode indexes
    dynamically) stays replicated."""
    if not shape:
        return PartitionSpec()
    field = name.rsplit(".", 1)[-1] if "." in name else name
    # Paged layout (models/layers.py PagedKVCache, mla.py PagedMLACache):
    # pool leaves are (L, n_pages+1, page_size, ...) — axis 1 is the PAGE
    # axis, not a slot axis, and any slot's table row may name any page, so
    # pages shard over "dp" (gathers cross shards; XLA inserts the
    # collective) while the tiny (L, B, max_pages) tables replicate —
    # the default batch-axis rule would wrongly split their slot axis.
    if field == "table":
        return PartitionSpec()
    entries = [None] * len(shape)
    if field in ("kp", "vp", "cp", "pp",
                 "k_scale", "v_scale", "c_scale", "p_scale"):
        if len(shape) >= 2 and _shardable(shape[1], ax.dp_size):
            entries[1] = ax.dp
        if (field in ("kp", "vp") and len(shape) == 5
                and _shardable(shape[-2], ax.model_size)):
            entries[-2] = ax.model
        return PartitionSpec(*entries)
    batch_axis = 1 if len(shape) >= 2 else 0
    if _shardable(shape[batch_axis], ax.dp_size):
        entries[batch_axis] = ax.dp
    if (field in ("k", "v", "cross_k", "cross_v") and len(shape) == 5
            and _shardable(shape[-2], ax.model_size)):
        entries[-2] = ax.model
    return PartitionSpec(*entries)


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


def _is_quantized_unit(leaf) -> bool:
    QuantizedTensor, PreparedQuantizedTensor = _quantized_types()
    return isinstance(leaf, (QuantizedTensor, PreparedQuantizedTensor))


def tree_shardings(tree, rule, cfg, mesh):
    """Tree of NamedShardings for `tree` (concrete or SDS leaves).
    Quantized units expand to a matching sub-tree via spec_for_quantized,
    so the result stays leaf-congruent with `tree` (device_put-ready)."""
    ax = MeshAxes(mesh)

    def one(path, leaf):
        if _is_quantized_unit(leaf):
            return jax.tree_util.tree_map(
                lambda spec: NamedSharding(mesh, spec),
                spec_for_quantized(leaf, ax))
        return NamedSharding(mesh, rule(_leaf_name(path), np.shape(leaf),
                                        cfg, ax))

    return jax.tree_util.tree_map_with_path(one, tree,
                                            is_leaf=_is_quantized_unit)


def with_shardings(tree, rule, cfg, mesh):
    """ShapeDtypeStruct tree re-annotated with NamedShardings (dry-run)."""
    ax = MeshAxes(mesh)

    def sds(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    def one(path, leaf):
        if _is_quantized_unit(leaf):
            return jax.tree_util.tree_map(sds, leaf,
                                          spec_for_quantized(leaf, ax))
        return sds(leaf, rule(_leaf_name(path), leaf.shape, cfg, ax))

    return jax.tree_util.tree_map_with_path(one, tree,
                                            is_leaf=_is_quantized_unit)
