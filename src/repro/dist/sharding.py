"""Named sharding rules for params / batches / caches.

A *rule* is `rule(name, shape, cfg, ax) -> PartitionSpec`, applied per leaf
by `with_shardings` (ShapeDtypeStruct trees, dry-run lowering) or
`tree_shardings` (concrete trees, device_put).  Rules are divisibility-
guarded so the same rule set covers every arch family and the CLAQ
QuantizedTensor leaves (packed planes / codebooks / outlier tables) without
per-arch special cases: a dimension is only sharded when the mesh axis
divides it, otherwise it stays replicated.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from . import context as dctx


class MeshAxes:
    """Resolved logical axes of a mesh ("dp" spans pod x data when present)."""

    def __init__(self, mesh):
        self.mesh = mesh
        self.dp_axes: Tuple[str, ...] = dctx.physical_axes(mesh, "dp")
        self.model_axes: Tuple[str, ...] = dctx.physical_axes(mesh, "model")
        self.dp_size: int = dctx._axis_size(mesh, "dp")
        self.model_size: int = dctx._axis_size(mesh, "model")

    @property
    def dp(self):
        return dctx.spec_entry(self.mesh, "dp")

    @property
    def model(self):
        return dctx.spec_entry(self.mesh, "model")


def _shardable(dim: int, size: int) -> bool:
    return size > 1 and dim >= size and dim % size == 0


def spec_for_param(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """Tensor-parallel params: shard the largest model-divisible dimension
    over "model"; everything else replicated.  Covers dense kernels
    (in, out), stacked (L, in, out), embeddings (vocab, d), and quantized
    leaves (packed planes / codebooks / outlier tables) uniformly."""
    if not shape or ax.model_size <= 1:
        return PartitionSpec()
    candidates = [d for d, dim in enumerate(shape)
                  if _shardable(dim, ax.model_size)]
    if not candidates:
        return PartitionSpec()
    best = max(candidates, key=lambda d: shape[d])
    entries = [None] * len(shape)
    entries[best] = ax.model
    return PartitionSpec(*entries)


def spec_for_param_serve(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """Serving keeps the training TP layout (decode is weight-bound; the
    all-gather of a replicated layout would dominate the step)."""
    return spec_for_param(name, shape, cfg, ax)


def spec_for_batch(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """Batches shard their leading (global batch) dimension over "dp"."""
    if not shape or not _shardable(shape[0], ax.dp_size):
        return PartitionSpec()
    return PartitionSpec(ax.dp)


def spec_for_cache(name: str, shape, cfg, ax: MeshAxes) -> PartitionSpec:
    """KV/state caches: batch dim over "dp"; the head/state dim (axis -2 of
    rank>=3 leaves, e.g. (B, S, KH, D) kv or (B, H, N, N) wkv state) over
    "model" when divisible."""
    if not shape:
        return PartitionSpec()
    entries = [None] * len(shape)
    if _shardable(shape[0], ax.dp_size):
        entries[0] = ax.dp
    if len(shape) >= 3 and _shardable(shape[-2], ax.model_size):
        entries[-2] = ax.model
    return PartitionSpec(*entries)


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


def tree_shardings(tree, rule, cfg, mesh):
    """Tree of NamedShardings for `tree` (concrete or SDS leaves)."""
    ax = MeshAxes(mesh)

    def one(path, leaf):
        return NamedSharding(mesh, rule(_leaf_name(path), np.shape(leaf),
                                        cfg, ax))

    return jax.tree_util.tree_map_with_path(one, tree)


def with_shardings(tree, rule, cfg, mesh):
    """ShapeDtypeStruct tree re-annotated with NamedShardings (dry-run)."""
    ax = MeshAxes(mesh)

    def one(path, leaf):
        spec = rule(_leaf_name(path), leaf.shape, cfg, ax)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)
