"""Version shims for jax APIs that moved between releases.

`shard_map` graduated from `jax.experimental.shard_map` to `jax.shard_map`,
and its replication-check kwarg was renamed `check_rep` -> `check_vma` along
the way.  Callers import it from here and always pass the new-style kwargs.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """`jax.shard_map` with new-style kwargs on any supported jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
