"""Fault-tolerant checkpointing: atomic, async, self-validating, elastic.

  * **atomic** — writes go to `<step>.tmp/` and are renamed into place only
    after the manifest (with per-leaf checksums) is fsynced; a crash
    mid-write can never produce a checkpoint that restore() would accept.
  * **async** — `save(..., blocking=False)` hands the host arrays to a
    background thread; the training step is never blocked on disk
    (straggler mitigation: checkpoint I/O off the critical path).
  * **self-validating restore** — `latest_step()` walks checkpoints newest
    to oldest and returns the first whose manifest and checksums verify, so
    a torn write falls back to the previous good one; `restore()` itself
    re-verifies every leaf's content hash against the manifest and fails
    fast with the offending leaf path (`CheckpointCorrupt`) instead of
    serving silently corrupted quantized planes.
  * **elastic / mesh-agnostic** — leaves are stored as host numpy arrays
    keyed by pytree path; `restore(template)` re-materializes them into any
    template (fresh device layout / different mesh), so jobs can restart on
    a different topology.  (At 1000+ nodes you'd write per-shard files; the
    format keeps a `shard` field for that extension.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leafname(path) -> str:
    return jax.tree_util.keystr(path)


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


class CheckpointCorrupt(RuntimeError):
    """A stored leaf's content hash disagrees with the manifest written at
    save time.  ``leaf`` names the offending pytree path, so the failure
    points at the corrupted plane instead of surfacing later as silently
    wrong numerics."""

    def __init__(self, message: str, leaf: str, step: int):
        super().__init__(message)
        self.leaf = leaf
        self.step = step


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = True,
             extra: Optional[Dict] = None):
        """state: any pytree (params / opt state / data cursor / rng)."""
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_leafname(p), np.ascontiguousarray(jax.device_get(x)))
                for p, x in flat]
        if blocking:
            self._write(step, host, extra)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host, extra):
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "shard": 0, "num_shards": 1,
                    "extra": extra or {}, "leaves": {}}
        arrays = {}
        for i, (name, arr) in enumerate(host):
            key = f"leaf_{i:05d}"
            dtype_str = str(arr.dtype)
            # npz can't serialize ml_dtypes (bfloat16 etc.) — store a u8 view
            stored = arr
            if arr.dtype.kind not in "biufc":
                stored = arr.view(np.uint8)
            arrays[key] = stored
            manifest["leaves"][name] = {
                "key": key, "shape": list(arr.shape), "dtype": dtype_str,
                "crc": _crc(stored)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self._list_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _list_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return out

    def _valid(self, step: int) -> bool:
        path = os.path.join(self.directory, f"step_{step:010d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "arrays.npz")) as z:
                for name, info in manifest["leaves"].items():
                    arr = z[info["key"]]
                    if _crc(arr) != info["crc"]:
                        return False
            return True
        except Exception:
            return False

    def latest_step(self) -> Optional[int]:
        for s in sorted(self._list_steps(), reverse=True):
            if self._valid(s):
                return s
        return None

    def restore(self, step: int, template: Any) -> Any:
        """Fill `template`'s leaves (by pytree path) from the checkpoint,
        verifying each leaf's content hash against the manifest first —
        a mismatch raises ``CheckpointCorrupt`` naming the leaf path."""
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        z = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            name = _leafname(p)
            if name not in manifest["leaves"]:
                raise KeyError(f"checkpoint missing leaf {name}")
            info = manifest["leaves"][name]
            arr = z[info["key"]]
            # the manifest CRC was taken over the STORED bytes (possibly a
            # u8 view of an ml_dtypes array) — verify before the view back
            if _crc(arr) != info["crc"]:
                raise CheckpointCorrupt(
                    f"checkpoint step {step}: stored bytes of leaf {name} "
                    f"do not match the manifest content hash "
                    f"(crc {_crc(arr)} != {info['crc']}) — refusing to "
                    f"serve a corrupted plane", leaf=name, step=step)
            if str(arr.dtype) != info["dtype"]:
                arr = arr.view(np.dtype(info["dtype"])).reshape(info["shape"])
            if hasattr(leaf, "dtype") and str(leaf.dtype) != str(arr.dtype):
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, template: Any):
        s = self.latest_step()
        if s is None:
            return None, None
        return s, self.restore(s, template)
