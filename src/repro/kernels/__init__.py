# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from .plan import (  # noqa: F401
    PlanGroup, PreparedQuantizedTensor, prepare_for_inference, prepare_tree,
)
