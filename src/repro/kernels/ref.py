"""Pure-jnp oracles for the Pallas kernels (tests assert allclose vs these)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantized import QuantizedTensor

Array = jax.Array


def ref_dequant(packed: Array, codebook: Array, bits: int, n: int) -> Array:
    """packed (packed_rows, K) + codebook (K, 2**bits) -> W (n, K)."""
    codes = packing.unpack_codes(packed, bits, n)
    return jnp.take_along_axis(codebook.T.astype(jnp.float32), codes, axis=0)


def ref_apply_outliers(W: Array, out_idx: Optional[Array],
                       out_val: Optional[Array]) -> Array:
    """Override W[idx[r,k], k] = val[r,k] where idx >= 0 (kernel semantics).

    Invalid slots (idx < 0) are routed out of bounds and dropped
    (mode='drop'), so they can never collide with a genuine row-0 outlier."""
    if out_idx is None or out_idx.shape[0] == 0:
        return W
    n, k_dim = W.shape
    safe = jnp.where(out_idx >= 0, out_idx, n)   # n = out of bounds -> drop
    colk = jnp.broadcast_to(jnp.arange(k_dim)[None, :], out_idx.shape)
    return W.at[safe, colk].set(out_val, mode="drop")


def ref_dequant_matmul(
    x: Array, packed: Array, codebook: Array,
    out_idx: Optional[Array], out_val: Optional[Array],
    *, bits: int, n: int,
) -> Array:
    """Oracle for kernels.dequant_matmul (single stripe): y = x @ W^T."""
    W = ref_dequant(packed, codebook, bits, n)
    W = ref_apply_outliers(W, out_idx, out_val)
    return jnp.dot(x.astype(jnp.float32), W.T,
                   preferred_element_type=jnp.float32)


def ref_qmatmul(x: Array, qt: QuantizedTensor) -> Array:
    """Oracle for the full multi-stripe QuantizedTensor matmul: x @ deq^T."""
    W = qt.dequantize(jnp.float32)
    y = jnp.einsum("...k,nk->...n", x.astype(jnp.float32), W)
    return y


def ref_act_int8_bound(x: Array, W: Array) -> Array:
    """Per-output-element error bound of the int8 activation path vs f32
    (DESIGN.md §9): quantization perturbs each activation by at most
    scale/2 (round-to-nearest, absmax scaling never clips), so
    |Δy[m, n]| <= scale_m / 2 * ||W[n, :]||_1.  x (..., K), W (N, K) ->
    bound (..., N).  The bound covers quantization error only; callers add
    a small epsilon for f32 accumulation-order noise."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    return 0.5 * scale * jnp.sum(jnp.abs(W.astype(jnp.float32)), axis=1)
