"""Ahead-of-time inference plans: compile a QuantizedTensor into the exact
padded, fused layout the Pallas dequant-GEMM consumes.

`kernels/ops.qmatmul` on a raw QuantizedTensor re-derives padding, plane
splits, the stripe column permutation, and outlier validity masks inside
every traced matmul, and issues one `pallas_call` per stripe.  All of that
is per-*tensor* work, not per-*token* work.  `prepare_for_inference` does
it once, at load/quantize time:

  (a) code planes, codebooks, and outlier tables are padded to kernel
      block multiples (K to the group's bk, N to bn) — padding K slots
      carry zero codebooks and idx=-1 outliers, so they contribute exactly
      zero and never need masking at matmul time;
  (b) the per-stripe column slicing is folded into ONE gather index over
      the activation's K axis, kept in two forms: `gather_idx` (flat, the
      XLA `jnp.take(..., mode="fill")` path and the dequantize oracle) and
      per-group `x_idx` per-bk-block tables the kernel consumes directly —
      plus a static per-group alignment analysis: when a group's fused K
      order is exactly original column order (single-bit-width tensors;
      `build_quantized_tensor` emits an identity permutation), `x_start`
      is set and the kernel fetches raw x blocks with NO indexing at all
      (DESIGN.md §9);
  (c) outlier slots are pre-validated: the per-column count is converted
      to idx=-1 padding once, instead of a mask per matmul;
  (d) stripes are grouped by bit-width and concatenated along K, so a
      matmul issues ONE fused `pallas_call` per distinct bit-width
      (typically 1-3) instead of one per stripe, with each group's output
      accumulated into the same VMEM-resident block via the kernel's `acc`
      operand.

The prepared tensor is a registered pytree: it can replace QuantizedTensor
leaves inside a params tree and flow through jit/pjit with zero per-trace
preparation (serve/engine.py prepares every leaf at construction).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.quantized import QuantizedTensor

from . import dequant_matmul as dm

Array = jax.Array


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class PlanGroup:
    """All same-bit-width stripes, concatenated along K and block-padded."""
    planes: Tuple[Array, ...]   # per plane-width: (n_padded//cpw, k_padded) u32
    codebook: Array             # (k_padded, 2**bits) f32, zero at padding
    out_idx: Optional[Array]    # (k_out, k_padded) int32, -1 = no outlier
    out_val: Optional[Array]    # (k_out, k_padded) f32
    x_idx: Optional[Array]      # (k_padded//bk, bk) int32 per-block x column
    #                             tables (None when x_start is set)
    bits: int                   # static
    bk: int                     # static — K block size for this group
    k_cols: int                 # static — unpadded fused K of the group
    x_start: Optional[int] = None   # static — set iff the fused K order is
    #                             original columns [x_start, x_start+k_cols)
    #                             with x_start % bk == 0: the kernel reads
    #                             raw x blocks, no per-column indexing

    @property
    def k_padded(self) -> int:
        return self.codebook.shape[0]

    def unpack_codes(self, rows: int) -> Array:
        """Recombine the group's split planes -> (rows, k_padded) int32."""
        codes = None
        shift = 0
        for w, p in zip(packing.plane_widths(self.bits), self.planes):
            part = packing._unpack_plane(p, w, rows) << shift
            codes = part if codes is None else codes | part
            shift += w
        return codes


jax.tree_util.register_dataclass(
    PlanGroup,
    data_fields=["planes", "codebook", "out_idx", "out_val", "x_idx"],
    meta_fields=["bits", "bk", "k_cols", "x_start"])


@dataclasses.dataclass(frozen=True)
class PreparedQuantizedTensor:
    """Deployment format: one gather index + one padded group per bit-width."""
    groups: Tuple[PlanGroup, ...]
    gather_idx: Array        # (sum k_padded,) int32 original-col per fused
    #                          K slot; == cols for padding (gathers 0.0)
    shape: Tuple[int, int]   # static (rows, cols) of the logical matrix
    n_padded: int            # static — rows padded to the N block
    bn: int                  # static — N block size (shared by all groups)

    @property
    def rows(self) -> int:
        return self.shape[0]

    @property
    def cols(self) -> int:
        return self.shape[1]

    @property
    def n_tiles(self) -> int:
        """Whole (bn, ·) output tiles along N — the unit in which the plan
        may be split across devices."""
        return self.n_padded // self.bn

    @property
    def x_gather_free(self) -> bool:
        """True iff every group fetches raw x blocks without per-column
        indexing (all groups aligned) — the fused matmul then contains no
        gather of any kind, in-kernel or XLA."""
        return all(g.x_start is not None for g in self.groups)

    def shards_whole_tiles(self, parts: int) -> bool:
        """True iff splitting N into `parts` equal contiguous shards keeps
        whole (bn, bk) tiles on every shard.  The plan layout packs codes
        along the row axis in 32/width-code words and pads N to bn, so an
        N split at a bn boundary is word-aligned for every plane width iff
        bn is a multiple of the full 32-row packing word — plans built
        with a smaller or unaligned bn cap replicate (a width-1 plane
        packs 32 rows per word, so e.g. bn=16 tile boundaries fall
        mid-word).  dist/sharding.spec_for_quantized uses this as the
        divisibility guard: shard the unit along N only when every shard
        keeps whole word-aligned tiles, otherwise replicate the whole
        unit — never tear it."""
        return parts > 1 and self.bn % 32 == 0 and self.n_tiles % parts == 0

    def dequantize(self, dtype=jnp.float32) -> Array:
        """Reference dequantization from the *prepared* layout (oracle for
        plan-vs-tensor parity tests; also serves materialize_kernel)."""
        rows, cols = self.shape
        W = jnp.zeros((rows, cols + 1), jnp.float32)   # last col: padding sink
        off = 0
        for g in self.groups:
            Wg = jnp.take_along_axis(g.codebook.T.astype(jnp.float32),
                                     g.unpack_codes(rows), axis=0)
            if g.out_idx is not None:
                safe = jnp.where(g.out_idx >= 0, g.out_idx, rows)
                colk = jnp.broadcast_to(
                    jnp.arange(g.k_padded)[None, :], g.out_idx.shape)
                Wg = Wg.at[safe, colk].set(g.out_val, mode="drop")
            idx = self.gather_idx[off:off + g.k_padded]
            W = W.at[:, idx].set(Wg)
            off += g.k_padded
        return W[:, :cols].astype(dtype)

    def effective_bits(self, include_codebooks: bool = False) -> float:
        """Storage cost of the *unpadded* payload (parity with
        QuantizedTensor.effective_bits up to outlier-count rounding)."""
        rows, cols = self.shape
        total = 0.0
        for g in self.groups:
            total += packing.storage_bits_per_element(g.bits) * rows * g.k_cols
            if g.out_idx is not None:
                total += 32.0 * float(jnp.sum(g.out_idx[:, :g.k_cols] >= 0))
            if include_codebooks:
                total += g.k_cols * g.codebook.shape[1] * 16.0
        return total / (rows * cols)


jax.tree_util.register_dataclass(
    PreparedQuantizedTensor,
    data_fields=["groups", "gather_idx"],
    meta_fields=["shape", "n_padded", "bn"])


def validated_outliers(qt: QuantizedTensor):
    """Outlier planes in stripe-permuted column order, invalid slots idx=-1.
    (Shared with the unprepared kernel dispatch in ops.py — the -1 contract
    must match the kernel epilogue in both paths.)"""
    if qt.out_idx.shape[0] == 0:
        return None, None
    k = qt.out_idx.shape[0]
    idx_p = qt.out_idx[:, qt.col_perm]
    val_p = qt.out_val[:, qt.col_perm]
    cnt_p = qt.out_count[qt.col_perm]
    valid = jnp.arange(k)[:, None] < cnt_p[None, :]
    return (jnp.where(valid, idx_p, -1).astype(jnp.int32),
            jnp.where(valid, val_p, 0.0).astype(jnp.float32))


def _static_group_layout(stripes, bk: int):
    """Per-bit-width group layout derived from static stripe metadata only
    (bits + column counts) — identical for every member of a layer stack.
    Returns [(bits, [(perm_offset, stripe_index), ...], k_cols, g_bk,
    k_padded)]; members are INDICES so `_build_plan` resolves them against
    its own (possibly vmapped) argument, never against closure constants.
    """
    offsets = []
    off = 0
    for s in stripes:
        offsets.append(off)
        off += s.n_cols
    layout = []
    for bits in sorted({s.bits for s in stripes}):
        members = [(o, si) for si, (o, s) in enumerate(zip(offsets, stripes))
                   if s.bits == bits]
        k_cols = sum(stripes[si].n_cols for _, si in members)
        g_bk = min(bk, _round_up(k_cols, 128))
        layout.append((bits, members, k_cols, g_bk, _round_up(k_cols, g_bk)))
    return layout


def _aligned_x_starts(qt: QuantizedTensor, layout):
    """Per-group x_start, or None where the group needs per-column index
    tables.  A group is *aligned* when its fused K order is exactly the
    original columns [s0, s0 + k_cols) with s0 a bk multiple — true for
    every single-bit-width tensor (`build_quantized_tensor` sorts columns
    ascending within each bit-class, so one class == identity).  Decided
    from concrete col_perm values at plan time; under tracing (prepare
    inside jit) it conservatively falls back to index tables.  For layer
    stacks the whole stack must agree (the flag is static, shared by every
    member)."""
    try:
        perm = np.asarray(qt.col_perm)
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        # traced col_perm (prepare under jit/vmap): no static analysis —
        # every group conservatively takes the index-table path.  Anything
        # else np.asarray raises is a real defect and must propagate.
        return [None] * len(layout)
    flat = perm.reshape(-1, perm.shape[-1])
    starts = []
    for bits, members, k_cols, g_bk, _k_padded in layout:
        idx = np.concatenate(
            [flat[:, o:o + qt.stripes[si].n_cols] for o, si in members],
            axis=1)
        s0 = int(idx[0, 0])
        ok = (s0 % g_bk == 0 and np.array_equal(
            idx, np.broadcast_to(np.arange(k_cols) + s0, idx.shape)))
        starts.append(s0 if ok else None)
    return starts


def prepare_for_inference(
    qt: QuantizedTensor,
    *,
    bn: int = dm.DEFAULT_BN,
    bk: int = dm.DEFAULT_BK,
) -> PreparedQuantizedTensor:
    """Compile `qt` into the fused deployment layout (see module docstring).

    bn/bk are *upper bounds*; each is shrunk to the tensor (bn to N rounded
    to the 32-row packing word, bk per group to its fused K rounded to the
    128-lane tile) so small matrices don't pay full-block padding.

    Layer-stacked tensors (launch.quantize stacks per-layer results, so
    data leaves carry leading (L,) or (L, E) dims while `shape` stays the
    per-matrix (rows, cols)) are prepared by vmapping over the stack: the
    AP/OR allocations depend only on (rows, cols), so every member shares
    one static plan layout, and the stacked prepared leaves slice per
    layer through scan / tree_map exactly like the stacked input did.
    The x alignment analysis runs on the whole stack BEFORE the vmap
    (x_start is static meta, so all members must agree on it).
    """
    layout = _static_group_layout(qt.stripes, bk)
    x_starts = _aligned_x_starts(qt, layout)
    build = functools.partial(_build_plan, bn=bn, layout=layout,
                              x_starts=x_starts)
    stack_dims = qt.stripes[0].packed.ndim - 2
    for _ in range(stack_dims):
        build = jax.vmap(build)
    return build(qt)


def _build_plan(qt: QuantizedTensor, *, bn: int, layout,
                x_starts) -> PreparedQuantizedTensor:
    rows = qt.rows
    bn = min(bn, _round_up(rows, 32))
    n_padded = _round_up(rows, bn)

    oi, ov = validated_outliers(qt)

    groups = []
    idx_parts = []
    for (bits, members, k_cols, g_bk, k_padded), x_start \
            in zip(layout, x_starts):
        widths = packing.plane_widths(bits)
        planes = []
        for wi, w in enumerate(widths):
            cpw = 32 // w
            parts = [packing.split_planes(qt.stripes[si].packed, bits,
                                          rows)[wi]
                     for _, si in members]
            p = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
            p = jnp.pad(p, ((0, n_padded // cpw - p.shape[0]),
                            (0, k_padded - k_cols)))
            planes.append(p)

        cb = jnp.concatenate(
            [qt.stripes[si].codebook for _, si in members], axis=0) \
            if len(members) > 1 else qt.stripes[members[0][1]].codebook
        cb = jnp.pad(cb.astype(jnp.float32), ((0, k_padded - k_cols), (0, 0)))

        g_oi = g_ov = None
        if oi is not None:
            g_oi = jnp.concatenate(
                [jax.lax.slice_in_dim(oi, o, o + qt.stripes[si].n_cols,
                                      axis=1)
                 for o, si in members], axis=1)
            g_ov = jnp.concatenate(
                [jax.lax.slice_in_dim(ov, o, o + qt.stripes[si].n_cols,
                                      axis=1)
                 for o, si in members], axis=1)
            g_oi = jnp.pad(g_oi, ((0, 0), (0, k_padded - k_cols)),
                           constant_values=-1)
            g_ov = jnp.pad(g_ov, ((0, 0), (0, k_padded - k_cols)))

        idx = jnp.concatenate(
            [jax.lax.slice_in_dim(qt.col_perm, o, o + qt.stripes[si].n_cols)
             for o, si in members]) if len(members) > 1 \
            else jax.lax.slice_in_dim(
                qt.col_perm, members[0][0],
                members[0][0] + qt.stripes[members[0][1]].n_cols)
        idx = jnp.pad(idx.astype(jnp.int32), (0, k_padded - k_cols),
                      constant_values=qt.cols)
        idx_parts.append(idx)

        groups.append(PlanGroup(
            planes=tuple(planes), codebook=cb, out_idx=g_oi, out_val=g_ov,
            x_idx=(None if x_start is not None
                   else idx.reshape(k_padded // g_bk, g_bk)),
            bits=bits, bk=g_bk, k_cols=k_cols, x_start=x_start))

    return PreparedQuantizedTensor(
        groups=tuple(groups),
        gather_idx=jnp.concatenate(idx_parts) if len(idx_parts) > 1
        else idx_parts[0],
        shape=qt.shape, n_padded=n_padded, bn=bn)


def prepare_tree(params, *, bn: int = dm.DEFAULT_BN, bk: int = dm.DEFAULT_BK):
    """Replace every QuantizedTensor leaf in a params tree with its prepared
    form (identity on dense leaves).  Engines call this once at load."""
    def one(leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf.prepare(bn=bn, bk=bk)
        return leaf

    return jax.tree_util.tree_map(
        one, params,
        is_leaf=lambda l: isinstance(l, (QuantizedTensor,
                                         PreparedQuantizedTensor)))
