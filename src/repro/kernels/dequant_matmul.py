"""Pallas TPU kernel: fused CLAQ dequant GEMM with outlier-reservation epilogue.

Computes  y = x @ W^T  where W (N=out, K=in) is stored as:
  * packed code planes (uint32 words along the N axis, one stream/column),
  * a per-column codebook (K, 2**bits),
  * structured outliers: up to `k_out` (row-index, fp-value) pairs per
    column overriding the dequantized value (Outlier Reservation, §3.4).

TPU adaptation (DESIGN.md §4):
  * codes unpack with shift/mask on the VPU; centroid lookup is done as a
    2**bits-way select-accumulate (no gather — codebooks are <=16 entries,
    so a select chain beats any gather on TPU and vectorizes across the
    whole tile);
  * outliers apply inside the dequant epilogue as `k_out` masked selects
    against the tile's global row ids — no scatter, shape-static;
  * the weight tile feeds the MXU directly from VMEM; full-width W never
    exists in HBM.

Grid: (M/bm, N/bn, K/bk), K innermost; the (bm, bn) f32 output block stays
resident in VMEM across the K sweep (revisited accumulation).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

# jax<0.5 names the Pallas TPU params class TPUCompilerParams; newer jax
# renamed it back to CompilerParams.  Resolve whichever exists.
_COMPILER_PARAMS_CLS = getattr(pltpu, "TPUCompilerParams", None) \
    or getattr(pltpu, "CompilerParams")

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _unpack_tile(words, width: int, bn: int):
    """(bn//cpw, bk) uint32 words -> (bn, bk) int32 codes of `width` bits."""
    cpw = 32 // width
    mask = jnp.uint32((1 << width) - 1)
    rep = jnp.repeat(words, cpw, axis=0)                      # (bn, bk)
    shift = (jax.lax.broadcasted_iota(jnp.uint32, (bn, 1), 0) % cpw) * width
    return ((rep >> shift) & mask).astype(jnp.int32)


def _dequant_tile(codes, cb, n_levels: int, compute_dtype):
    """codes (bn, bk) + cb (bk, n_levels) -> W tile (bn, bk).

    n_levels-way select-accumulate: for <=16 centroids this is a handful of
    vectorized VPU ops per element — cheaper and more TPU-natural than a
    gather from VMEM.
    """
    w = jnp.zeros(codes.shape, compute_dtype)
    for c in range(n_levels):
        w = jnp.where(codes == c, cb[None, :, c].astype(compute_dtype), w)
    return w


def _kernel(x_ref, *rest, bits: int, plane_widths: Sequence[int], bn: int,
            k_out: int, n_levels: int, has_acc: bool, compute_dtype):
    nplanes = len(plane_widths)
    plane_refs = rest[:nplanes]
    rest = rest[nplanes:]
    cb_ref, rest = rest[0], rest[1:]
    if k_out > 0:
        idx_ref, val_ref, rest = rest[0], rest[1], rest[2:]
    if has_acc:
        acc_ref, rest = rest[0], rest[1:]
    (o_ref,) = rest

    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        # seed the VMEM-resident output block: zeros, or the running
        # accumulator when fusing multiple bit-width groups into one output
        o_ref[...] = acc_ref[...] if has_acc else jnp.zeros_like(o_ref)

    # --- unpack code planes -> (bn, bk) int32 codes -------------------------
    codes = None
    shift = 0
    for w, ref in zip(plane_widths, plane_refs):
        part = _unpack_tile(ref[...], w, bn) << shift
        codes = part if codes is None else codes | part
        shift += w
    # --- centroid lookup -----------------------------------------------------
    wt = _dequant_tile(codes, cb_ref[...], n_levels, compute_dtype)

    # --- outlier-reservation epilogue ---------------------------------------
    if k_out > 0:
        n0 = pl.program_id(1) * bn
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0) + n0
        idx = idx_ref[...]            # (k_out, bk) global row ids, -1 invalid
        val = val_ref[...]            # (k_out, bk)
        for r in range(k_out):
            hit = idx[r][None, :] == row_ids             # (bn, bk)
            wt = jnp.where(hit, val[r][None, :].astype(compute_dtype), wt)

    # --- MXU ------------------------------------------------------------------
    x = x_ref[...].astype(compute_dtype)
    o_ref[...] += jax.lax.dot_general(
        x, wt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


# pallas_call dispatches issued from python since process start (trace-time
# under jit).  Tests and benchmarks read deltas of this to assert the fused
# plan path launches exactly one kernel per distinct stripe bit-width.
launch_count = 0


@functools.partial(
    jax.jit,
    static_argnames=("bits", "n", "bm", "bn", "bk", "interpret", "compute_dtype"),
)
def _dequant_matmul(x, planes, codebook, out_idx, out_val, acc, *,
                    bits, n, bm, bn, bk, interpret, compute_dtype):
    from repro.core import packing

    widths = packing.plane_widths(bits)
    m, k_dim = x.shape
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0
    for w, p in zip(widths, planes):
        assert p.shape == (n // (32 // w), k_dim), (p.shape, n, k_dim, w)
    grid = (m // bm, n // bn, k_dim // bk)
    n_levels = 2 ** bits

    k_out = 0 if out_idx is None else out_idx.shape[0]

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    operands = [x]
    for w, p in zip(widths, planes):
        cpw = 32 // w
        in_specs.append(pl.BlockSpec((bn // cpw, bk), lambda i, j, k: (j, k)))
        operands.append(p)
    in_specs.append(pl.BlockSpec((bk, n_levels), lambda i, j, k: (k, 0)))
    operands.append(codebook)
    if k_out > 0:
        in_specs.append(pl.BlockSpec((k_out, bk), lambda i, j, k: (0, k)))
        in_specs.append(pl.BlockSpec((k_out, bk), lambda i, j, k: (0, k)))
        operands.extend([out_idx, out_val])
    if acc is not None:
        assert acc.shape == (m, n), (acc.shape, m, n)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        operands.append(acc)

    kernel = functools.partial(
        _kernel, bits=bits, plane_widths=widths, bn=bn, k_out=k_out,
        n_levels=n_levels, has_acc=acc is not None,
        compute_dtype=compute_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def dequant_matmul(
    x: Array,                     # (M, K)
    planes: tuple,                # per-plane (n_words, K) uint32
    codebook: Array,              # (K, 2**bits)
    out_idx: Optional[Array],     # (k_out, K) int32 global row ids, -1 pad
    out_val: Optional[Array],     # (k_out, K)
    *,
    bits: int,
    n: int,                       # N = out features (rows of W)
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    compute_dtype=jnp.float32,
    acc: Optional[Array] = None,  # (M, N) f32 running accumulator to fold in
) -> Array:
    """y = [acc +] x @ W^T for one uniform-bit-width CLAQ group.  Shapes
    must be padded to block multiples by the caller (kernels/ops.py /
    kernels/plan.py do this).  `acc` seeds the output block at the first K
    step, so multi-group (mixed-precision) matmuls accumulate inside the
    kernel instead of through an XLA add per group."""
    global launch_count
    launch_count += 1
    return _dequant_matmul(x, tuple(planes), codebook, out_idx, out_val, acc,
                           bits=bits, n=n, bm=bm, bn=bn, bk=bk,
                           interpret=interpret, compute_dtype=compute_dtype)
