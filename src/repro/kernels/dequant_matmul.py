"""Pallas TPU kernel: fused CLAQ dequant GEMM with outlier-reservation epilogue.

Computes  y = x @ W^T  where W (N=out, K=in) is stored as:
  * packed code planes (uint32 words along the N axis, one stream/column),
  * a per-column codebook (K, 2**bits),
  * structured outliers: up to `k_out` (row-index, fp-value) pairs per
    column overriding the dequantized value (Outlier Reservation, §3.4).

TPU adaptation (DESIGN.md §4):
  * codes unpack with shift/mask on the VPU; centroid lookup is done as a
    2**bits-way select-accumulate (no gather — codebooks are <=16 entries,
    so a select chain beats any gather on TPU and vectorizes across the
    whole tile);
  * outliers apply inside the dequant epilogue as `k_out` masked selects
    against the tile's global row ids — no scatter, shape-static;
  * the weight tile feeds the MXU directly from VMEM; full-width W never
    exists in HBM.

Grid: (M/bm, N/bn, K/bk), K innermost; the (bm, bn) f32 output block stays
resident in VMEM across the K sweep (revisited accumulation).

Activation fetch (`x_mode`, DESIGN.md §9) — how each (bm, bk) x tile
reaches the MXU:
  * "blocked": x arrives pre-gathered and K-padded by the caller (the
    legacy XLA-gather path and the per-stripe unprepared dispatch); the
    tile is a plain (i, k) block.
  * "aligned": x is the RAW activation matrix; the plan proved the group's
    fused K order IS original column order (single-bit-width tensors —
    `build_quantized_tensor` emits an identity permutation), so the tile
    is the raw (i, x_base + k) block, and only the padded K tail past
    `k_cols` is masked to zero in-kernel (the tail's codebooks/outliers
    are zero/-1, but interpret-mode Pallas pads out-of-bounds blocks with
    NaN, and NaN * 0 would poison the accumulator).
  * "gathered": x is the raw matrix, VMEM-resident as one (bm, K) block
    pinned at (i, 0) across the whole (N, K) sweep, plus a per-bk-block
    int32 index table (the plan's `gather_idx` reshaped); the kernel takes
    the tile's columns out of the resident block (on TPU a VMEM-local
    dynamic gather along lanes; never an HBM gather) and masks index
    `cols` (the fill slot) to 0.0 — bitwise the same tile the XLA
    `jnp.take(..., mode="fill")` used to build.

Per-token int8 activations ride any mode: x may be int8 (cast to the
compute dtype after the fetch) with an optional (M, 1) f32 `x_scale`
operand folded into the output block at the LAST K step — one multiply
per output element after the integer-valued accumulation, so the MXU
consumes unscaled int8-exact values.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

# jax<0.5 names the Pallas TPU params class TPUCompilerParams; newer jax
# renamed it back to CompilerParams.  Resolve whichever exists.
_COMPILER_PARAMS_CLS = getattr(pltpu, "TPUCompilerParams", None) \
    or getattr(pltpu, "CompilerParams")

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _unpack_tile(words, width: int, bn: int):
    """(bn//cpw, bk) uint32 words -> (bn, bk) int32 codes of `width` bits."""
    cpw = 32 // width
    mask = jnp.uint32((1 << width) - 1)
    rep = jnp.repeat(words, cpw, axis=0)                      # (bn, bk)
    shift = (jax.lax.broadcasted_iota(jnp.uint32, (bn, 1), 0) % cpw) * width
    return ((rep >> shift) & mask).astype(jnp.int32)


def _dequant_tile(codes, cb, n_levels: int, compute_dtype):
    """codes (bn, bk) + cb (bk, n_levels) -> W tile (bn, bk).

    n_levels-way select-accumulate: for <=16 centroids this is a handful of
    vectorized VPU ops per element — cheaper and more TPU-natural than a
    gather from VMEM.
    """
    w = jnp.zeros(codes.shape, compute_dtype)
    for c in range(n_levels):
        w = jnp.where(codes == c, cb[None, :, c].astype(compute_dtype), w)
    return w


def _kernel(x_ref, *rest, bits: int, plane_widths: Sequence[int], bn: int,
            bk: int, k_out: int, n_levels: int, has_acc: bool, compute_dtype,
            x_mode: str, k_cols: int, has_scale: bool):
    nplanes = len(plane_widths)
    if x_mode == "gathered":
        xi_ref, rest = rest[0], rest[1:]
    plane_refs = rest[:nplanes]
    rest = rest[nplanes:]
    cb_ref, rest = rest[0], rest[1:]
    if k_out > 0:
        idx_ref, val_ref, rest = rest[0], rest[1], rest[2:]
    if has_scale:
        scale_ref, rest = rest[0], rest[1:]
    if has_acc:
        acc_ref, rest = rest[0], rest[1:]
    (o_ref,) = rest

    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        # seed the VMEM-resident output block: zeros, or the running
        # accumulator when fusing multiple bit-width groups into one output
        o_ref[...] = acc_ref[...] if has_acc else jnp.zeros_like(o_ref)

    # --- fetch the (bm, bk) x tile (see module docstring: x_mode) -----------
    if x_mode == "blocked":
        xt = x_ref[...]
    elif x_mode == "aligned":
        xt = x_ref[...]
        if k_cols % bk != 0:
            # the group's padded K tail: weights there are zero, but the
            # raw-x block read past `cols` is NaN-padded in interpret mode
            fused = k_step * bk + jax.lax.broadcasted_iota(
                jnp.int32, (1, bk), 1)
            xt = jnp.where(fused < k_cols, xt, jnp.zeros((), xt.dtype))
    else:                              # "gathered": in-kernel take over the
        xf = x_ref[...]                # VMEM-resident raw (bm, K) block
        x_cols = xf.shape[1]
        ii = xi_ref[0, :]              # (bk,) original col per fused K slot
        xt = jnp.take(xf, jnp.minimum(ii, x_cols - 1), axis=1)
        xt = jnp.where((ii < x_cols)[None, :], xt, jnp.zeros((), xt.dtype))

    # --- unpack code planes -> (bn, bk) int32 codes -------------------------
    codes = None
    shift = 0
    for w, ref in zip(plane_widths, plane_refs):
        part = _unpack_tile(ref[...], w, bn) << shift
        codes = part if codes is None else codes | part
        shift += w
    # --- centroid lookup -----------------------------------------------------
    wt = _dequant_tile(codes, cb_ref[...], n_levels, compute_dtype)

    # --- outlier-reservation epilogue ---------------------------------------
    if k_out > 0:
        n0 = pl.program_id(1) * bn
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0) + n0
        idx = idx_ref[...]            # (k_out, bk) global row ids, -1 invalid
        val = val_ref[...]            # (k_out, bk)
        for r in range(k_out):
            hit = idx[r][None, :] == row_ids             # (bn, bk)
            wt = jnp.where(hit, val[r][None, :].astype(compute_dtype), wt)

    # --- MXU ------------------------------------------------------------------
    x = xt.astype(compute_dtype)
    o_ref[...] += jax.lax.dot_general(
        x, wt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    if has_scale:
        # per-token (row) activation scale, folded in ONCE after the full
        # K sweep — the last launch of a multi-group matmul carries it, so
        # the whole accumulated sum (acc seed included) is scaled exactly
        # once: y = scale_m * sum_k xq[m,k] * w[n,k]
        @pl.when(k_step == pl.num_programs(2) - 1)
        def _fold_scale():
            o_ref[...] = o_ref[...] * scale_ref[...].astype(jnp.float32)


# pallas_call dispatches issued from python since process start (trace-time
# under jit).  Tests and benchmarks read deltas of this to assert the fused
# plan path launches exactly one kernel per distinct stripe bit-width.
launch_count = 0


@functools.partial(
    jax.jit,
    static_argnames=("bits", "n", "bm", "bn", "bk", "interpret",
                     "compute_dtype", "x_mode", "x_base", "k_cols"),
)
def _dequant_matmul(x, planes, codebook, out_idx, out_val, acc, x_idx,
                    x_scale, *, bits, n, bm, bn, bk, interpret,
                    compute_dtype, x_mode, x_base, k_cols):
    from repro.core import packing

    widths = packing.plane_widths(bits)
    m = x.shape[0]
    k_padded = planes[0].shape[-1]     # fused, block-padded K of the group
    assert m % bm == 0 and n % bn == 0 and k_padded % bk == 0
    for w, p in zip(widths, planes):
        assert p.shape == (n // (32 // w), k_padded), (p.shape, n, k_padded, w)
    grid = (m // bm, n // bn, k_padded // bk)
    n_levels = 2 ** bits

    k_out = 0 if out_idx is None else out_idx.shape[0]

    if x_mode == "blocked":
        assert x.shape[1] == k_padded, (x.shape, k_padded)
        in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    elif x_mode == "aligned":
        # raw x; the group's fused K order IS original columns starting at
        # block offset x_base — a plain shifted block fetch, no indexing
        in_specs = [pl.BlockSpec((bm, bk),
                                 lambda i, j, k, xb=x_base: (i, xb + k))]
    elif x_mode == "gathered":
        # raw x, whole K axis resident per M block (index map constant in
        # j/k — Pallas keeps the block in VMEM across the (N, K) sweep)
        in_specs = [pl.BlockSpec((bm, x.shape[1]), lambda i, j, k: (i, 0))]
    else:
        raise ValueError(f"unknown x_mode {x_mode!r}")
    operands = [x]
    if x_mode == "gathered":
        assert x_idx is not None and x_idx.shape == (k_padded // bk, bk), \
            (None if x_idx is None else x_idx.shape, k_padded, bk)
        in_specs.append(pl.BlockSpec((1, bk), lambda i, j, k: (k, 0)))
        operands.append(x_idx)
    for w, p in zip(widths, planes):
        cpw = 32 // w
        in_specs.append(pl.BlockSpec((bn // cpw, bk), lambda i, j, k: (j, k)))
        operands.append(p)
    in_specs.append(pl.BlockSpec((bk, n_levels), lambda i, j, k: (k, 0)))
    operands.append(codebook)
    if k_out > 0:
        in_specs.append(pl.BlockSpec((k_out, bk), lambda i, j, k: (0, k)))
        in_specs.append(pl.BlockSpec((k_out, bk), lambda i, j, k: (0, k)))
        operands.extend([out_idx, out_val])
    if x_scale is not None:
        assert x_scale.shape == (m, 1), (x_scale.shape, m)
        in_specs.append(pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)))
        operands.append(x_scale)
    if acc is not None:
        assert acc.shape == (m, n), (acc.shape, m, n)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        operands.append(acc)

    kernel = functools.partial(
        _kernel, bits=bits, plane_widths=widths, bn=bn, bk=bk, k_out=k_out,
        n_levels=n_levels, has_acc=acc is not None,
        compute_dtype=compute_dtype, x_mode=x_mode, k_cols=k_cols,
        has_scale=x_scale is not None)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def dequant_matmul(
    x: Array,                     # (M, K) — fused-padded ("blocked") or raw
    planes: tuple,                # per-plane (n_words, K) uint32
    codebook: Array,              # (K, 2**bits)
    out_idx: Optional[Array],     # (k_out, K) int32 global row ids, -1 pad
    out_val: Optional[Array],     # (k_out, K)
    *,
    bits: int,
    n: int,                       # N = out features (rows of W)
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    compute_dtype=jnp.float32,
    acc: Optional[Array] = None,  # (M, N) f32 running accumulator to fold in
    x_mode: str = "blocked",      # "blocked" | "aligned" | "gathered"
    x_base: int = 0,              # aligned: x block offset (x_start // bk)
    k_cols: int = 0,              # aligned: unpadded fused K (tail mask)
    x_idx: Optional[Array] = None,    # gathered: (K/bk, bk) int32 tables
    x_scale: Optional[Array] = None,  # (M, 1) f32 per-token act scale
) -> Array:
    """y = [acc +] x @ W^T for one uniform-bit-width CLAQ group.  Plane /
    codebook / outlier shapes must be padded to block multiples by the
    caller (kernels/ops.py / kernels/plan.py do this); with the raw-x
    modes ("aligned" / "gathered", module docstring) x itself needs only
    its rows padded to bm.  `acc` seeds the output block at the first K
    step, so multi-group (mixed-precision) matmuls accumulate inside the
    kernel instead of through an XLA add per group; `x_scale` folds a
    per-token int8 activation scale into the output at the last K step
    (pass it on the LAST launch of a multi-group chain only)."""
    global launch_count
    launch_count += 1
    return _dequant_matmul(x, tuple(planes), codebook, out_idx, out_val, acc,
                           x_idx, x_scale,
                           bits=bits, n=n, bm=bm, bn=bn, bk=bk,
                           interpret=interpret, compute_dtype=compute_dtype,
                           x_mode=x_mode, x_base=x_base, k_cols=k_cols)
