"""Jit'd wrappers dispatching QuantizedTensor matmuls to the Pallas kernel
(TPU / interpret) or the XLA reference path (CPU dry-run lowering).

`qmatmul(x, qt)` computes x @ dequantize(qt)^T for the full multi-stripe,
outlier-carrying format; the kernel path never materializes W in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantized import QuantizedTensor

from . import dequant_matmul as dm
from . import ref as ref_lib

Array = jax.Array


def _pad_to(arr: Array, axis: int, mult: int, value=0) -> Array:
    size = arr.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths, constant_values=value)


def stripe_matmul(
    x: Array,
    stripe_packed: Array,
    codebook: Array,
    out_idx: Optional[Array],
    out_val: Optional[Array],
    *,
    bits: int,
    n: int,
    interpret: bool = True,
    bm: int = dm.DEFAULT_BM,
    bn: int = dm.DEFAULT_BN,
    bk: int = dm.DEFAULT_BK,
    compute_dtype=jnp.float32,
) -> Array:
    """Single-stripe kernel call with all padding handled. x: (M, K)."""
    m, k_dim = x.shape
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 32))
    bk = min(bk, _round_up(k_dim, 128))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    kp = xp.shape[1]
    n_padded = _round_up(n, bn)

    planes = []
    for w, p in zip(packing.plane_widths(bits),
                    packing.split_planes(stripe_packed, bits, n)):
        cpw = 32 // w
        p = _pad_to(p, 0, n_padded // cpw)  # pad rows for padded N
        p = p[: n_padded // cpw]
        planes.append(_pad_to(p, 1, bk))

    cb = _pad_to(codebook.astype(jnp.float32), 0, bk)
    oi = ov = None
    if out_idx is not None and out_idx.shape[0] > 0:
        oi = _pad_to(out_idx.astype(jnp.int32), 1, bk, value=-1)
        ov = _pad_to(out_val.astype(jnp.float32), 1, bk)

    y = dm.dequant_matmul(
        xp, tuple(planes), cb, oi, ov,
        bits=bits, n=n_padded, bm=bm, bn=bn, bk=bk,
        interpret=interpret, compute_dtype=compute_dtype)
    return y[:m, :n]


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _prepared_outliers(qt: QuantizedTensor):
    """Permute outlier planes to stripe order; mark invalid slots idx=-1."""
    if qt.out_idx.shape[0] == 0:
        return None, None
    k = qt.out_idx.shape[0]
    idx_p = qt.out_idx[:, qt.col_perm]
    val_p = qt.out_val[:, qt.col_perm]
    cnt_p = qt.out_count[qt.col_perm]
    valid = jnp.arange(k)[:, None] < cnt_p[None, :]
    return jnp.where(valid, idx_p, -1), jnp.where(valid, val_p, 0.0)


def qmatmul(
    x: Array,
    qt: QuantizedTensor,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
    compute_dtype=None,
) -> Array:
    """x (..., K) @ dequantize(qt)^T -> (..., N).

    use_kernel=False: XLA reference path (gather-dequant + dot). This is what
    the CPU dry-run lowers (Pallas TPU kernels can't lower on the CPU
    backend); its HLO cost is the *baseline* the kernel improves on.
    use_kernel=True: the Pallas kernel (interpret=True on CPU for tests).
    """
    if compute_dtype is None:
        compute_dtype = jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16
    if not use_kernel:
        return ref_lib.ref_qmatmul(x, qt).astype(x.dtype)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xp = jnp.take(x2, qt.col_perm, axis=1)     # stripe order
    oi, ov = _prepared_outliers(qt)

    y = jnp.zeros((x2.shape[0], qt.rows), jnp.float32)
    off = 0
    for s in qt.stripes:
        nc = s.n_cols
        xs = jax.lax.slice_in_dim(xp, off, off + nc, axis=1)
        soi = sov = None
        if oi is not None:
            soi = jax.lax.slice_in_dim(oi, off, off + nc, axis=1)
            sov = jax.lax.slice_in_dim(ov, off, off + nc, axis=1)
        y = y + stripe_matmul(
            xs, s.packed, s.codebook, soi, sov,
            bits=s.bits, n=qt.rows, interpret=interpret,
            compute_dtype=compute_dtype)
        off += nc
    return y.reshape(lead + (qt.rows,)).astype(x.dtype)
