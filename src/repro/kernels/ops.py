"""Jit'd wrappers dispatching QuantizedTensor matmuls to the Pallas kernel
(TPU / interpret) or the XLA reference path (CPU dry-run lowering).

`qmatmul(x, qt)` computes x @ dequantize(qt)^T for the full multi-stripe,
outlier-carrying format; the kernel path never materializes W in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantized import QuantizedTensor

from . import dequant_matmul as dm
from . import ref as ref_lib
from .plan import PreparedQuantizedTensor, validated_outliers

Array = jax.Array


def _pad_to(arr: Array, axis: int, mult: int, value=0) -> Array:
    size = arr.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths, constant_values=value)


def stripe_matmul(
    x: Array,
    stripe_packed: Array,
    codebook: Array,
    out_idx: Optional[Array],
    out_val: Optional[Array],
    *,
    bits: int,
    n: int,
    interpret: bool = True,
    bm: int = dm.DEFAULT_BM,
    bn: int = dm.DEFAULT_BN,
    bk: int = dm.DEFAULT_BK,
    compute_dtype=jnp.float32,
) -> Array:
    """Single-stripe kernel call with all padding handled. x: (M, K)."""
    m, k_dim = x.shape
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 32))
    bk = min(bk, _round_up(k_dim, 128))

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    kp = xp.shape[1]
    n_padded = _round_up(n, bn)

    planes = []
    for w, p in zip(packing.plane_widths(bits),
                    packing.split_planes(stripe_packed, bits, n)):
        cpw = 32 // w
        p = _pad_to(p, 0, n_padded // cpw)  # pad rows for padded N
        p = p[: n_padded // cpw]
        planes.append(_pad_to(p, 1, bk))

    cb = _pad_to(codebook.astype(jnp.float32), 0, bk)
    oi = ov = None
    if out_idx is not None and out_idx.shape[0] > 0:
        oi = _pad_to(out_idx.astype(jnp.int32), 1, bk, value=-1)
        ov = _pad_to(out_val.astype(jnp.float32), 1, bk)

    y = dm.dequant_matmul(
        xp, tuple(planes), cb, oi, ov,
        bits=bits, n=n_padded, bm=bm, bn=bn, bk=bk,
        interpret=interpret, compute_dtype=compute_dtype)
    return y[:m, :n]


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def quantize_activations(x: Array):
    """Per-token (row) dynamic absmax int8 quantization of activations:
    x (..., K) f32 -> (xq (..., K) int8, scale (..., 1) f32) with
    x ≈ xq * scale.  |x/scale| <= 127 exactly at the row max, so round
    never clips; all-zero rows get scale 1 (0/0 would mint NaNs).  The
    quantization error per element is <= scale/2 (round-to-nearest), which
    bounds the matmul error at scale_m/2 * ||W_n||_1 per output element
    (kernels/ref.ref_act_int8_bound, DESIGN.md §9)."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    xq = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return xq, scale


def normalize_act_dtype(act_dtype):
    """None/'f32' -> None (full precision); 'int8' passes through;
    anything else raises.  The single validation point for the activation
    quantization knob — the ServingEngine calls it too."""
    if act_dtype in (None, "f32"):
        return None
    if act_dtype != "int8":
        raise ValueError(f"unsupported act_dtype {act_dtype!r} "
                         "(expected 'f32' or 'int8')")
    return act_dtype


def prepared_qmatmul(
    x: Array,
    pqt: PreparedQuantizedTensor,
    *,
    interpret: bool = True,
    bm: int = dm.DEFAULT_BM,
    compute_dtype=jnp.float32,
    gather: str = "kernel",
    act_dtype=None,
) -> Array:
    """Fused hot path: x (..., K) @ dequantize(pqt)^T -> (..., N).

    The plan did all per-tensor work offline, so this is exactly ONE
    `pallas_call` per distinct stripe bit-width, each accumulating into
    the same output block via the kernel's acc operand.

    gather="kernel" (default): the kernel consumes RAW x — aligned groups
    read plain (i, k) blocks, permuted groups take their columns from a
    VMEM-resident x block via the plan's per-bk-block index tables.  No
    XLA gather, no padded activation copy (only rows pad to the M block).
    Bit-identical to gather="xla", the pre-fold path kept for A/B
    benchmarking: one XLA take of x into fused-padded order, then
    "blocked" kernel launches.

    act_dtype="int8": per-token dynamic absmax quantization of x; the
    kernel consumes int8 activations and the (m, 1) f32 scales fold into
    the output block at the last K step of the LAST group's launch (the
    XLA-gather path applies the same scales as one XLA multiply — the two
    paths stay bit-identical).
    """
    act_dtype = normalize_act_dtype(act_dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    scale = None
    if act_dtype == "int8":
        x2, scale = quantize_activations(x2)
    m = x2.shape[0]
    bm = min(bm, _round_up(m, 8))

    if gather == "xla":
        xg = jnp.take(x2, pqt.gather_idx, axis=1, mode="fill", fill_value=0)
        xp = _pad_to(xg, 0, bm)
        y = None
        off = 0
        for g in pqt.groups:
            xs = jax.lax.slice_in_dim(xp, off, off + g.k_padded, axis=1)
            y = dm.dequant_matmul(
                xs, g.planes, g.codebook, g.out_idx, g.out_val,
                bits=g.bits, n=pqt.n_padded, bm=bm, bn=pqt.bn, bk=g.bk,
                interpret=interpret, compute_dtype=compute_dtype, acc=y)
            off += g.k_padded
        y = y[:m, :pqt.rows]
        if scale is not None:
            y = y * scale
    elif gather == "kernel":
        xp = _pad_to(x2, 0, bm)
        sp = _pad_to(scale, 0, bm) if scale is not None else None
        y = None
        for gi, g in enumerate(pqt.groups):
            aligned = g.x_start is not None
            y = dm.dequant_matmul(
                xp, g.planes, g.codebook, g.out_idx, g.out_val,
                bits=g.bits, n=pqt.n_padded, bm=bm, bn=pqt.bn, bk=g.bk,
                interpret=interpret, compute_dtype=compute_dtype, acc=y,
                x_mode="aligned" if aligned else "gathered",
                x_base=g.x_start // g.bk if aligned else 0,
                k_cols=g.k_cols, x_idx=g.x_idx,
                x_scale=sp if gi == len(pqt.groups) - 1 else None)
        y = y[:m, :pqt.rows]
    else:
        raise ValueError(f"unknown gather mode {gather!r} "
                         "(expected 'kernel' or 'xla')")
    return y.reshape(lead + (pqt.rows,)).astype(x.dtype)


def _prepared_ref_qmatmul(x: Array, pqt: PreparedQuantizedTensor,
                          act_dtype=None) -> Array:
    """XLA path over the prepared layout.  Unlike ref_qmatmul it never
    scatters W back into original column order: the gather index already
    aligned the activations with the fused group layout, so the matmul is a
    plain per-group dequant + dot accumulation (padded K slots have zero
    codebooks and idx=-1 outliers, so they contribute exactly zero).
    act_dtype="int8" applies the same per-token absmax quantization as the
    kernel path (int8-exact values through the dot, one scale multiply at
    the end)."""
    rows = pqt.rows
    xf = x.astype(jnp.float32)
    scale = None
    if normalize_act_dtype(act_dtype) == "int8":
        xq, scale = quantize_activations(xf)
        xf = xq.astype(jnp.float32)
    xg = jnp.take(xf, pqt.gather_idx, axis=-1, mode="fill", fill_value=0)
    y = jnp.zeros(x.shape[:-1] + (rows,), jnp.float32)
    off = 0
    for g in pqt.groups:
        Wg = jnp.take_along_axis(g.codebook.T.astype(jnp.float32),
                                 g.unpack_codes(rows), axis=0)
        Wg = ref_lib.ref_apply_outliers(Wg, g.out_idx, g.out_val)
        # XLA doesn't need the kernel's K padding — slice to the unpadded
        # group so total contraction is exactly `cols` (parity with the
        # dense dot; padded slots are zero anyway).
        xs = jax.lax.slice_in_dim(xg, off, off + g.k_cols, axis=-1)
        y = y + jnp.einsum("...k,nk->...n", xs, Wg[:, :g.k_cols],
                           preferred_element_type=jnp.float32)
        off += g.k_padded
    if scale is not None:
        y = y * scale
    return y


def qmatmul(
    x: Array,
    qt,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
    compute_dtype=None,
    act_dtype=None,
    gather: str = "kernel",
) -> Array:
    """x (..., K) @ dequantize(qt)^T -> (..., N) for a QuantizedTensor or a
    PreparedQuantizedTensor.

    use_kernel=False: XLA reference path (gather-dequant + dot). This is what
    the CPU dry-run lowers (Pallas TPU kernels can't lower on the CPU
    backend); its HLO cost is the *baseline* the kernel improves on.
    use_kernel=True: the Pallas kernel (interpret=True on CPU for tests).
    Prepared tensors take the fused path: one launch per distinct bit-width,
    with the stripe-permutation gather folded into the kernel (gather=
    "kernel", default) or as the pre-fold XLA take (gather="xla" — the A/B
    baseline, bit-identical).  act_dtype="int8" opts activations into
    per-token dynamic int8 quantization (prepared tensors only).
    """
    if compute_dtype is None:
        compute_dtype = jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16
    if isinstance(qt, PreparedQuantizedTensor):
        if not use_kernel:
            return _prepared_ref_qmatmul(x, qt,
                                         act_dtype=act_dtype).astype(x.dtype)
        return prepared_qmatmul(x, qt, interpret=interpret,
                                compute_dtype=compute_dtype,
                                gather=gather, act_dtype=act_dtype)
    if normalize_act_dtype(act_dtype) is not None:
        raise ValueError(
            "act_dtype quantization needs an ahead-of-time plan — prepare "
            "the tensor first (QuantizedTensor.prepare / prepare_tree; "
            "ServingEngine does this at init unless prepare=False)")
    if not use_kernel:
        return ref_lib.ref_qmatmul(x, qt).astype(x.dtype)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xp = jnp.take(x2, qt.col_perm, axis=1)     # stripe order
    oi, ov = validated_outliers(qt)

    y = jnp.zeros((x2.shape[0], qt.rows), jnp.float32)
    off = 0
    for s in qt.stripes:
        nc = s.n_cols
        xs = jax.lax.slice_in_dim(xp, off, off + nc, axis=1)
        soi = sov = None
        if oi is not None:
            soi = jax.lax.slice_in_dim(oi, off, off + nc, axis=1)
            sov = jax.lax.slice_in_dim(ov, off, off + nc, axis=1)
        y = y + stripe_matmul(
            xs, s.packed, s.codebook, soi, sov,
            bits=s.bits, n=qt.rows, interpret=interpret,
            compute_dtype=compute_dtype)
        off += nc
    return y.reshape(lead + (qt.rows,)).astype(x.dtype)
