"""CLAQ orchestration: plan -> quantize -> package, per matrix and per model.

This is the host-level driver (quantization is an offline pipeline); the
inner loops (`gptq.gptq_quantize_matrix`, `kmeans`) are jit-compiled.  A
row-sharded variant runs the same engine under `shard_map` for mesh-parallel
quantization of large matrices (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import gptq, kmeans as kmeans_lib, outlier as outlier_lib, policy
from .policy import APConfig, CLAQConfig, ORConfig  # re-export  # noqa: F401
from .quantized import QuantizedTensor, build_quantized_tensor

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MatrixPlan:
    """Host-side allocation decisions for one matrix (all static)."""
    column_bits: np.ndarray      # (cols,) int
    reserve_counts: np.ndarray   # (cols,) int
    achieved_code_bits: float
    achieved_extra_bits: float
    outlier_ratio: np.ndarray    # (cols,) float — the Outlier Order metric


@dataclasses.dataclass(frozen=True)
class QuantStats:
    proxy_loss: float          # tr((W-Q) H (W-Q)^T) / rows
    mse: float
    effective_bits: float      # codes + reserved outliers
    effective_bits_with_codebooks: float
    code_bits: float
    extra_bits: float


def plan_matrix(W: Array, cfg: CLAQConfig,
                metric: str = "outlier_order",
                act_norm: Optional[Array] = None) -> MatrixPlan:
    """Compute per-column bit-widths and reservation counts.

    metric: 'outlier_order' (paper) or 'magnitude_mp' (Table 3 baseline).
    """
    rows, cols = W.shape
    if metric == "outlier_order":
        R = outlier_lib.outlier_ratio(W, cfg.outlier_standard)
        # Tie-break by normalized column peak magnitude: R_j is quantized in
        # steps of 1/rows, so a term < 1/(2*rows) can never reorder distinct
        # ratios, but it keeps the Outlier Order total when no entry clears
        # S*mean (small calibration-free matrices, near-Gaussian weights) —
        # the ranking limit of Eq. 3 as the outlier standard decreases.
        peak = jnp.max(jnp.abs(W.astype(jnp.float32)), axis=0)
        R = R + peak / (jnp.max(peak) + 1e-30) * (0.5 / rows)
    elif metric == "magnitude_mp":
        R = policy.magnitude_mp_metric(W, act_norm)
    else:
        raise ValueError(metric)

    if cfg.ap is not None:
        bits, code_bits = policy.ap_column_bits(R, cfg.ap)
    else:
        bits = jnp.full((cols,), cfg.bits, jnp.int32)
        code_bits = float(cfg.bits)

    if cfg.orr is not None:
        counts, extra_bits = policy.or_reserve_counts(R, rows, cfg.orr)
    else:
        counts = jnp.zeros((cols,), jnp.int32)
        extra_bits = 0.0

    return MatrixPlan(
        column_bits=np.asarray(bits),
        reserve_counts=np.asarray(counts),
        achieved_code_bits=float(code_bits),
        achieved_extra_bits=float(extra_bits),
        outlier_ratio=np.asarray(R),
    )


def _pad_cols(arr: Array, cols_p: int, value=0):
    pad = cols_p - arr.shape[-1]
    if pad == 0:
        return arr
    widths = [(0, 0)] * (arr.ndim - 1) + [(0, pad)]
    return jnp.pad(arr, widths, constant_values=value)


def quantize_matrix(
    W: Array,
    H: Optional[Array],
    cfg: CLAQConfig,
    plan: Optional[MatrixPlan] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axis: str = "model",
) -> Tuple[QuantizedTensor, Array, QuantStats]:
    """Quantize one (rows=out, cols=in) matrix with the full CLAQ recipe.

    H=None falls back to an identity Hessian (pure weight-space rounding;
    used for ablations and when no calibration data is available).
    Returns (deployable QuantizedTensor, dequantized matrix, stats).
    """
    W = jnp.asarray(W, jnp.float32)
    rows, cols = W.shape
    if plan is None:
        plan = plan_matrix(W, cfg, metric=cfg.metric)
    if H is None:
        H = jnp.eye(cols, dtype=jnp.float32)

    reserved = outlier_lib.topk_per_column_mask(
        W, jnp.asarray(plan.reserve_counts, jnp.int32))

    # Pad the column axis to the GPTQ blocksize (identity-extended Hessian).
    B = cfg.gptq_blocksize
    cols_p = ((cols + B - 1) // B) * B
    Wp = _pad_cols(W, cols_p)
    Hp = jnp.eye(cols_p, dtype=jnp.float32).at[:cols, :cols].set(
        H.astype(jnp.float32))
    bits_p = _pad_cols(jnp.asarray(plan.column_bits, jnp.int32), cols_p,
                       value=int(plan.column_bits.min(initial=cfg.bits)))
    res_p = _pad_cols(reserved, cols_p, value=False)

    U = gptq.prepare_hinv_cholesky(Hp, cfg.percdamp)

    frozen = None
    if cfg.codebook_mode == "frozen":
        weight = jnp.where(res_p, 0.0, 1.0)
        frozen, _ = kmeans_lib.kmeans_columns(
            Wp, k_max=2 ** cfg.p_max, k_valid=2 ** bits_p,
            iters=cfg.kmeans_iters, weight=weight)

    kwargs = dict(
        k_max=2 ** cfg.p_max, blocksize=B, method=cfg.method,
        kmeans_iters=cfg.kmeans_iters, codebook_mode=cfg.codebook_mode,
        frozen_codebooks=frozen,
    )
    if mesh is not None:
        result = _quantize_rowsharded(Wp, U, bits_p, res_p, kwargs, mesh, shard_axis)
    else:
        result = gptq.gptq_quantize_matrix(Wp, U, bits_p, res_p, **kwargs)

    Q = result.Q[:, :cols]
    qt = build_quantized_tensor(
        codes=result.codes[:, :cols],
        codebooks=result.codebooks[:cols],
        column_bits=plan.column_bits,
        reserve_counts=plan.reserve_counts,
        Q=Q,
        reserved_mask=reserved,
    )
    stats = QuantStats(
        proxy_loss=float(gptq.proxy_loss(W, Q, H)),
        mse=float(jnp.mean((W - Q) ** 2)),
        effective_bits=qt.effective_bits(),
        effective_bits_with_codebooks=qt.effective_bits(include_codebooks=True),
        code_bits=plan.achieved_code_bits,
        extra_bits=plan.achieved_extra_bits,
    )
    return qt, Q, stats


def _quantize_rowsharded(Wp, U, bits_p, res_p, kwargs, mesh, shard_axis):
    """Run the GPTQ loop with matrix rows sharded over `shard_axis`."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import shard_map

    def body(Wl, Ul, bl, rl):
        return gptq.gptq_quantize_matrix(
            Wl, Ul, bl, rl, axis_name=shard_axis, **kwargs)

    out_specs = gptq.QuantizeResult(
        Q=P(shard_axis, None), codes=P(shard_axis, None),
        codebooks=P(None, None), reserved=P(shard_axis, None))
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(shard_axis, None), P(None, None), P(None), P(shard_axis, None)),
        out_specs=out_specs,
        check_vma=False,
    )
    res = fn(Wp, U, bits_p, res_p)
    # codebooks are computed replicated per shard; shard_map stacks them —
    # they are identical, so out_specs P(None, None) keeps one copy.
    return res


# ---------------------------------------------------------------------------
# Whole-model quantization
# ---------------------------------------------------------------------------

def default_quantize_predicate(path: str, leaf: Any) -> bool:
    """Quantize 2-D matmul weights; leave embeddings, norms, biases, and
    tiny recurrence parameters (SSM decay vectors, conv kernels) in fp."""
    if not hasattr(leaf, "ndim") or leaf.ndim != 2:
        return False
    name = path.lower()
    if any(k in name for k in ("embed", "norm", "bias", "a_log", "dt_bias",
                               "decay", "conv", "pos", "router")):
        return False
    return min(leaf.shape) >= 32


def quantize_model(
    params: Dict[str, Any],
    hessians: Dict[str, Array],
    cfg: CLAQConfig,
    predicate: Callable[[str, Any], bool] = default_quantize_predicate,
    metric: str = "outlier_order",
    dense_output: bool = False,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> Tuple[Dict[str, Any], Dict[str, QuantStats]]:
    """Quantize every eligible kernel in a params pytree.

    Weights are stored in JAX kernel layout (in, out); the engine works in
    paper layout (out, in), so kernels are transposed on the way in/out.
    ``hessians`` maps tap names (the dense() call path) to (in,in) Hessians;
    missing entries fall back to identity.
    Returns (new params with QuantizedTensor (or dense) leaves, stats dict).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out_leaves = []
    stats: Dict[str, QuantStats] = {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if not predicate(name, leaf):
            out_leaves.append(leaf)
            continue
        H = hessians.get(name)
        qt, Q, st = quantize_matrix(jnp.asarray(leaf).T, H, cfg, mesh=mesh)
        stats[name] = st
        out_leaves.append(Q.T.astype(leaf.dtype) if dense_output else qt)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), stats
