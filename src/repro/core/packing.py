"""Bit-packing of quantization codes into uint32 words.

Codes are packed *along the row axis within each column* (a column is one
codebook's stream), matching how the Pallas dequant kernel walks memory:
one packed word yields `32/width` consecutive rows of one column.

Widths 1/2/4/8 divide 32, so tiles stay word-aligned.  3-bit codes are
stored as **two bit-planes** (low 2 bits + high 1 bit, concatenated along
the packed-row axis): exactly 3.0 bits/element, and each plane tiles
cleanly — the TPU-friendly alternative to the GPU habit of 10-codes-in-32
(which can't tile at MXU-aligned block sizes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_PLANES = {1: (1,), 2: (2,), 3: (2, 1), 4: (4,), 8: (8,)}


def plane_widths(bits: int):
    if bits not in _PLANES:
        raise ValueError(f"unsupported bit-width {bits}")
    return _PLANES[bits]


def plane_rows(rows: int, width: int) -> int:
    cpw = 32 // width
    return (rows + cpw - 1) // cpw


def packed_rows(rows: int, bits: int) -> int:
    return sum(plane_rows(rows, w) for w in plane_widths(bits))


def _pack_plane(vals: Array, width: int) -> Array:
    cpw = 32 // width
    rows, cols = vals.shape
    pr = plane_rows(rows, width)
    v = jnp.pad(vals.astype(jnp.uint32), ((0, pr * cpw - rows), (0, 0)))
    v = v.reshape(pr, cpw, cols)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * width)[None, :, None]
    # Disjoint bit-fields: sum == bitwise-or, and sum lowers everywhere.
    return (v << shifts).sum(axis=1, dtype=jnp.uint32)


def _unpack_plane(words: Array, width: int, rows: int) -> Array:
    cpw = 32 // width
    mask = jnp.uint32((1 << width) - 1)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * width)[None, :, None]
    v = (words[:, None, :] >> shifts) & mask
    v = v.reshape(words.shape[0] * cpw, words.shape[1])
    return v[:rows].astype(jnp.int32)


def pack_codes(codes: Array, bits: int) -> Array:
    """(rows, cols) int codes < 2**bits -> (packed_rows(rows,bits), cols) uint32.

    Multi-plane widths concatenate planes along the packed-row axis
    (low-order plane first)."""
    planes = []
    shift = 0
    for w in plane_widths(bits):
        planes.append(_pack_plane((codes >> shift) & ((1 << w) - 1), w))
        shift += w
    return planes[0] if len(planes) == 1 else jnp.concatenate(planes, axis=0)


def unpack_codes(words: Array, bits: int, rows: int) -> Array:
    """(packed_rows, cols) uint32 -> (rows, cols) int32 codes."""
    out = None
    shift = 0
    r0 = 0
    for w in plane_widths(bits):
        pr = plane_rows(rows, w)
        part = _unpack_plane(words[r0:r0 + pr], w, rows) << shift
        out = part if out is None else out | part
        r0 += pr
        shift += w
    return out


def split_planes(words: Array, bits: int, rows: int):
    """Split a packed array into its per-plane arrays (for the kernel path)."""
    parts = []
    r0 = 0
    for w in plane_widths(bits):
        pr = plane_rows(rows, w)
        parts.append(words[r0:r0 + pr])
        r0 += pr
    return tuple(parts)


def storage_bits_per_element(bits: int) -> float:
    """Effective storage cost per element (exact for rows % 32 == 0)."""
    return float(sum(plane_widths(bits)))
