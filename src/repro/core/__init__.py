"""CLAQ core: the paper's contribution as a composable JAX library."""
from .policy import APConfig, CLAQConfig, ORConfig, draft_config  # noqa: F401
from .claq import (  # noqa: F401
    MatrixPlan,
    QuantStats,
    plan_matrix,
    quantize_matrix,
    quantize_model,
    default_quantize_predicate,
)
from .quantized import QuantStripe, QuantizedTensor  # noqa: F401
from .kmeans import kmeans_1d, kmeans_columns, dequantize_codes  # noqa: F401
from .outlier import (  # noqa: F401
    outlier_ratio,
    outlier_order,
    top_fraction_mask,
    topk_per_column_mask,
    layer_outlier_ratio,
)
from .gptq import (  # noqa: F401
    HessianState,
    init_hessian,
    accumulate_hessian,
    finalize_hessian,
    prepare_hinv_cholesky,
    gptq_quantize_matrix,
    proxy_loss,
)
from .rtn import rtn_quantize_matrix  # noqa: F401
from .search import MatrixInfo, heuristic_ap_search  # noqa: F401
