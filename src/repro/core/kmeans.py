"""1-D K-Means codebook generation (paper §3.1).

The paper uses scikit-learn-intelex K-Means on each column of a weight
matrix.  Here it is re-built as a fully `jit`-able, deterministic JAX
routine so it can run (a) inside the blocked GPTQ loop, (b) vmapped over
all columns at once for the fast "frozen codebook" mode, and (c) under
`shard_map` with `psum`'d sufficient statistics when the matrix rows are
sharded across the mesh.

Design choices vs sklearn:
  * init = mid-quantiles of the sorted column (deterministic, no RNG, and
    for 1-D data quantile init is within a small factor of optimal — Lloyd
    then converges in a handful of iterations);
  * fixed iteration count (static shapes for jit) instead of tol-based
    stopping;
  * supports a *dynamic* number of valid centroids `k_valid <= k_max`
    (needed by Adaptive Precision where column bit-width varies at trace
    time) by parking invalid centroids at +inf;
  * supports per-element weights (weight 0 = element excluded, used by
    Outlier Reservation so fp16-reserved entries don't drag centroids).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def _quantile_init(x_sorted: Array, k_max: int, k_valid: Array) -> Array:
    """Centroid init at mid-quantiles of the sorted data.

    Invalid slots (index >= k_valid) are set to +inf so they are never the
    nearest centroid and never receive assignments.
    """
    n = x_sorted.shape[0]
    slot = jnp.arange(k_max)
    pos = (slot.astype(jnp.float32) + 0.5) / jnp.maximum(k_valid, 1).astype(jnp.float32)
    idx = jnp.clip((pos * n).astype(jnp.int32), 0, n - 1)
    c = x_sorted[idx]
    return jnp.where(slot < k_valid, c, jnp.inf)


def _assign(x: Array, centroids: Array) -> Array:
    """Nearest-centroid assignment; +inf centroids are never selected."""
    d = jnp.abs(x[:, None] - centroids[None, :])
    # |x - inf| = inf, but guard NaN (inf - inf) just in case.
    d = jnp.where(jnp.isnan(d), jnp.inf, d)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def _lloyd_stats(x: Array, w: Array, assign: Array, k_max: int):
    """Per-cluster weighted sums and counts (the psum-able statistics)."""
    onehot = jax.nn.one_hot(assign, k_max, dtype=x.dtype) * w[:, None]
    sums = onehot.T @ x
    counts = onehot.sum(axis=0)
    return sums, counts


@functools.partial(jax.jit, static_argnames=("k_max", "iters"))
def kmeans_1d(
    x: Array,
    k_max: int,
    k_valid: Array | int | None = None,
    iters: int = 10,
    weight: Optional[Array] = None,
    axis_name: Optional[str] = None,
):
    """1-D K-Means.

    Args:
      x: (n,) float values of one weight-matrix column (possibly a row-shard
         when ``axis_name`` is set).
      k_max: static maximum number of centroids (= 2**p_hi for AP).
      k_valid: dynamic number of active centroids (<= k_max). None => k_max.
      iters: Lloyd iterations (static).
      weight: optional (n,) weights; 0 excludes an element (OR reservation).
      axis_name: if set, sufficient statistics are ``psum``'d over this mesh
         axis (rows of the matrix sharded across devices) — the distributed
         CLAQ quantizer (DESIGN.md §4).

    Returns:
      centroids: (k_max,) — sorted ascending over valid slots; invalid slots
        hold +inf (callers mask with ``slot < k_valid``).
      codes: (n,) int32 nearest-centroid assignment.
    """
    x = x.astype(jnp.float32)
    n = x.shape[0]
    if k_valid is None:
        k_valid = k_max
    k_valid = jnp.asarray(k_valid, jnp.int32)
    w = jnp.ones((n,), jnp.float32) if weight is None else weight.astype(jnp.float32)

    # Init from quantiles of the *weighted-included* values: push excluded
    # elements to the median so they don't stretch the init range.
    med = jnp.median(x)
    x_incl = jnp.where(w > 0, x, med)
    c = _quantile_init(jnp.sort(x_incl), k_max, k_valid)

    def step(c, _):
        a = _assign(x, c)
        sums, counts = _lloyd_stats(x, w, a, k_max)
        if axis_name is not None:
            sums = jax.lax.psum(sums, axis_name)
            counts = jax.lax.psum(counts, axis_name)
        newc = jnp.where(counts > 0, sums / jnp.maximum(counts, 1e-9), c)
        slot = jnp.arange(k_max)
        newc = jnp.where(slot < k_valid, newc, jnp.inf)
        return newc, None

    c, _ = jax.lax.scan(step, c, None, length=iters)
    # Canonical form: ascending valid centroids (inf slots sort to the end).
    c = jnp.sort(c)
    codes = _assign(x, c)
    return c, codes


def kmeans_columns(
    W: Array,
    k_max: int,
    k_valid: Array | int | None = None,
    iters: int = 10,
    weight: Optional[Array] = None,
):
    """Vectorized per-column K-Means over a (rows, cols) matrix.

    ``k_valid`` may be a scalar or a (cols,) vector (Adaptive Precision).
    Returns (codebooks (cols, k_max), codes (rows, cols)).
    """
    rows, cols = W.shape
    if k_valid is None:
        k_valid = jnp.full((cols,), k_max, jnp.int32)
    k_valid = jnp.broadcast_to(jnp.asarray(k_valid, jnp.int32), (cols,))
    if weight is None:
        weight = jnp.ones_like(W, dtype=jnp.float32)

    def one(col, kv, wcol):
        return kmeans_1d(col, k_max=k_max, k_valid=kv, iters=iters, weight=wcol)

    cb, codes = jax.vmap(one, in_axes=(1, 0, 1), out_axes=(0, 1))(W, k_valid, weight)
    return cb, codes


def dequantize_codes(codebooks: Array, codes: Array) -> Array:
    """codes (rows, cols) + codebooks (cols, k) -> values (rows, cols)."""
    safe_cb = jnp.where(jnp.isfinite(codebooks), codebooks, 0.0)
    return jnp.take_along_axis(safe_cb.T, codes, axis=0)


def inertia(x: Array, centroids: Array, weight: Optional[Array] = None) -> Array:
    """Weighted within-cluster sum of squares (quality metric for tests)."""
    codes = _assign(x, centroids)
    safe = jnp.where(jnp.isfinite(centroids), centroids, 0.0)
    err = x - safe[codes]
    w = jnp.ones_like(x) if weight is None else weight
    return jnp.sum(w * err * err)
