"""Blocked GPTQ (OBS-style) quantize-and-compensate engine with pluggable
column quantizers (K-Means / uniform), per-column bit-widths (Adaptive
Precision) and per-column fp16 outlier reservation (OR).

Layout convention follows the paper: W has shape (rows=out_features,
cols=in_features); the Hessian H = X^T X is (cols, cols) over *input*
features, and columns are quantized sequentially with lazy blocked error
compensation exactly as in GPTQ (Frantar et al. 2022):

    U = cholesky(inv(H + damp*I), upper)
    for each column j (in blocks of `blocksize`):
        q_j   = Quant(w_j)                # K-Means / uniform, bits_j levels
        err_j = (w_j - q_j) / U[j, j]
        W[:, j+1:] -= err_j  U[j, j+1:]   # within block eagerly, rest lazily

Everything is jit-able: the column loop is a `lax.fori_loop`, bit-widths and
reservation masks are dynamic per column, and the K-Means sub-solver runs on
static `k_max` slots with a dynamic valid count (kmeans.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import kmeans as kmeans_lib

Array = jax.Array


# ---------------------------------------------------------------------------
# Hessian plumbing
# ---------------------------------------------------------------------------

class HessianState(NamedTuple):
    H: Array          # (in_dim, in_dim) running sum of 2 * x x^T
    count: Array      # scalar, tokens accumulated


def init_hessian(in_dim: int, dtype=jnp.float32) -> HessianState:
    return HessianState(jnp.zeros((in_dim, in_dim), dtype), jnp.zeros((), jnp.float32))


@jax.jit
def accumulate_hessian(state: HessianState, x: Array) -> HessianState:
    """x: (..., in_dim) calibration activations feeding this matrix."""
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return HessianState(state.H + 2.0 * (x2.T @ x2), state.count + x2.shape[0])


def finalize_hessian(state: HessianState) -> Array:
    return state.H / jnp.maximum(state.count, 1.0)


def prepare_hinv_cholesky(H: Array, percdamp: float = 0.01) -> Array:
    """GPTQ's preconditioner: U = cholesky(inv(H_damped), upper).

    Dead input dims (zero diag) get their diagonal set to 1 (their weights
    are then quantized without compensation, as in reference GPTQ).
    """
    d = jnp.diag(H)
    dead = d <= 0.0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    damp = percdamp * jnp.mean(jnp.where(dead, 0.0, d))
    Hd = H + damp * jnp.eye(H.shape[0], dtype=H.dtype)
    L = jnp.linalg.cholesky(Hd)
    Hinv = jax.scipy.linalg.cho_solve((L, True), jnp.eye(H.shape[0], dtype=H.dtype))
    Hinv = (Hinv + Hinv.T) * 0.5
    # Upper Cholesky factor: Hinv = U^T U with U = L^T (L the lower factor).
    return jnp.linalg.cholesky(Hinv).T


def proxy_loss(W: Array, Q: Array, H: Array) -> Array:
    """Calibration-set quantization objective tr((W-Q) H (W-Q)^T) / rows."""
    D = (W - Q).astype(jnp.float32)
    return jnp.einsum("ri,ij,rj->", D, H.astype(jnp.float32), D) / W.shape[0]


# ---------------------------------------------------------------------------
# Column quantizers
# ---------------------------------------------------------------------------

def _uniform_codebook(w: Array, k_max: int, k_valid: Array, weight: Array) -> Array:
    """Asymmetric min-max uniform grid over the non-reserved entries
    (== GPTQ's per-column asymmetric quantizer, expressed as a codebook)."""
    big = jnp.float32(3.4e38)
    lo = jnp.min(jnp.where(weight > 0, w, big))
    hi = jnp.max(jnp.where(weight > 0, w, -big))
    lo = jnp.minimum(lo, hi)  # guard fully-reserved columns
    slot = jnp.arange(k_max, dtype=jnp.float32)
    denom = jnp.maximum(k_valid.astype(jnp.float32) - 1.0, 1.0)
    cb = lo + (hi - lo) * slot / denom
    return jnp.where(jnp.arange(k_max) < k_valid, cb, jnp.inf)


def _column_codebook(
    w: Array, k_max: int, k_valid: Array, weight: Array,
    method: str, kmeans_iters: int, axis_name: Optional[str] = None,
) -> Array:
    if axis_name is not None:
        # Row-sharded quantization (shard_map): one column is tiny, so gather
        # it whole — every shard then fits the *identical* codebook (exact
        # parity with the unsharded path), while the O(rows*cols) GPTQ
        # updates stay sharded.  (kmeans_1d also supports psum'd statistics
        # via axis_name for the fully-distributed variant.)
        w = jax.lax.all_gather(w, axis_name, tiled=True)
        weight = jax.lax.all_gather(weight, axis_name, tiled=True)
    if method == "kmeans":
        cb, _ = kmeans_lib.kmeans_1d(
            w, k_max=k_max, k_valid=k_valid, iters=kmeans_iters, weight=weight)
        return cb
    elif method == "uniform":
        return _uniform_codebook(w, k_max, k_valid, weight)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# The blocked GPTQ loop
# ---------------------------------------------------------------------------

class QuantizeResult(NamedTuple):
    Q: Array           # (rows, cols) dequantized (reserved entries at fp value)
    codes: Array       # (rows, cols) int32 centroid indices
    codebooks: Array   # (cols, k_max) f32, +inf in invalid slots
    reserved: Array    # (rows, cols) bool — fp16-reserved entries


@functools.partial(
    jax.jit,
    static_argnames=("k_max", "blocksize", "method", "kmeans_iters", "codebook_mode", "axis_name"),
)
def gptq_quantize_matrix(
    W: Array,
    U: Array,
    column_bits: Array,
    reserved_mask: Array,
    *,
    k_max: int,
    blocksize: int = 128,
    method: str = "kmeans",
    kmeans_iters: int = 10,
    codebook_mode: str = "live",
    frozen_codebooks: Optional[Array] = None,
    axis_name: Optional[str] = None,
) -> QuantizeResult:
    """Quantize W (rows, cols) column-by-column with OBS compensation.

    Args:
      U: upper-triangular preconditioner from ``prepare_hinv_cholesky``.
      column_bits: (cols,) int — per-column bit-width (AP); k_valid = 2**bits.
      reserved_mask: (rows, cols) bool — entries kept in fp16 (OR). Reserved
        entries contribute zero quantization error and are excluded from
        codebook fitting.
      codebook_mode: 'live' refits the codebook on the GPTQ-compensated
        column at quantization time (paper-faithful); 'frozen' uses
        ``frozen_codebooks`` computed from the original weights (fast mode).
    """
    rows, cols = W.shape
    assert cols % blocksize == 0, "pad columns to a multiple of blocksize"
    nblocks = cols // blocksize
    W = W.astype(jnp.float32)
    U = U.astype(jnp.float32)

    if frozen_codebooks is None:
        frozen_codebooks = jnp.full((cols, k_max), jnp.inf, jnp.float32)

    def quant_column(w, j):
        kv = (2 ** column_bits[j]).astype(jnp.int32)
        rmask = reserved_mask[:, j]
        weight = jnp.where(rmask, 0.0, 1.0)
        if codebook_mode == "frozen":
            cb = frozen_codebooks[j]
        else:
            cb = _column_codebook(w, k_max, kv, weight, method, kmeans_iters,
                                  axis_name=axis_name)
        codes = kmeans_lib._assign(w, cb)
        safe = jnp.where(jnp.isfinite(cb), cb, 0.0)
        q = jnp.where(rmask, w, safe[codes])
        return q, codes, cb

    def block_body(b, carry):
        W, codes_all, cb_all = carry
        j0 = b * blocksize
        Wb = jax.lax.dynamic_slice(W, (0, j0), (rows, blocksize))
        Ub = jax.lax.dynamic_slice(U, (j0, j0), (blocksize, blocksize))

        def col_body(i, inner):
            Wb, Qb, Eb, codes_b, cb_b = inner
            w = Wb[:, i]
            q, codes, cb = quant_column(w, j0 + i)
            d = jnp.maximum(Ub[i, i], 1e-12)  # Cholesky diag is positive
            err = (w - q) / d
            upd_mask = (jnp.arange(blocksize) > i).astype(jnp.float32)
            Wb = Wb - jnp.outer(err, Ub[i] * upd_mask)
            Qb = Qb.at[:, i].set(q)
            Eb = Eb.at[:, i].set(err)
            codes_b = codes_b.at[:, i].set(codes)
            cb_b = cb_b.at[i].set(cb)
            return (Wb, Qb, Eb, codes_b, cb_b)

        init = (
            Wb,
            jnp.zeros((rows, blocksize), jnp.float32),
            jnp.zeros((rows, blocksize), jnp.float32),
            jnp.zeros((rows, blocksize), jnp.int32),
            jnp.full((blocksize, k_max), jnp.inf, jnp.float32),
        )
        _, Qb, Eb, codes_b, cb_b = jax.lax.fori_loop(0, blocksize, col_body, init)

        # Lazy update of all later columns: W[:, j0+B:] -= Eb @ U[j0:j0+B, j0+B:]
        Uband = jax.lax.dynamic_slice(U, (j0, 0), (blocksize, cols))
        later = (jnp.arange(cols) >= j0 + blocksize).astype(jnp.float32)
        W = W - Eb @ (Uband * later[None, :])
        W = jax.lax.dynamic_update_slice(W, Qb, (0, j0))
        codes_all = jax.lax.dynamic_update_slice(codes_all, codes_b, (0, j0))
        cb_all = jax.lax.dynamic_update_slice(cb_all, cb_b, (j0, 0))
        return (W, codes_all, cb_all)

    init = (
        W,
        jnp.zeros((rows, cols), jnp.int32),
        jnp.full((cols, k_max), jnp.inf, jnp.float32),
    )
    Wq, codes, cbs = jax.lax.fori_loop(0, nblocks, block_body, init)
    return QuantizeResult(Q=Wq, codes=codes, codebooks=cbs, reserved=reserved_mask)
