"""Bit-budget policies: Adaptive Precision (§3.3) and Outlier Reservation (§3.4).

Both are driven by the Outlier Order metric (outlier.py).  The policies are
pure functions from (R, budget) -> per-column allocations, so they are
testable against exact-budget invariants and reusable by the Appendix-G
heuristic search.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import outlier as outlier_lib

Array = jax.Array

# Storage cost of one reserved fp16 outlier: 16-bit value + 16-bit row index.
BITS_PER_RESERVED_OUTLIER = 32.0


@dataclasses.dataclass(frozen=True)
class APConfig:
    """Two-level Adaptive Precision (paper keeps |B|=2 for kernel simplicity)."""
    target_bits: float
    p_lo: int = 2
    p_hi: int = 4


@dataclasses.dataclass(frozen=True)
class ORConfig:
    """Column-level adaptive outlier reservation.

    ``extra_bits`` is the reservation budget expressed in average bits per
    element (paper's fusion models use 0.07 / 0.13).  ``o1``/``o2`` split the
    global outlier count between the top ``top_frac`` sensitive columns and
    the rest (paper Appendix C, Setting 2: 28%/72%).
    """
    extra_bits: float
    o1: float = 0.28
    o2: float = 0.72
    top_frac: float = 0.10


@dataclasses.dataclass(frozen=True)
class CLAQConfig:
    """Full per-matrix quantization recipe.

    method: 'kmeans' (paper), 'uniform' (GPTQ-style minmax grid baseline),
            'rtn' (no GPTQ compensation).
    """
    bits: int = 4
    method: str = "kmeans"
    ap: Optional[APConfig] = None
    orr: Optional[ORConfig] = None
    outlier_standard: float = outlier_lib.DEFAULT_OUTLIER_STANDARD
    kmeans_iters: int = 10
    gptq_blocksize: int = 128
    percdamp: float = 0.01
    # 'frozen' computes codebooks once from the original weights (vectorized,
    # fast, parallel); 'live' re-clusters each column on the GPTQ-compensated
    # values at quantization time (paper-faithful).
    codebook_mode: str = "live"
    # AP/OR sensitivity metric: 'outlier_order' (paper) or 'magnitude_mp'
    # (Table 3's MP-dagger baseline)
    metric: str = "outlier_order"

    @property
    def p_max(self) -> int:
        return self.ap.p_hi if self.ap is not None else self.bits


def draft_config(qcfg: CLAQConfig, draft_bits: int) -> CLAQConfig:
    """Derive the low-bit DRAFT recipe for self-speculative decoding from
    the target's recipe: same quantization engine knobs (method, K-Means
    iterations, GPTQ blocksize, damping, codebook mode, metric) so both
    models come out of one calibration pass, but a flat ``draft_bits``
    code width — the draft IS the precision floor, so Adaptive Precision
    is dropped — while Outlier Reservation is kept (a few fp outliers are
    the cheapest accuracy lever at 2-bit, which is what keeps the draft's
    argmax tracking the target's)."""
    if draft_bits < 1:
        raise ValueError(f"draft_bits must be >= 1, got {draft_bits}")
    return dataclasses.replace(qcfg, bits=draft_bits, ap=None)


def ap_column_bits(R: Array, cfg: APConfig) -> Tuple[Array, float]:
    """Per-column bit-widths for a two-level AP scheme.

    The high-precision column count is chosen so the average code bit-width
    equals ``target_bits`` as closely as an integer count allows:
        n_hi = round(cols * (target - p_lo) / (p_hi - p_lo))      (Eq. 4)
    Returns (bits (cols,) int32, achieved average bits).
    """
    cols = R.shape[0]
    frac = (cfg.target_bits - cfg.p_lo) / (cfg.p_hi - cfg.p_lo)
    if not (0.0 <= frac <= 1.0):
        raise ValueError(
            f"target {cfg.target_bits} outside [{cfg.p_lo}, {cfg.p_hi}]")
    n_hi = int(round(frac * cols))
    hi_mask = outlier_lib.top_fraction_mask(R, n_hi / cols if cols else 0.0)
    bits = jnp.where(hi_mask, cfg.p_hi, cfg.p_lo).astype(jnp.int32)
    achieved = (n_hi * cfg.p_hi + (cols - n_hi) * cfg.p_lo) / max(cols, 1)
    return bits, achieved


def or_reserve_counts(
    R: Array, rows: int, cfg: ORConfig
) -> Tuple[Array, float]:
    """Per-column reserved-outlier counts for the OR scheme (Eq. 5).

    Total reserved count N = extra_bits * numel / BITS_PER_RESERVED_OUTLIER,
    split o1 : o2 between the top ``top_frac`` columns and the rest, with the
    same count per column inside each class.
    Returns (counts (cols,) int32, achieved extra bits/element).
    """
    cols = R.shape[0]
    numel = rows * cols
    total = cfg.extra_bits * numel / BITS_PER_RESERVED_OUTLIER
    n_top = max(int(round(cfg.top_frac * cols)), 1)
    n_rest = cols - n_top
    k1 = int(round(cfg.o1 * total / n_top))
    k2 = int(round(cfg.o2 * total / max(n_rest, 1))) if n_rest else 0
    k1 = min(k1, rows)
    k2 = min(k2, rows)
    top = outlier_lib.top_fraction_mask(R, n_top / cols if cols else 0.0)
    counts = jnp.where(top, k1, k2).astype(jnp.int32)
    achieved = (n_top * k1 + n_rest * k2) * BITS_PER_RESERVED_OUTLIER / max(numel, 1)
    return counts, achieved


def magnitude_mp_metric(W: Array, act_norm: Optional[Array] = None) -> Array:
    """Baseline mixed-precision metric (Table 3's MP†): activation-to-weight
    salience per column, |w|·||x|| style, following SparseGPT's criterion.

    act_norm: (cols,) mean L2 of the calibration activations per input dim;
    when None, plain column magnitude is used.
    """
    col_mag = jnp.mean(jnp.abs(W.astype(jnp.float32)), axis=0)
    if act_norm is None:
        return col_mag
    return col_mag * act_norm.astype(jnp.float32)


def codebook_overhead_bits(rows: int, bits_per_col: Array, k_max: int) -> float:
    """Average per-element overhead of storing per-column codebooks:
    2**bits fp16 entries per column spread over `rows` elements."""
    entries = jnp.sum(2.0 ** bits_per_col.astype(jnp.float32))
    return float(entries * 16.0 / (rows * bits_per_col.shape[0]))


def effective_bits(
    rows: int,
    bits_per_col: Array,
    reserve_counts: Optional[Array] = None,
) -> float:
    """Average stored bits/element: codes + codebooks + reserved outliers.

    Matches the paper's accounting convention (code bits + reservation bits;
    codebook amortization reported separately since the paper folds it into
    "comparable codebook size" claims).
    """
    cols = bits_per_col.shape[0]
    code_bits = float(jnp.sum(bits_per_col)) / cols
    extra = 0.0
    if reserve_counts is not None:
        extra = float(jnp.sum(reserve_counts)) * BITS_PER_RESERVED_OUTLIER / (rows * cols)
    return code_bits + extra
