"""Outlier Order — the column-wise quantization-sensitivity metric (paper §3.2).

R_j = |{ i : |W_ij| > S * mean(|W|) }| / rows            (paper Eq. 3)

S is the "outlier standard" (paper Appendix B finds S=13 best; we default to
that).  The ranking of R_j ("Outlier Order") drives both Adaptive Precision
and Outlier Reservation.  Computed once per matrix, O(numel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

DEFAULT_OUTLIER_STANDARD = 13.0


def outlier_ratio(W: Array, standard: float = DEFAULT_OUTLIER_STANDARD) -> Array:
    """Per-column outlier ratio R_j (Eq. 3). W: (rows, cols) -> (cols,)."""
    absW = jnp.abs(W.astype(jnp.float32))
    thresh = standard * jnp.mean(absW)
    return jnp.mean((absW > thresh).astype(jnp.float32), axis=0)


def outlier_order(R: Array) -> Array:
    """Columns sorted by descending sensitivity. Ties broken by column index
    (stable) so allocations are deterministic."""
    return jnp.argsort(-R, stable=True).astype(jnp.int32)


def top_fraction_mask(R: Array, fraction: float) -> Array:
    """Boolean mask of the ceil(fraction*cols) most sensitive columns.

    Implemented by rank (argsort of argsort) rather than a value threshold so
    the *count* is exact even with ties — the bit-budget accounting depends
    on exact counts (paper's T_AP / T_OR thresholds are defined by count).
    """
    cols = R.shape[0]
    n_top = int(round(fraction * cols))
    order = outlier_order(R)
    rank = jnp.zeros((cols,), jnp.int32).at[order].set(jnp.arange(cols, dtype=jnp.int32))
    return rank < n_top


def topk_per_column_mask(W: Array, counts: Array) -> Array:
    """Boolean (rows, cols) mask of the `counts[j]` largest-|.| entries per column.

    Used by Outlier Reservation: the same number of largest-magnitude
    parameters is reserved in each column of a sensitivity class (§3.4 —
    "the same number of the largest and smallest parameters are reserved").
    `counts` is a (cols,) int vector (dynamic), mask is rank-based.
    """
    absW = jnp.abs(W)
    # rank 0 = largest magnitude in its column
    order = jnp.argsort(-absW, axis=0, stable=True)
    rank = jnp.zeros_like(order).at[order, jnp.arange(W.shape[1])[None, :]].set(
        jnp.arange(W.shape[0], dtype=order.dtype)[:, None]
    )
    return rank < counts[None, :].astype(rank.dtype)


def layer_outlier_ratio(W: Array, standard: float = DEFAULT_OUTLIER_STANDARD) -> Array:
    """Whole-matrix outlier ratio (Appendix A / G: matrix-level ranking)."""
    absW = jnp.abs(W.astype(jnp.float32))
    thresh = standard * jnp.mean(absW)
    return jnp.mean((absW > thresh).astype(jnp.float32))
