"""Round-To-Nearest baselines (no GPTQ error compensation).

Two codebook flavours so the paper's ablation axes separate cleanly:
  * 'uniform'  — per-column asymmetric min-max grid (the classic RTN baseline
                 in Table 1);
  * 'kmeans'   — CLAQ's codebooks *without* compensation (isolates the value
                 of K-Means centroids from the value of OBS updates).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kmeans as kmeans_lib

Array = jax.Array


def rtn_quantize_matrix(
    W: Array,
    bits: int,
    method: str = "uniform",
    kmeans_iters: int = 10,
    reserved_mask: Optional[Array] = None,
):
    """Quantize all columns independently. Returns (Q, codes, codebooks)."""
    W = W.astype(jnp.float32)
    rows, cols = W.shape
    k = 2 ** bits
    weight = None
    if reserved_mask is not None:
        weight = jnp.where(reserved_mask, 0.0, 1.0)

    if method == "kmeans":
        cbs, codes = kmeans_lib.kmeans_columns(W, k_max=k, iters=kmeans_iters,
                                               weight=weight)
    elif method == "uniform":
        wsel = W if weight is None else jnp.where(weight > 0, W, jnp.nan)
        lo = jnp.nanmin(wsel, axis=0)
        hi = jnp.nanmax(wsel, axis=0)
        lo = jnp.where(jnp.isnan(lo), 0.0, lo)
        hi = jnp.where(jnp.isnan(hi), 0.0, hi)
        grid = lo[:, None] + (hi - lo)[:, None] * (
            jnp.arange(k, dtype=jnp.float32)[None, :] / max(k - 1, 1))
        cbs = grid  # (cols, k)
        codes = jax.vmap(kmeans_lib._assign, in_axes=(1, 0), out_axes=1)(W, cbs)
    else:
        raise ValueError(method)

    Q = kmeans_lib.dequantize_codes(cbs, codes)
    if reserved_mask is not None:
        Q = jnp.where(reserved_mask, W, Q)
    return Q, codes, cbs
