"""QuantizedTensor — the deployable storage format produced by CLAQ.

Layout (DESIGN.md §4, "AP inside one kernel, no ragged tiles"):

  * Columns are *permuted* so each Adaptive-Precision bit-class occupies a
    contiguous stripe; each stripe is a dense (packed codes, codebooks) pair
    with a single static bit-width — uniform tiles for the Pallas kernel.
  * Outlier Reservation is stored structurally: per column, a fixed number
    of (row index, fp value) pairs — dense (k_max, cols) planes with a valid
    count per column.  No CSR, no scatter at inference.
  * ``col_perm[p]`` = original column index stored at permuted position p.

The object is a registered pytree, so it can sit inside a params tree and
flow through jit/pjit; static metadata (shape, bit-widths) lives in the
treedef.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import packing

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantStripe:
    packed: Array     # (packed_rows, n_cols_stripe) uint32
    codebook: Array   # (n_cols_stripe, 2**bits) float32 (invalid slots = 0)
    bits: int         # static

    @property
    def n_cols(self) -> int:
        # last axis: holds for per-matrix (packed_rows, n_cols) leaves AND
        # layer-stacked (L, ..., packed_rows, n_cols) leaves alike
        return self.packed.shape[-1]


jax.tree_util.register_dataclass(
    QuantStripe, data_fields=["packed", "codebook"], meta_fields=["bits"])


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Quantized (rows, cols) matrix in paper layout (rows=out, cols=in)."""
    stripes: Tuple[QuantStripe, ...]
    col_perm: Array    # (cols,) int32 — original col index per permuted slot
    out_idx: Array     # (k_out_max, cols) int32 — row indices, ORIGINAL col order
    out_val: Array     # (k_out_max, cols) float32
    out_count: Array   # (cols,) int32 — valid reserved entries per column
    shape: Tuple[int, int]   # static (rows, cols)

    @property
    def rows(self) -> int:
        return self.shape[0]

    @property
    def cols(self) -> int:
        return self.shape[1]

    def dequantize(self, dtype=jnp.float32) -> Array:
        """Reference dequantization — the jnp oracle the kernels test against."""
        rows, cols = self.shape
        parts = []
        for s in self.stripes:
            codes = packing.unpack_codes(s.packed, s.bits, rows)
            parts.append(jnp.take_along_axis(s.codebook.T.astype(jnp.float32),
                                             codes, axis=0))
        Wp = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        # Un-permute columns: position p holds original column col_perm[p].
        W = jnp.zeros((rows, cols), jnp.float32).at[:, self.col_perm].set(Wp)
        if self.out_idx.shape[0] > 0:
            k = self.out_idx.shape[0]
            valid = jnp.arange(k)[:, None] < self.out_count[None, :]
            colj = jnp.broadcast_to(jnp.arange(cols)[None, :], self.out_idx.shape)
            safe_idx = jnp.where(valid, self.out_idx, rows)  # OOB -> dropped
            W = W.at[safe_idx, colj].set(self.out_val, mode="drop")
        return W.astype(dtype)

    def prepare(self, **kwargs):
        """Compile (and cache) the ahead-of-time inference plan for this
        tensor (kernels.plan.prepare_for_inference).  The cache lives on
        the instance, outside the pytree data fields, so it never flows
        through jit; it is keyed on the requested block sizes, so callers
        tuning bn/bk never get a stale plan."""
        key = tuple(sorted(kwargs.items()))
        cache = object.__getattribute__(self, "__dict__").setdefault(
            "_plans", {})
        plan = cache.get(key)
        if plan is None:
            from repro.kernels.plan import prepare_for_inference
            plan = cache[key] = prepare_for_inference(self, **kwargs)
        return plan

    def effective_bits(self, include_codebooks: bool = False) -> float:
        rows, cols = self.shape
        code_bits = sum(packing.storage_bits_per_element(s.bits) * rows * s.n_cols
                        for s in self.stripes)
        outlier_bits = float(np.sum(np.asarray(self.out_count))) * 32.0
        total = code_bits + outlier_bits
        if include_codebooks:
            total += sum(s.codebook.shape[0] * s.codebook.shape[1] * 16.0
                         for s in self.stripes)
        return total / (rows * cols)


jax.tree_util.register_dataclass(
    QuantizedTensor,
    data_fields=["stripes", "col_perm", "out_idx", "out_val", "out_count"],
    meta_fields=["shape"],
)


def build_quantized_tensor(
    codes: Array,              # (rows, cols) int32 (original column order)
    codebooks: Array,          # (cols, k_max) f32 with +inf invalid slots
    column_bits: np.ndarray,   # (cols,) host ints
    reserve_counts: np.ndarray,  # (cols,) host ints
    Q: Array,                  # (rows, cols) final dequantized (for outlier values)
    reserved_mask: Array,      # (rows, cols) bool
) -> QuantizedTensor:
    """Assemble the deployment format from a gptq.QuantizeResult."""
    rows, cols = codes.shape
    column_bits = np.asarray(column_bits)
    reserve_counts = np.asarray(reserve_counts)

    # --- stripes (stable order: ascending bit-width, original index within) --
    stripes = []
    perm_parts = []
    for b in sorted(set(int(x) for x in column_bits.tolist())):
        idx = np.nonzero(column_bits == b)[0].astype(np.int32)
        perm_parts.append(idx)
        sub_codes = jnp.take(codes, jnp.asarray(idx), axis=1)
        sub_cb = jnp.take(codebooks, jnp.asarray(idx), axis=0)[:, : 2 ** b]
        sub_cb = jnp.where(jnp.isfinite(sub_cb), sub_cb, 0.0).astype(jnp.float32)
        stripes.append(QuantStripe(
            packed=packing.pack_codes(sub_codes, b),
            codebook=sub_cb,
            bits=b,
        ))
    col_perm = jnp.asarray(np.concatenate(perm_parts), jnp.int32)

    # --- structured outliers (original column order) -------------------------
    k_max = int(reserve_counts.max()) if reserve_counts.size else 0
    if k_max > 0:
        # Rank rows per column by reservation: reserved entries are exactly
        # the top-count magnitude entries, so sort the mask (desc) to get
        # their row indices in the first `count` slots.
        order = jnp.argsort(-reserved_mask.astype(jnp.int32), axis=0, stable=True)
        out_idx = order[:k_max].astype(jnp.int32)
        colj = jnp.broadcast_to(jnp.arange(cols)[None, :], out_idx.shape)
        out_val = Q[out_idx, colj].astype(jnp.float32)
        out_count = jnp.asarray(reserve_counts, jnp.int32)
    else:
        out_idx = jnp.zeros((0, cols), jnp.int32)
        out_val = jnp.zeros((0, cols), jnp.float32)
        out_count = jnp.zeros((cols,), jnp.int32)

    return QuantizedTensor(
        stripes=tuple(stripes), col_perm=col_perm,
        out_idx=out_idx, out_val=out_val, out_count=out_count,
        shape=(rows, cols),
    )
