"""Heuristic adaptive-precision search across matrices (paper Appendix G).

Each matrix may be assigned one of three classes: pure p_lo, a p_lo&3 mix,
or a p_lo&4 mix.  Matrices are ranked by whole-matrix outlier ratio
(HAWQ-v2-flavoured), and we enumerate feasible (class counts, high-precision
column fraction) combinations under the model-size constraint, scoring each
by the paper's precision score:

    PS_total = OR_4 * PS_4 * p_4 * M_4 + OR_3 * PS_3 * p_3 * M_3     (Eq. 7)

The configuration with the maximal score wins.  This module is pure host
Python over per-matrix summary statistics, so it is fast and testable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MatrixInfo:
    name: str
    rows: int
    cols: int
    outlier_ratio: float   # whole-matrix (Appendix A)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    # name -> (bit_pair, high_fraction); bit_pair like (2, 4) or (2, 2)=pure
    assignment: Dict[str, Tuple[Tuple[int, int], float]]
    avg_bits: float
    score: float


def heuristic_ap_search(
    matrices: Sequence[MatrixInfo],
    target_bits: float,
    p_lo: int = 2,
    ps3: float = 3.0,
    ps4: float = 4.0,
    fractions: Sequence[float] = (0.0, 0.05, 0.1, 0.2, 0.3, 0.4, 0.526, 0.6),
) -> SearchResult:
    """Enumerate (M4, M3, p4, p3) splits and pick the max-precision-score one.

    Matrices sorted by outlier ratio; the top M4 get the (p_lo,4) mix at
    fraction p4, the next M3 get (p_lo,3) at fraction p3, the rest pure p_lo.
    Budget: average bits over all elements <= target_bits.
    """
    mats = sorted(matrices, key=lambda m: -m.outlier_ratio)
    sizes = np.array([m.rows * m.cols for m in mats], dtype=np.float64)
    ors = np.array([m.outlier_ratio for m in mats], dtype=np.float64)
    total = sizes.sum()
    n = len(mats)

    # candidate counts: coarse grid to keep enumeration tractable at n~200
    count_grid = sorted({0, 1, 2, 4, 8, 16, 19, 32, 64, n // 4, n // 2, n})
    count_grid = [c for c in count_grid if 0 <= c <= n]

    best: SearchResult | None = None
    for m4 in count_grid:
        for m3 in count_grid:
            if m4 + m3 > n:
                continue
            for p4 in fractions:
                for p3 in fractions:
                    s4 = sizes[:m4]
                    s3 = sizes[m4:m4 + m3]
                    s2 = sizes[m4 + m3:]
                    bits = (np.sum(s4) * (p_lo + p4 * (4 - p_lo))
                            + np.sum(s3) * (p_lo + p3 * (3 - p_lo))
                            + np.sum(s2) * p_lo) / total
                    if bits > target_bits + 1e-9:
                        continue
                    score = (float(np.sum(ors[:m4])) * ps4 * p4 * max(m4, 1)
                             + float(np.sum(ors[m4:m4 + m3])) * ps3 * p3 * max(m3, 1))
                    if best is None or score > best.score:
                        assignment = {}
                        for i, m in enumerate(mats):
                            if i < m4:
                                assignment[m.name] = ((p_lo, 4), p4)
                            elif i < m4 + m3:
                                assignment[m.name] = ((p_lo, 3), p3)
                            else:
                                assignment[m.name] = ((p_lo, p_lo), 0.0)
                        best = SearchResult(assignment=assignment,
                                            avg_bits=float(bits), score=float(score))
    assert best is not None
    return best


def assignment_to_claq_configs(result: SearchResult, base_cfg) -> Dict[str, object]:
    """Materialize per-matrix CLAQConfig objects from a search result."""
    from .policy import APConfig, CLAQConfig
    out = {}
    for name, ((lo, hi), frac) in result.assignment.items():
        if hi == lo or frac == 0.0:
            cfg = dataclasses.replace(base_cfg, bits=lo, ap=None)
        else:
            target = lo + frac * (hi - lo)
            cfg = dataclasses.replace(
                base_cfg, bits=lo,
                ap=APConfig(target_bits=target, p_lo=lo, p_hi=hi))
        out[name] = cfg
    return out
