"""Serving launcher: quantize (optional) + batched engine demo.

  PYTHONPATH=src python -m repro.launch.serve --arch llama1_7b --smoke \
      --bits 3 --requests 8

Multi-device serving: ``--mesh-shape 2x4`` (or ``--dp 2 --tp 4``) builds a
(data, model) mesh and wires the engine onto it — prepared CLAQ plans
shard along N over "model" (whole (bn, bk) tile groups per shard), the
slot cache shards over "dp".  On a single host, force device count first:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Lifecycle / robustness knobs (DESIGN.md §10): ``--queue-depth`` bounds
the admission queue (requests beyond it see backpressure and wait in the
launcher), ``--deadline-ms`` attaches an SLO deadline to every request
(expired work is ABANDONED, queued or running), ``--guards`` folds the
per-step finite check into the decode jit (non-finite rows quarantine
only their own request), and ``--inject-faults`` drives the whole thing
with a seeded deterministic fault plan (NaN/Inf logits, cache-pressure
windows forcing preemption+resume, transient step failures absorbed by
bounded retry) — the demo must end with every request terminal.

Telemetry / replay (DESIGN.md §13): ``--telemetry`` attaches the
per-request span recorder (zero overhead when off), ``--replay-trace
trace.jsonl`` drives submissions from a JSONL arrival trace instead of
``--requests`` (synthesize one with ``python -m repro.serve.replay``),
``--report-json out.json`` writes the end-of-run scheduling report
(TTFT/TPOT p50/p90/p99, tokens/s/slot, queue/occupancy timelines,
preemption accounting), ``--telemetry-trace out.json`` writes a
Chrome/Perfetto ``trace_event`` file (one track per slot — open it at
ui.perfetto.dev), and ``--stats`` prints every engine counter through
the ONE uniform metrics registry instead of ad-hoc dicts.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize, claq_quantize_with_draft
from repro.models import api
from repro.serve import (AdmissionController, AdmissionRejected,
                         FaultInjector, Replayer, RetryPolicy, ServingEngine,
                         SLOConfig, SpecConfig, StepClock, StepCostModel,
                         Telemetry, build_report, load_trace,
                         write_perfetto)


def _build_mesh(args):
    """Resolve --mesh-shape / --dp / --tp into a (data, model) mesh, or
    None for single-device serving."""
    if args.mesh_shape:
        try:
            dp, tp = (int(v) for v in args.mesh_shape.lower().split("x"))
        except ValueError as e:
            raise SystemExit(
                f"--mesh-shape must be DPxTP (e.g. 2x4), got "
                f"{args.mesh_shape!r}") from e
    else:
        dp, tp = max(args.dp, 1), max(args.tp, 1)
    if dp * tp <= 1:
        return None
    n_dev = len(jax.devices())
    if dp * tp > n_dev:
        raise SystemExit(
            f"mesh {dp}x{tp} needs {dp * tp} devices, have {n_dev} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"to emulate on one host)")
    return jax.make_mesh((dp, tp), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=float, default=0,
                    help="0 = fp; else CLAQ-quantize to this avg bit-width")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=16,
                    help="smallest prefill length bucket")
    ap.add_argument("--no-bucketing", action="store_true",
                    help="admit at exact prompt lengths (one compile each)")
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="speculation window length; 0 = vanilla decode. "
                         ">0 quantizes a low-bit draft of the same "
                         "checkpoint from the same calibration pass and "
                         "serves with propose/verify/rollback windows "
                         "(lossless for greedy decoding)")
    ap.add_argument("--draft-bits", type=int, default=2,
                    help="code bit-width of the speculative draft model")
    ap.add_argument("--draft-plan-bn", type=int, default=0,
                    help="plan N-tile cap for the DRAFT's prepared plans "
                         "(0 = inherit the target's; the 2-bit draft's "
                         "skinnier groups often want smaller tiles)")
    ap.add_argument("--draft-plan-bk", type=int, default=0,
                    help="plan K-block cap for the draft's prepared plans "
                         "(0 = inherit)")
    ap.add_argument("--act-dtype", choices=("f32", "int8"), default="f32",
                    help="activation precision for quantized matmuls: int8 "
                         "= per-token dynamic absmax quantization folded "
                         "into the fused kernel (opt-in; changes numerics "
                         "within the documented bound, DESIGN.md §9)")
    ap.add_argument("--kv-layout", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="slot KV cache layout: contiguous (one "
                         "(max_len,) strip per slot) or paged (global "
                         "page pool + per-slot page tables; pages "
                         "allocated on demand, freed at retirement, "
                         "shared across common prompt prefixes — "
                         "DESIGN.md §11)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout; must divide "
                         "--max-len)")
    ap.add_argument("--kv-pool-pages", type=int, default=0,
                    help="page-pool size (paged layout; 0 = capacity-"
                         "equivalent to contiguous: slots * max_len / "
                         "page_size).  Larger overcommits admission "
                         "against typed PoolExhausted backpressure")
    ap.add_argument("--kv-dtype", choices=("f32", "int8"), default="f32",
                    help="resident-page precision (paged layout): int8 "
                         "stores K/V quantized per token row with absmax "
                         "scales (~4x tokens per byte, bounded error, "
                         "no preemption)")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="bounded admission queue depth (0 = engine "
                         "default, 2x slots); submissions beyond it see "
                         "typed backpressure and wait in the launcher")
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="per-request SLO deadline; expired work is "
                         "ABANDONED (queued or running), 0 = none")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="chunked prefill: split admitted prompts' prefill "
                         "into fixed chunks of this many tokens interleaved "
                         "with decode (0 = monolithic; must divide "
                         "--max-len; bitwise-identical token streams, "
                         "DESIGN.md §14)")
    ap.add_argument("--slo-ttft-p99-ms", type=float, default=0,
                    help="attach the SLO-guarded admission controller "
                         "defending this p99 TTFT target via the "
                         "graceful-degradation ladder (0 = off)")
    ap.add_argument("--controller-mode", choices=("admission", "full"),
                    default="full",
                    help="controller ladder: 'admission' = defer/shed "
                         "only; 'full' adds spec_half/spec_off/kv_int8 "
                         "degradation rungs (capability-gated)")
    ap.add_argument("--cost-model", action="store_true",
                    help="price each step from the work it ran (padded "
                         "prefill tokens, decode/draft calls, verify "
                         "span) and advance the virtual clock by it — "
                         "implied by --slo-ttft-p99-ms")
    ap.add_argument("--guards", action="store_true",
                    help="fold a per-step finite check into the decode "
                         "jit; a non-finite row quarantines only its own "
                         "request (FAILED + diagnostics)")
    ap.add_argument("--on-pressure", choices=("preempt", "truncate"),
                    default="preempt",
                    help="cache-pressure policy: preempt (evict + resume "
                         "bit-identically, default) or truncate (opt-in "
                         "legacy behavior)")
    ap.add_argument("--inject-faults", action="store_true",
                    help="drive the run under a seeded deterministic "
                         "fault plan (NaN/Inf logits, pressure windows, "
                         "transient step failures); implies --guards and "
                         "a virtual clock so outcomes replay exactly")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the injected fault plan")
    ap.add_argument("--verify-contracts", action="store_true",
                    help="run the repro.analysis contract rules over the "
                         "engine's compiled artifacts at init and refuse "
                         "to serve on any ERROR finding (DESIGN.md §12)")
    ap.add_argument("--mesh-shape", default=None,
                    help="DPxTP device mesh, e.g. 2x4 (data x model)")
    ap.add_argument("--dp", type=int, default=0,
                    help="data-parallel mesh size (alternative to "
                         "--mesh-shape)")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor(model)-parallel mesh size")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach the per-request span recorder "
                         "(serve/telemetry.py) — structured lifecycle "
                         "events + TTFT/TPOT histograms, host-side only, "
                         "zero overhead when off")
    ap.add_argument("--replay-trace", metavar="PATH",
                    help="drive submissions from this JSONL arrival trace "
                         "instead of --requests (implies --telemetry; "
                         "synthesize a trace with `python -m "
                         "repro.serve.replay`)")
    ap.add_argument("--report-json", metavar="PATH",
                    help="write the end-of-run scheduling report here — "
                         "TTFT/TPOT p50/p90/p99, tokens/s/slot, timelines, "
                         "preemption accounting (implies --telemetry)")
    ap.add_argument("--telemetry-trace", metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON here — "
                         "one track per slot, spans for prefill/decode/"
                         "spec/resume; open at ui.perfetto.dev (implies "
                         "--telemetry)")
    ap.add_argument("--stats", action="store_true",
                    help="print the uniform metrics report at exit (every "
                         "stats() counter through the metrics registry)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    spec = (SpecConfig(gamma=args.spec_gamma, draft_bits=args.draft_bits)
            if args.spec_gamma > 0 else None)
    draft_params = None
    if args.bits > 0:
        base = int(args.bits)
        qcfg = CLAQConfig(
            bits=base, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
            ap=(APConfig(args.bits, base, 4) if args.bits != base else None))
        calib = calibration_set(cfg.vocab, n_segments=8, seq_len=64)
        t0 = time.time()
        if spec is not None:
            # one calibration pass, two quantizations: the serving target
            # and the low-bit speculative draft share the tapped Hessians
            (params, report), (draft_params, drep) = claq_quantize_with_draft(
                params, cfg, calib, qcfg, draft_bits=spec.draft_bits)
            print(f"[serve] CLAQ-quantized target to "
                  f"{report.mean_effective_bits:.2f} bits + draft to "
                  f"{drep.mean_effective_bits:.2f} bits in "
                  f"{time.time() - t0:.1f}s (one calibration pass)")
        else:
            params, report = claq_quantize(params, cfg, calib, qcfg)
            print(f"[serve] CLAQ-quantized to "
                  f"{report.mean_effective_bits:.2f} "
                  f"bits in {time.time() - t0:.1f}s")
    elif spec is not None:
        # fp target: the draft is still a CLAQ quantization of the same
        # weights, with Outlier Reservation kept — the cheap accuracy
        # lever that keeps the draft's argmax tracking the target
        # (core.draft_config's contract)
        calib = calibration_set(cfg.vocab, n_segments=8, seq_len=64)
        dcfg = CLAQConfig(bits=args.draft_bits, method="kmeans",
                          kmeans_iters=6, gptq_blocksize=32,
                          orr=ORConfig(0.1))
        draft_params, drep = claq_quantize(params, cfg, calib, dcfg)
        print(f"[serve] fp target + {drep.mean_effective_bits:.2f}-bit "
              f"CLAQ draft")

    mesh = _build_mesh(args)
    if mesh is not None:
        print(f"[serve] mesh {dict(mesh.shape)} over {mesh.size} devices")

    injector = None
    clock = None
    if args.inject_faults:
        # faults imply guards (NaN injection must quarantine, not poison)
        # and a virtual clock (deadline outcomes must replay exactly)
        injector = FaultInjector(seed=args.fault_seed)
        clock = StepClock()
        print(f"[serve] fault plan (seed {args.fault_seed}): "
              f"{json.dumps(injector.describe())}")
    # any telemetry-consuming flag turns the recorder on; otherwise the
    # engine hooks stay None and cost nothing on the hot path
    telemetry = (Telemetry()
                 if (args.telemetry or args.replay_trace or args.report_json
                     or args.telemetry_trace) else None)
    controller = None
    if args.slo_ttft_p99_ms > 0:
        controller = AdmissionController(
            SLOConfig(ttft_p99_ms=args.slo_ttft_p99_ms),
            mode=args.controller_mode)
    cost_model = (StepCostModel()
                  if args.cost_model or controller is not None else None)
    eng = ServingEngine(params, cfg, n_slots=args.slots,
                        max_len=args.max_len, min_bucket=args.min_bucket,
                        bucketing=not args.no_bucketing, mesh=mesh,
                        draft_params=draft_params, spec=spec,
                        draft_plan_bn=args.draft_plan_bn or None,
                        draft_plan_bk=args.draft_plan_bk or None,
                        act_dtype=args.act_dtype,
                        guards=args.guards or args.inject_faults,
                        faults=injector,
                        queue_depth=args.queue_depth or None,
                        on_pressure=args.on_pressure, clock=clock,
                        kv_layout=args.kv_layout,
                        page_size=(args.page_size
                                   if args.kv_layout == "paged" else None),
                        kv_pages=(args.kv_pool_pages or None
                                  if args.kv_layout == "paged" else None),
                        kv_dtype=(args.kv_dtype
                                  if args.kv_layout == "paged"
                                  and args.kv_dtype != "f32" else None),
                        verify_contracts=args.verify_contracts,
                        telemetry=telemetry,
                        chunked_prefill=args.chunk_tokens or None,
                        controller=controller, cost_model=cost_model)
    if controller is not None:
        print(f"[serve] SLO controller: p99 TTFT target "
              f"{args.slo_ttft_p99_ms:.0f}ms, ladder "
              f"{'->'.join(controller.ladder)}")
    if args.chunk_tokens:
        print(f"[serve] chunked prefill: {args.chunk_tokens}-token chunks "
              f"interleaved with decode")
    if args.verify_contracts:
        rep = eng.contract_report
        print(f"[serve] contracts: {len(rep.rules_run)} rules clean "
              f"({len(rep.findings)} warning(s)) over the compiled "
              f"decode artifacts")
    if args.kv_layout == "paged":
        print(f"[serve] paged KV cache: page_size={eng.page_size}, "
              f"pool={eng.n_pages} pages, resident dtype "
              f"{eng.kv_dtype or 'fp'}")
    if args.act_dtype != "f32":
        print(f"[serve] activations: per-token {args.act_dtype} "
              f"(opt-in weight-activation quantized serving)")
    rng = np.random.default_rng(0)
    pending = [rng.integers(1, cfg.vocab, size=rng.integers(4, 12)).tolist()
               for _ in range(args.requests)]
    # bounded retry absorbs the injected transient step failures; under
    # the virtual clock the backoff never wall-sleeps
    retry = RetryPolicy(max_attempts=4,
                        backoff_s=0.0 if injector is not None else 0.05)
    t0 = time.time()
    steps = 0
    step_tokens = 0
    t_decode = 0.0
    backpressure_waits = 0
    fault_retries = 0
    report = None
    if args.replay_trace:
        # trace-driven mode: the Replayer owns arrivals, stepping, and the
        # end-of-run scheduling report; --requests is ignored
        trace = load_trace(args.replay_trace)
        print(f"[serve] replaying {len(trace)} arrivals from "
              f"{args.replay_trace}")
        report = Replayer(eng, trace, retry=retry).run()
        steps = report["driver_steps"]
        backpressure_waits = report["scheduling"]["backpressure_waits"]
        fault_retries = report["scheduling"]["transient_retries"]
    else:
        while pending or eng.active or len(eng.queue):
            while pending:
                try:
                    eng.submit(pending[0], max_new_tokens=args.max_new,
                               deadline_ms=args.deadline_ms or None)
                    pending.pop(0)
                except AdmissionRejected:
                    if not eng.active and not len(eng.queue):
                        raise    # empty engine rejected it: will never fit
                    backpressure_waits += 1  # queue full: drain first
                    break
            ts = time.time()
            emitted, retries = retry.run(eng.step)
            fault_retries += retries
            if clock is not None:
                clock.advance()
            if emitted:
                steps += 1
                # speculative steps emit LISTS of accepted tokens per
                # request; only those count toward throughput (rejected
                # drafts are rolled back, not served)
                step_tokens += sum(len(v) if isinstance(v, list) else 1
                                   for v in emitted.values())
                t_decode += time.time() - ts
    finished = eng.take_finished()
    dt = time.time() - t0
    # Throughput counts tokens actually emitted — EOS can retire a request
    # before its max_new_tokens budget, so `done * max_new` overcounts.
    total_tokens = sum(r.tokens_out for r in finished.values())
    st = eng.stats()
    print(f"[serve] {len(finished)} requests, {total_tokens} tokens, "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    if steps and t_decode:
        print(f"[serve] {steps} decode steps, "
              f"{step_tokens / steps:.2f} tokens/step, "
              f"{t_decode / steps * 1e3:.1f} ms/step "
              f"({step_tokens / max(t_decode, 1e-9):.1f} decode tok/s)")
    if spec is not None:
        print(f"[serve] speculative gamma={spec.gamma} "
              f"draft_bits={spec.draft_bits}: acceptance rate "
              f"{st['acceptance_rate']:.0%} "
              f"({st['spec_accepted']}/{st['spec_drafted']} drafts), "
              f"{st['tokens_per_step']:.2f} accepted tokens/step")
    print(f"[serve] prefill traces {st['prefill_traces']} "
          f"(buckets {st['buckets']}), compile-cache hit rate "
          f"{st['bucket_hit_rate']:.0%}")
    if "paged" in st and not args.stats:
        # paged counters now live on the metrics registry (one uniform
        # naming scheme); --stats prints the full report, this is the
        # abbreviated default view rendered from the same registry
        print(eng.metrics().render(prefix="serve.paged",
                                   title="serve.paged"))
    lc = st["lifecycle"]
    nonterminal = len(eng.active) + st["queued"]
    print(f"[serve] lifecycle: {json.dumps(lc)}, preemptions "
          f"{st['preemptions']}, resumes {st['resumes']}, backpressure "
          f"waits {backpressure_waits}, transient-fault retries "
          f"{fault_retries}")
    if telemetry is not None and report is None:
        # non-replay run with telemetry on: build the same scheduling
        # report the Replayer would have produced
        report = build_report(
            eng, elapsed=dt, driver_steps=steps,
            extra={"backpressure_waits": backpressure_waits,
                   "transient_retries": fault_retries,
                   "expired_at_submit": 0,
                   "rejected_unfittable": 0})
    if report is not None:
        tt, tp = report["ttft_ms"], report["tpot_ms"]
        print(f"[serve] ttft_ms p50/p90/p99 = {tt['p50']:.2f}/"
              f"{tt['p90']:.2f}/{tt['p99']:.2f}  tpot_ms p50/p90/p99 = "
              f"{tp['p50']:.2f}/{tp['p90']:.2f}/{tp['p99']:.2f}  "
              f"tokens/s/slot = "
              f"{report['tokens']['per_s_per_slot']:.2f}")
    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"[serve] scheduling report -> {args.report_json}")
    if args.telemetry_trace:
        write_perfetto(args.telemetry_trace, telemetry)
        print(f"[serve] perfetto trace -> {args.telemetry_trace} "
              f"(open at ui.perfetto.dev)")
    if args.stats:
        print(eng.metrics().render(title="serve metrics"))
    if nonterminal:
        raise SystemExit(
            f"[serve] {nonterminal} requests never reached a terminal "
            f"state — lifecycle invariant violated")
    if args.inject_faults:
        print("[serve] fault plan survived: every request terminal")


if __name__ == "__main__":
    main()
