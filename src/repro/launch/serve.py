"""Serving launcher: quantize (optional) + batched engine demo.

  PYTHONPATH=src python -m repro.launch.serve --arch llama1_7b --smoke \
      --bits 3 --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize
from repro.models import api
from repro.serve import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=float, default=0,
                    help="0 = fp; else CLAQ-quantize to this avg bit-width")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=16,
                    help="smallest prefill length bucket")
    ap.add_argument("--no-bucketing", action="store_true",
                    help="admit at exact prompt lengths (one compile each)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    if args.bits > 0:
        base = int(args.bits)
        qcfg = CLAQConfig(
            bits=base, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
            ap=(APConfig(args.bits, base, 4) if args.bits != base else None))
        calib = calibration_set(cfg.vocab, n_segments=8, seq_len=64)
        t0 = time.time()
        params, report = claq_quantize(params, cfg, calib, qcfg)
        print(f"[serve] CLAQ-quantized to {report.mean_effective_bits:.2f} "
              f"bits in {time.time() - t0:.1f}s")

    eng = ServingEngine(params, cfg, n_slots=args.slots,
                        max_len=args.max_len, min_bucket=args.min_bucket,
                        bucketing=not args.no_bucketing)
    rng = np.random.default_rng(0)
    pending = [rng.integers(1, cfg.vocab, size=rng.integers(4, 12)).tolist()
               for _ in range(args.requests)]
    t0 = time.time()
    while pending or eng.active:
        if pending and eng.free:
            batch = [pending.pop(0)
                     for _ in range(min(len(pending), len(eng.free)))]
            eng.add_requests(batch, max_new_tokens=args.max_new)
        eng.step()
    done = len(eng.take_finished())
    dt = time.time() - t0
    st = eng.stats()
    print(f"[serve] {done} requests, {dt:.2f}s "
          f"({done * args.max_new / dt:.1f} tok/s)")
    print(f"[serve] prefill traces {st['prefill_traces']} "
          f"(buckets {st['buckets']}), compile-cache hit rate "
          f"{st['bucket_hit_rate']:.0%}")


if __name__ == "__main__":
    main()
