"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)
