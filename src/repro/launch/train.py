"""Training launcher: mesh-parallel train loop with fault tolerance.

Single-host usage (CPU smoke / debug):
  PYTHONPATH=src python -m repro.launch.train --arch llama1_7b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt

On a real cluster this process runs per host under `jax.distributed`
(initialize() from launcher env), the mesh spans all pods, and the same
code path applies — the mesh shape is the only thing that changes.

Fault-tolerance behaviour (tested in tests/test_checkpoint.py):
  * resumes from the newest *valid* checkpoint (torn writes skipped);
  * the data pipeline is step-indexed, so no batch is replayed or skipped;
  * checkpoints are written by a background thread (async) and validated
    by checksum at restore;
  * straggler mitigation: per-step wall-clock watchdog — a step exceeding
    ``--step-timeout`` logs a straggler event (on a cluster the external
    supervisor uses these to re-dispatch the slow host).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, SyntheticCorpus
from repro.dist import context as dctx
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import OptimConfig, init_opt_state
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--step-timeout", type=float, default=300.0)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                       total_steps=args.steps)
    opt = init_opt_state(params, ocfg)
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                      batch=args.batch, seed=0))
    step_fn = jax.jit(make_train_step(cfg, ocfg, args.microbatches))

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}")

    if mesh is not None:
        shardings = shd.tree_shardings(params, shd.spec_for_param, cfg, mesh)
        params = jax.device_put(params, shardings)

    ctx = dctx.use_mesh(mesh) if mesh is not None else dctx.use_mesh(None)
    with ctx:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {"tokens": data.batch_at(step)}
            if cfg.modality == "vision":
                P = max(int(args.seq_len * cfg.prefix_frac), 1)
                batch = {"tokens": data.batch_at(step)[:, P:],
                         "prefix_embeds": jnp.zeros(
                             (args.batch, P, cfg.d_model), jnp.float32)}
            elif cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (args.batch, args.seq_len, cfg.d_model), jnp.float32)
            params, opt, metrics = step_fn(params, opt, batch)
            dt = time.time() - t0
            if dt > args.step_timeout:
                print(f"[train][straggler] step {step} took {dt:.1f}s "
                      f"(> {args.step_timeout}s)")
            if step % 10 == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt},
                         blocking=False)
    if mgr:
        mgr.wait()
        mgr.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    print("[train] done")


if __name__ == "__main__":
    main()
