"""The CLAQ PTQ pipeline: calibrate -> plan -> quantize -> package.

Mirrors the paper's protocol (§4.1/App. F): 128x2048-token calibration
segments, per-matrix Hessians accumulated from the activations feeding each
matmul, GPTQ-compensated K-Means quantization per column, AP/OR budgets
from the Outlier Order metric.

Calibration runs the model *eagerly and unrolled* so the tap collector sees
concrete per-layer activations (the JAX stand-in for torch forward hooks);
only the (in,in) moment matrices are kept, so memory stays O(d_model^2).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CLAQConfig, QuantizedTensor, quantize_matrix
from repro.core import claq as claq_lib
from repro.core import policy as policy_lib
from repro.models import api
from repro.models import modules as nn

Array = jax.Array

# parameter dicts that hold quantizable kernels, and names never quantized
_SKIP_KEYS = ("embedding", "scale", "bias", "a_log", "dt_bias", "d_skip",
              "conv_w", "conv_b", "mix", "w_bias", "u_bonus", "router",
              "lora_a", "lora_b")


def calibrate(params, cfg, calib_tokens: Array, batch_size: int = 4,
              extra_batches: Optional[Dict[str, Array]] = None
              ) -> Dict[str, Array]:
    """Run calibration batches through the model eagerly; returns
    {tap_name: (in,in) Hessian}."""
    collector = nn.TapCollector()
    n = calib_tokens.shape[0]
    with nn.collecting(collector):
        for i in range(0, n, batch_size):
            chunk = calib_tokens[i:i + batch_size]
            batch = {"tokens": chunk}
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (chunk.shape[0], chunk.shape[1], cfg.d_model), jnp.float32)
            if extra_batches:
                batch.update({k: v[i:i + batch_size]
                              for k, v in extra_batches.items()})
            api.loss_fn(params, cfg, batch, unroll=True)
    return collector.finalized()


def _dotted(path) -> str:
    """pytree key path -> dotted name ('attn.q')."""
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return ".".join(p for p in out if p != "kernel")


def _sum_hessians(hessians: Dict[str, Array], pattern: str) -> Optional[Array]:
    rx = re.compile(pattern)
    acc = None
    for name, H in hessians.items():
        if rx.fullmatch(name):
            acc = H if acc is None else acc + H
    return acc


@dataclasses.dataclass
class QuantizeReport:
    stats: Dict[str, claq_lib.QuantStats]

    @property
    def mean_effective_bits(self) -> float:
        if not self.stats:
            return 0.0
        return float(np.mean([s.effective_bits for s in self.stats.values()]))

    @property
    def total_proxy_loss(self) -> float:
        return float(np.sum([s.proxy_loss for s in self.stats.values()]))


def _quantize_leaf(kernel, H, qcfg, mesh=None):
    """kernel (in,out) -> QuantizedTensor (paper layout), stats."""
    qt, _, st = quantize_matrix(jnp.asarray(kernel, jnp.float32).T, H, qcfg,
                                mesh=mesh)
    return qt, st


def _quantize_subtree(sub, hessians, prefix_fmt, n_items, qcfg, stats,
                      mesh=None, expert_keys=("w_gate", "w_up", "w_down")):
    """Quantize every eligible kernel of a stacked subtree (layer axis
    leading), re-stacking results across the stack."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(sub)
    per_item = [[] for _ in range(n_items)]
    for path, leaf in flat:
        dotted = _dotted(path)
        last = path[-1].key if hasattr(path[-1], "key") else ""
        eligible = (
            last == "kernel"
            and not any(k in dotted for k in _SKIP_KEYS)
            and leaf.ndim == 3 and min(leaf.shape[1:]) >= 16)
        expert = (last in expert_keys and leaf.ndim == 4
                  and min(leaf.shape[2:]) >= 16)
        for i in range(n_items):
            li = leaf[i]
            if eligible:
                tap = prefix_fmt.format(i) + "." + dotted
                H = hessians.get(tap)  # None -> identity (weight-space)
                qt, st = _quantize_leaf(li, H, qcfg, mesh)
                stats[f"{prefix_fmt.format(i)}.{dotted}"] = st
                per_item[i].append(qt)
            elif expert:
                E = li.shape[0]
                qts = []
                mid = last == "w_down"   # input dim is F (expert_mid taps)
                for e in range(E):
                    tap = (prefix_fmt.format(i)
                           + f".mlp.expert_{'mid' if mid else 'in'}_{e}")
                    H = hessians.get(tap)
                    # li[e] is (in, out) for gate/up and down alike
                    qt, st = _quantize_leaf(li[e], H, qcfg, mesh)
                    qts.append(qt)
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *qts)
                stats[f"{prefix_fmt.format(i)}.{dotted}.{last}"] = st
                per_item[i].append(stacked)
            else:
                per_item[i].append(li)
    items = [jax.tree_util.tree_unflatten(treedef, leaves)
             for leaves in per_item]
    return jax.tree_util.tree_map(lambda *xs: _stack_mixed(*xs), *items)


def _stack_mixed(*xs):
    return jnp.stack(xs)


def quantize_model_params(
    params: Dict[str, Any],
    cfg,
    hessians: Dict[str, Array],
    qcfg: CLAQConfig,
    mesh=None,
) -> Tuple[Dict[str, Any], QuantizeReport]:
    """Quantize all block weights of a model (embeddings/norms/head stay fp,
    matching the paper's weight-only scope).  Returns (params', report)."""
    stats: Dict[str, claq_lib.QuantStats] = {}
    out = dict(params)

    if cfg.family == "encdec":
        out["enc_blocks"] = _quantize_subtree(
            params["enc_blocks"], hessians, "enc.{}", cfg.enc_layers,
            qcfg, stats, mesh)
        out["dec_blocks"] = _quantize_subtree(
            params["dec_blocks"], hessians, "dec.{}", cfg.dec_layers,
            qcfg, stats, mesh)
        return out, QuantizeReport(stats)

    out["blocks"] = _quantize_subtree(
        params["blocks"], hessians, "layers.{}", cfg.n_layers,
        qcfg, stats, mesh)

    if "shared_attn" in params:
        # shared across sites: sum the per-site Hessians
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params["shared_attn"])
        leaves = []
        for path, leaf in flat:
            dotted = _dotted(path)
            last = path[-1].key if hasattr(path[-1], "key") else ""
            if (last == "kernel" and leaf.ndim == 2
                    and not any(k in dotted for k in _SKIP_KEYS)
                    and min(leaf.shape) >= 16):
                H = _sum_hessians(
                    hessians, r"shared_attn\.site\d+\." + re.escape(dotted))
                qt, st = _quantize_leaf(leaf, H, qcfg, mesh)
                stats[f"shared_attn.{dotted}"] = st
                leaves.append(qt)
            else:
                leaves.append(leaf)
        out["shared_attn"] = jax.tree_util.tree_unflatten(treedef, leaves)

    return out, QuantizeReport(stats)


def claq_quantize(params, cfg, calib_tokens, qcfg: CLAQConfig,
                  batch_size: int = 4, mesh=None,
                  extra_batches: Optional[Dict[str, Array]] = None):
    """End-to-end: calibrate + quantize. The paper's full pipeline."""
    hessians = calibrate(params, cfg, calib_tokens, batch_size, extra_batches)
    return quantize_model_params(params, cfg, hessians, qcfg, mesh)


def claq_quantize_with_draft(params, cfg, calib_tokens, qcfg: CLAQConfig,
                             draft_qcfg: Optional[CLAQConfig] = None,
                             draft_bits: int = 2, batch_size: int = 4,
                             mesh=None,
                             extra_batches: Optional[Dict[str, Array]] = None):
    """ONE calibration pass, TWO quantizations of the same fp weights: the
    serving target at ``qcfg`` and a low-bit speculative DRAFT at
    ``draft_qcfg`` (default: `core.draft_config(qcfg, draft_bits)` — flat
    ``draft_bits`` codes, Outlier Reservation kept, AP dropped).

    Calibration — the eager unrolled model sweep that taps every matrix's
    (in, in) Hessian — is the expensive, data-touching stage; the second
    quantization reuses those Hessians verbatim, so the draft model is
    nearly free and sees EXACTLY the same activation statistics as the
    target (the draft/target pair self-speculative decoding wants, see
    serve/speculative.py).

    Returns ``(target_params, target_report), (draft_params,
    draft_report)``.
    """
    hessians = calibrate(params, cfg, calib_tokens, batch_size, extra_batches)
    target = quantize_model_params(params, cfg, hessians, qcfg, mesh)
    if draft_qcfg is None:
        draft_qcfg = policy_lib.draft_config(qcfg, draft_bits)
    draft = quantize_model_params(params, cfg, hessians, draft_qcfg, mesh)
    return target, draft
