"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh with 512 placeholder host devices, and extract the
memory / FLOP / collective figures the roofline analysis (EXPERIMENTS.md)
is built from.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun.json
Results are cached per cell in the JSON; finished cells are skipped.
"""
# The VERY FIRST lines — before ANY other import — jax locks the device
# count on first init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, SHAPES_BY_NAME,  # noqa: E402
                           cell_applicable, get_config)
from repro.dist import context as dctx                        # noqa: E402
from repro.dist import sharding as shd                         # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models import api                                   # noqa: E402
from repro.optim import OptimConfig, OptState, init_opt_state  # noqa: E402
from repro.train import make_train_step                        # noqa: E402

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(tok):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str):
    """Per-device collective bytes (result-shape proxy) by op kind."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_tok, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_tok)
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# Model FLOPs (analytic "useful work" reference)
# ---------------------------------------------------------------------------

def count_params(cfg) -> dict:
    sds = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    total = active = embed = 0
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape))
        total += n
        if "embed" in name or "lm_head" in name:
            embed += n
            continue
        if any(k in name for k in ("w_gate", "w_up", "w_down")):
            active += n * cfg.top_k // max(cfg.n_experts, 1)
        else:
            active += n
    return {"total": total, "active_nonembed": active, "embed": embed,
            "nonembed": total - embed}


def _attention_flops(cfg, B, S, kind) -> float:
    """Analytic 'useful' mixing flops (causal-optimal; the MODEL_FLOPS
    reference the roofline fraction is measured against)."""
    H, hd, L_ = cfg.n_heads, cfg.head_dim, cfg.n_layers
    if cfg.family == "encdec":
        Le, Ld = cfg.enc_layers, cfg.dec_layers
        enc = 4.0 * B * S * S * H * hd * Le           # bidirectional
        dec_self = 2.0 * B * S * S * H * hd * Ld      # causal
        cross = 4.0 * B * S * S * H * hd * Ld
        fwd = enc + dec_self + cross
    elif cfg.use_mla:
        dqk = cfg.head_dim + cfg.rope_head_dim
        fwd = (B * S * S * H * (dqk + cfg.v_head_dim)) * L_
    elif cfg.family == "rwkv":
        Hh = cfg.d_model // cfg.rwkv_head_dim
        N = cfg.rwkv_head_dim
        c = cfg.rwkv_chunk
        # intra-chunk quadratic + state in/out terms
        fwd = (4.0 * B * S * c * Hh * N + 4.0 * B * S * Hh * N * N) * L_
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        Hh = d_inner // cfg.ssm_headdim
        c = cfg.ssm_chunk
        ssd = (2.0 * B * S * c * (cfg.ssm_state + cfg.ssm_headdim) * Hh
               + 4.0 * B * S * Hh * cfg.ssm_headdim * cfg.ssm_state) * L_
        W = min(cfg.attn_window or S, S)
        attn = 2.0 * B * S * W * H * hd * cfg.n_sites
        fwd = ssd + attn
    else:
        W = min(cfg.attn_window or S, S)
        fwd = 2.0 * B * S * W * H * hd * L_           # causal (S*W/2 pairs x2)

    if kind == "train":
        return 3.0 * fwd
    return fwd


def _decode_attention_flops(cfg, B, S_ctx) -> float:
    H, hd, L_ = cfg.n_heads, cfg.head_dim, cfg.n_layers
    if cfg.family == "encdec":
        Ld = cfg.dec_layers
        return 4.0 * B * S_ctx * H * hd * Ld * 2      # self cache + cross
    if cfg.use_mla:
        # absorbed decode: scores/context against the latent cache
        return 4.0 * B * S_ctx * cfg.n_heads * cfg.kv_lora * L_
    if cfg.family == "rwkv":
        Hh = cfg.d_model // cfg.rwkv_head_dim
        return 6.0 * B * Hh * cfg.rwkv_head_dim ** 2 * L_
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        Hh = d_inner // cfg.ssm_headdim
        ssm = 6.0 * B * Hh * cfg.ssm_headdim * cfg.ssm_state * L_
        W = min(cfg.attn_window or S_ctx, S_ctx)
        return ssm + 4.0 * B * W * H * hd * cfg.n_sites
    W = min(cfg.attn_window or S_ctx, S_ctx)
    return 4.0 * B * W * H * hd * L_


def model_flops(cfg, cell, counts) -> float:
    """Useful work: parameter matmuls (6ND train / 2ND inference, active
    params for MoE) + analytic attention/SSD/WKV mixing flops."""
    B, S = cell.global_batch, cell.seq_len
    tokens = B * S
    n = counts["active_nonembed"]
    if cell.kind == "train":
        return 6.0 * n * tokens + _attention_flops(cfg, B, S, "train")
    if cell.kind == "prefill":
        return 2.0 * n * tokens + _attention_flops(cfg, B, S, "prefill")
    return 2.0 * n * B + _decode_attention_flops(cfg, B, S)


# ---------------------------------------------------------------------------
# Quantized-parameter stand-ins (shape-only CLAQ plan; no GPTQ run needed
# to LOWER the quantized serving path)
# ---------------------------------------------------------------------------

def _qt_struct(n_layers, rows, cols, qcfg):
    """ShapeDtypeStruct tree of a layer-stacked QuantizedTensor."""
    from repro.core import packing
    from repro.core.policy import BITS_PER_RESERVED_OUTLIER
    from repro.core.quantized import QuantStripe, QuantizedTensor

    if qcfg.ap is not None:
        frac = (qcfg.ap.target_bits - qcfg.ap.p_lo) / (qcfg.ap.p_hi - qcfg.ap.p_lo)
        n_hi = int(round(frac * cols))
        parts = [(qcfg.ap.p_lo, cols - n_hi), (qcfg.ap.p_hi, n_hi)]
    else:
        parts = [(qcfg.bits, cols)]
    stripes = tuple(
        QuantStripe(
            packed=jax.ShapeDtypeStruct(
                (n_layers, packing.packed_rows(rows, b), n), jnp.uint32),
            codebook=jax.ShapeDtypeStruct((n_layers, n, 2 ** b), jnp.float32),
            bits=b)
        for b, n in parts if n > 0)
    k_max = 0
    if qcfg.orr is not None:
        total = qcfg.orr.extra_bits * rows * cols / BITS_PER_RESERVED_OUTLIER
        n_top = max(int(round(qcfg.orr.top_frac * cols)), 1)
        k1 = min(int(round(qcfg.orr.o1 * total / n_top)), rows)
        k2 = min(int(round(qcfg.orr.o2 * total / max(cols - n_top, 1))), rows)
        k_max = max(k1, k2)
    return QuantizedTensor(
        stripes=stripes,
        col_perm=jax.ShapeDtypeStruct((n_layers, cols), jnp.int32),
        out_idx=jax.ShapeDtypeStruct((n_layers, k_max, cols), jnp.int32),
        out_val=jax.ShapeDtypeStruct((n_layers, k_max, cols), jnp.float32),
        out_count=jax.ShapeDtypeStruct((n_layers, cols), jnp.int32),
        shape=(rows, cols),
    )


def quantize_param_sds(param_sds, cfg, qcfg):
    """Replace eligible block kernels with QuantizedTensor stand-ins
    (paper layout rows=out, cols=in), mirroring launch.quantize rules."""
    from repro.launch.quantize import _SKIP_KEYS

    def walk(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in flat:
            name = jax.tree_util.keystr(path).lower()
            last = path[-1].key if hasattr(path[-1], "key") else ""
            if (last == "kernel" and leaf.ndim == 3
                    and not any(k in name for k in _SKIP_KEYS)
                    and min(leaf.shape[1:]) >= 16):
                L_, d_in, d_out = leaf.shape
                out.append(_qt_struct(L_, d_out, d_in, qcfg))
            elif (last in ("w_gate", "w_up", "w_down") and leaf.ndim == 4
                  and min(leaf.shape[2:]) >= 16):
                L_, E, d_in, d_out = leaf.shape
                qt = _qt_struct(L_ * E, d_out, d_in, qcfg)
                out.append(jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct(
                        (L_, E) + a.shape[1:], a.dtype), qt))
            else:
                out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    new = dict(param_sds)
    for key in ("blocks", "enc_blocks", "dec_blocks"):
        if key in new:
            new[key] = walk(new[key])
    return new


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def _sds_sharded(tree, rule, cfg, mesh):
    return shd.with_shardings(tree, rule, cfg, mesh)


def prepare_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = shd.MeshAxes(mesh)
    cell = SHAPES_BY_NAME[shape_name]
    if cfg.family == "moe":
        groups = ax.dp_size
        tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
        while groups > 1 and tokens % groups != 0:
            groups //= 2
        cfg = dataclasses.replace(cfg, moe_groups=groups)
    return cfg, mesh, cell


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               quant: Optional[str] = None):
    """Returns (lowered, compiled, meta). Raises on sharding bugs.
    quant: e.g. '2.12' lowers the serving path with CLAQ QuantizedTensor
    weights (AP+OR fusion plan at that bit-width) — the paper's deployment
    format in the dry-run."""
    cfg, mesh, cell = prepare_cell(arch, shape_name, multi_pod)

    param_sds = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    if quant:
        from repro.core import APConfig, CLAQConfig, ORConfig
        bits = float(quant)
        base = int(bits)
        qcfg = CLAQConfig(
            bits=base,
            ap=(APConfig(base + (bits - base) * 0.6, base, 4)
                if bits != base else None),
            orr=(ORConfig((bits - base) * 0.4) if bits != base else None))
        param_sds = quantize_param_sds(param_sds, cfg, qcfg)
    params = _sds_sharded(param_sds, shd.spec_for_param, cfg, mesh)

    batch_sds = api.input_specs(cfg, cell)
    batch = _sds_sharded(batch_sds, shd.spec_for_batch, cfg, mesh)

    with mesh, dctx.use_mesh(mesh):
        if cell.kind == "train":
            ocfg = OptimConfig(total_steps=10000)
            opt_sds = jax.eval_shape(lambda p: init_opt_state(p, ocfg), param_sds)
            opt = OptState(
                m=_sds_sharded(opt_sds.m, shd.spec_for_param, cfg, mesh),
                v=_sds_sharded(opt_sds.v, shd.spec_for_param, cfg, mesh),
                step=jax.ShapeDtypeStruct(
                    (), jnp.int32,
                    sharding=jax.NamedSharding(mesh, jax.sharding.PartitionSpec())),
                err=None,
            )
            step_fn = make_train_step(cfg, ocfg)
            lowered = jax.jit(step_fn).lower(params, opt, batch)
        elif cell.kind == "prefill":
            params = _sds_sharded(param_sds, shd.spec_for_param_serve, cfg, mesh)
            cache_sds = api.cache_specs(cfg, cell)
            cache = _sds_sharded(cache_sds, shd.spec_for_cache, cfg, mesh)

            def prefill_fn(p, b, c):
                return api.prefill_step(p, cfg, b, c)
            lowered = jax.jit(prefill_fn).lower(params, batch, cache)
        else:  # decode
            params = _sds_sharded(param_sds, shd.spec_for_param_serve, cfg, mesh)
            cache_sds = api.cache_specs(cfg, cell)
            cache = _sds_sharded(cache_sds, shd.spec_for_cache, cfg, mesh)
            tok = jax.ShapeDtypeStruct(
                (cell.global_batch,), jnp.int32,
                sharding=jax.NamedSharding(
                    mesh, shd.spec_for_batch(
                        "token", (cell.global_batch,), cfg, shd.MeshAxes(mesh))))

            def decode_fn(p, t, c):
                return api.decode_step(p, cfg, t, c)
            lowered = jax.jit(decode_fn).lower(params, tok, cache)

        compiled = lowered.compile()
    return lowered, compiled, (cfg, mesh, cell)


def analyze(compiled, cfg, mesh, cell) -> dict:
    """Loop/fusion-aware roofline terms from the compiled per-device HLO
    (dist.hlo_analysis; XLA's own cost_analysis counts scan bodies once and
    ignores fusion, so it is kept only as a reference field)."""
    from repro.dist.hlo_analysis import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax<0.5 returns [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    h = analyze_hlo(hlo)
    counts = count_params(cfg)
    n_chips = int(np.prod(list(mesh.shape.values())))

    flops_dev = float(h["flops"])
    bytes_dev = float(h["hbm_bytes"])
    coll_total = float(h["collective_bytes"])
    mflops = model_flops(cfg, cell, counts)

    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    coll_t = coll_total / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    bottleneck = max(terms, key=terms.get)

    return {
        "chips": n_chips,
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_total,
            "collectives": {k.replace("coll_", ""): v
                            for k, v in h.items() if k.startswith("coll_")},
            "xla_cost_flops_1iter": float(cost.get("flops", 0.0)),
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
        },
        "roofline": {
            **terms,
            "bottleneck": bottleneck.replace("_s", ""),
            "model_flops_global": mflops,
            "model_flops_per_dev": mflops / n_chips,
            "useful_flop_fraction": (mflops / n_chips) / max(flops_dev, 1.0),
            "roofline_fraction": (mflops / n_chips / PEAK_FLOPS)
                                  / max(terms[bottleneck], 1e-30),
        },
        "params": counts,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant: Optional[str] = None) -> dict:
    cfg0 = get_config(arch)
    cell = SHAPES_BY_NAME[shape_name]
    ok, reason = cell_applicable(cfg0, cell)
    if not ok:
        return {"status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        lowered, compiled, (cfg, mesh, cell) = lower_cell(
            arch, shape_name, multi_pod, quant=quant)
        result = analyze(compiled, cfg, mesh, cell)
        result.update(status="ok", compile_s=round(time.time() - t0, 1))
        return result
    except Exception as e:  # a sharding bug is a bug in our system
        return {"status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
                "compile_s": round(time.time() - t0, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                res = run_cell(arch, shape, mp)
                results[key] = res
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" compile={res['compile_s']}s")
                elif status == "error":
                    extra = " " + res["error"][:160]
                print(f"[dryrun] {key} -> {status}{extra}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
