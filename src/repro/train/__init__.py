from .step import make_train_step  # noqa: F401
