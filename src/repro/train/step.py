"""train_step factory: loss -> grads -> (clipped, scheduled) AdamW update,
with optional microbatch gradient accumulation (compute/comm overlap: XLA
overlaps the reduce-scatter of microbatch i with compute of i+1)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.optim.adamw import OptimConfig, OptState, apply_updates

Array = jax.Array


def make_train_step(cfg, optim_cfg: OptimConfig, n_microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leaves have leading dim global_batch; with n_microbatches > 1 the
    batch is split on axis 0 and gradients are accumulated in f32.
    """

    def loss_fn(params, batch):
        loss, metrics = api.loss_fn(params, cfg, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def split(x):
            return x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                             + x.shape[1:])
        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_sum + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree_util.tree_map(lambda a: a / n_microbatches, acc)
        return loss_sum / n_microbatches, {}, grads

    def train_step(params, opt_state: OptState, batch: Dict[str, Array]):
        if n_microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, optim_cfg)
        out = {"loss": loss, **opt_metrics}
        return params, opt_state, out

    return train_step
