from .pipeline import DataConfig, SyntheticCorpus, synth_batch, calibration_set  # noqa: F401
