"""Synthetic-corpus data pipeline: deterministic, step-indexed, shardable.

Design goals (1000-node posture):
  * **stateless indexing** — `batch_at(step)` is a pure function of
    (seed, step), so restarts/elastic re-shards never replay or skip data
    and any host can materialize exactly its shard;
  * **learnable structure** — tokens follow a hashed first-order Markov
    process mixed with Zipf unigrams, giving models a few bits/token of
    learnable signal (enough for PPL orderings in the paper benchmarks);
  * **distribution families** — different parameterizations stand in for
    C4 vs WikiText2 (calibration-transfer experiment, paper App. H).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    # Markov structure: next ~ mix of K hashed successors of cur + Zipf noise
    branch: int = 4
    struct_prob: float = 0.85     # P(follow structure) vs unigram noise
    name: str = "c4like"          # c4like | wikilike (different hash params)


_FAMILY_SALT = {"c4like": 0x9E3779B1, "wikilike": 0x85EBCA77}


def _hash_successors(tok: Array, vocab: int, branch: int, salt: int) -> Array:
    """Deterministic per-token successor set: (..., branch) int32."""
    t = tok.astype(jnp.uint32)
    ks = jnp.arange(1, branch + 1, dtype=jnp.uint32)
    h = (t[..., None] * jnp.uint32(salt) + ks * jnp.uint32(0xC2B2AE35))
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0x27D4EB2F)
    return (h % jnp.uint32(vocab)).astype(jnp.int32)


def synth_batch(cfg: DataConfig, step: int) -> Array:
    """(batch, seq_len) int32 tokens, pure function of (cfg.seed, step)."""
    salt = _FAMILY_SALT.get(cfg.name, 0x9E3779B1)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k0, k1 = jax.random.split(key)
    # Zipf-ish unigram start tokens
    u = jax.random.uniform(k0, (cfg.batch,), minval=1e-6, maxval=1.0)
    start = (jnp.power(u, 3.0) * cfg.vocab).astype(jnp.int32) % cfg.vocab

    def step_fn(carry, k):
        cur = carry
        succ = _hash_successors(cur, cfg.vocab, cfg.branch, salt)  # (B, branch)
        kb, kc, kn = jax.random.split(k, 3)
        pick = jax.random.randint(kb, (cfg.batch,), 0, cfg.branch)
        structured = jnp.take_along_axis(succ, pick[:, None], axis=1)[:, 0]
        u2 = jax.random.uniform(kc, (cfg.batch,), minval=1e-6, maxval=1.0)
        noise = (jnp.power(u2, 3.0) * cfg.vocab).astype(jnp.int32) % cfg.vocab
        use_struct = jax.random.uniform(kn, (cfg.batch,)) < cfg.struct_prob
        nxt = jnp.where(use_struct, structured, noise)
        return nxt, cur

    keys = jax.random.split(k1, cfg.seq_len)
    _, toks = jax.lax.scan(step_fn, start, keys)
    return jnp.moveaxis(toks, 0, 1)                       # (B, S)


class SyntheticCorpus:
    """Step-indexed corpus with optional host-sharding."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        assert cfg.batch % num_shards == 0
        self._fn = jax.jit(synth_batch, static_argnums=(0,))

    def batch_at(self, step: int) -> Array:
        full = self._fn(self.cfg, int(step))
        if self.num_shards == 1:
            return full
        per = self.cfg.batch // self.num_shards
        return full[self.shard * per:(self.shard + 1) * per]

    def iterate(self, start_step: int = 0) -> Iterator[Array]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def calibration_set(vocab: int, n_segments: int = 128, seq_len: int = 2048,
                    seed: int = 1234, name: str = "c4like") -> Array:
    """The paper's calibration protocol: 128 random 2048-token segments
    (paper §F), drawn from the synthetic stand-in corpus."""
    cfg = DataConfig(vocab=vocab, seq_len=seq_len, batch=n_segments,
                     seed=seed, name=name)
    return synth_batch(cfg, 0)
