"""Minimal functional module system: named scopes, parameter initializers,
calibration taps, and a quantization-aware dense primitive.

Models are pure functions over nested-dict params.  Two cross-cutting
concerns are threaded through module-level context:

  * **Scopes** give every dense() call a stable path name ("layers.3.attn.q").
    The same names key calibration Hessians and quantization stats.
  * **Taps**: during (eager) calibration runs, dense() streams its input
    activations into per-name Hessian accumulators (H += 2 x^T x) — the JAX
    answer to torch forward hooks, memory-light because only the (in,in)
    moment matrix is kept.
  * **Quantized dispatch**: a params leaf may be a QuantizedTensor instead of
    a dense kernel; dense() then routes through kernels.ops.qmatmul.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import gptq
from repro.core.quantized import QuantizedTensor
from repro.kernels.plan import PreparedQuantizedTensor

Array = jax.Array

_STATE = threading.local()


def _scope_stack():
    if not hasattr(_STATE, "scopes"):
        _STATE.scopes = []
    return _STATE.scopes


@contextlib.contextmanager
def scope(name: str):
    _scope_stack().append(str(name))
    try:
        yield
    finally:
        _scope_stack().pop()


def current_scope() -> str:
    return ".".join(_scope_stack())


def scoped_name(name: str) -> str:
    prefix = current_scope()
    return f"{prefix}.{name}" if prefix else name


# ---------------------------------------------------------------------------
# Calibration taps
# ---------------------------------------------------------------------------

class TapCollector:
    """Streams dense() inputs into per-matrix Hessian accumulators."""

    def __init__(self):
        self.hessians: Dict[str, gptq.HessianState] = {}

    def record(self, name: str, x: Array):
        in_dim = x.shape[-1]
        st = self.hessians.get(name)
        if st is None:
            st = gptq.init_hessian(in_dim)
        self.hessians[name] = gptq.accumulate_hessian(st, x)

    def finalized(self) -> Dict[str, Array]:
        return {k: gptq.finalize_hessian(v) for k, v in self.hessians.items()}


@contextlib.contextmanager
def collecting(collector: TapCollector):
    prev = getattr(_STATE, "collector", None)
    _STATE.collector = collector
    try:
        yield collector
    finally:
        _STATE.collector = prev


def _maybe_record(name: str, x: Array):
    col: Optional[TapCollector] = getattr(_STATE, "collector", None)
    if col is not None and not isinstance(x, jax.core.Tracer):
        col.record(name, x)


def record_expert_inputs(name: str, x_e: Array):
    """MoE calibration taps: x_e (G, E, cap, D) dispatched activations.
    One Hessian per expert (tokens routed to it) — the activation-aware
    compensation analogue for expert FFNs (DESIGN.md §3)."""
    col: Optional[TapCollector] = getattr(_STATE, "collector", None)
    if col is None or isinstance(x_e, jax.core.Tracer):
        return
    E = x_e.shape[1]
    base = scoped_name(name)
    for e in range(E):
        col.record(f"{base}_{e}", x_e[:, e].reshape(-1, x_e.shape[-1]))


# ---------------------------------------------------------------------------
# Quantized-matmul runtime mode
# ---------------------------------------------------------------------------

class QuantMode:
    """'ref' = XLA dequant+dot (CPU dry-run path); 'kernel' = Pallas kernel
    (interpret=True off-TPU).  `act_dtype` opts quantized matmuls into
    per-token int8 activation quantization ("int8"; None/"f32" = full
    precision) — an engine-level deployment knob (DESIGN.md §9), read at
    trace time like `mode`."""
    mode: str = "ref"
    interpret: bool = True
    act_dtype: Optional[str] = None


@contextlib.contextmanager
def quant_mode(mode: str, interpret: bool = True,
               act_dtype: Optional[str] = None):
    prev = (QuantMode.mode, QuantMode.interpret, QuantMode.act_dtype)
    QuantMode.mode, QuantMode.interpret = mode, interpret
    QuantMode.act_dtype = act_dtype
    try:
        yield
    finally:
        QuantMode.mode, QuantMode.interpret, QuantMode.act_dtype = prev


@contextlib.contextmanager
def activation_quant(act_dtype: Optional[str]):
    """Scope ONLY the activation quantization mode (the ServingEngine wraps
    its jitted steps with this so `mode`/`interpret` stay whatever the
    caller set)."""
    prev = QuantMode.act_dtype
    QuantMode.act_dtype = act_dtype
    try:
        yield
    finally:
        QuantMode.act_dtype = prev


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def dense(p: Dict[str, Any], x: Array, name: str = "dense") -> Array:
    """y = x @ kernel (+ bias). kernel: (in, out) array, or a
    QuantizedTensor / PreparedQuantizedTensor in paper layout (out, in).
    Prepared leaves take the fused one-launch-per-bit-width kernel path."""
    full = scoped_name(name)
    kernel = p["kernel"]
    if isinstance(kernel, (QuantizedTensor, PreparedQuantizedTensor)):
        from repro.kernels import ops as kops
        y = kops.qmatmul(x, kernel,
                         use_kernel=(QuantMode.mode == "kernel"),
                         interpret=QuantMode.interpret,
                         act_dtype=QuantMode.act_dtype)
    else:
        _maybe_record(full, x)
        y = x @ kernel.astype(x.dtype)
    b = p.get("bias")
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def materialize_kernel(p: Dict[str, Any]) -> Array:
    """Kernel as a dense (in, out) array (dequantizing if quantized) — for
    paths that need explicit weight access (e.g. MLA absorbed decode)."""
    kernel = p["kernel"]
    if isinstance(kernel, (QuantizedTensor, PreparedQuantizedTensor)):
        return kernel.dequantize(jnp.bfloat16).T
    return kernel


def rms_norm(p: Dict[str, Any], x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layer_norm(p: Dict[str, Any], x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embed(p: Dict[str, Any], tokens: Array) -> Array:
    return jnp.take(p["embedding"], tokens, axis=0)


# ---------------------------------------------------------------------------
# Initializers (host-side, explicit rngs)
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, out_dim: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None):
    if scale is None:
        scale = in_dim ** -0.5
    k = jax.random.normal(rng, (in_dim, out_dim), dtype) * scale
    p = {"kernel": k}
    if bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def rms_norm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def layer_norm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    return {"embedding": jax.random.normal(rng, (vocab, dim), dtype) * 0.02}


def split_rngs(rng, n: int):
    return list(jax.random.split(rng, n))
