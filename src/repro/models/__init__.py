"""Model zoo: config-driven architectures (dense/MoE/MLA/SSM/RWKV/enc-dec)."""
