"""Shared transformer layers: RoPE, blocked (flash-style) attention, GQA
attention sublayer with KV cache, SwiGLU MLP.

Attention is double-blocked (query blocks x kv blocks) with an online
softmax — pure jnp/lax, so it lowers on any backend, keeps the S^2 score
matrix out of memory (critical for the 32k prefill dry-run cells), and is
sharding-transparent under pjit (head/batch/sequence axes shardable).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from repro.kernels import ops as kops
from . import modules as nn

Array = jax.Array


def attn_constrain(q, k, v, q_block: int = 512):
    """Pick the attention compute sharding (first viable):
      1) KV heads over `model` (clean TP);
      2) batch over dp+model (head-indivisible archs at large batch);
      3) query rows *within each q block* over `model` (context-parallel
         prefill at small batch — the q-block scan axis itself cannot be
         sharded, so rows inside the block are);
      4) data-parallel only.
    Returns (q, k, v, block_spec) where block_spec is the sharding hint
    applied to every (B, KH, G, q_block, D) tile inside blocked_attention.
    `dctx.constrain` drops any non-divisible axis, so later options only
    engage when earlier ones resolved to None."""
    mesh = dctx.get_mesh()
    if mesh is None:
        return q, k, v, None
    msz = mesh.shape["model"]
    B, Sq, H, _ = q.shape
    KH = k.shape[2]
    dp = dctx._axis_size(mesh, "dp")
    if KH % msz == 0:
        q = dctx.constrain(q, "dp", None, "model", None)
        k = dctx.constrain(k, "dp", None, "model", None)
        v = dctx.constrain(v, "dp", None, "model", None)
        return q, k, v, ("dp", "model", None, None, None)
    if B % (dp * msz) == 0:
        q = dctx.constrain(q, "dp+model", None, None, None)
        k = dctx.constrain(k, "dp+model", None, None, None)
        v = dctx.constrain(v, "dp+model", None, None, None)
        return q, k, v, ("dp+model", None, None, None, None)
    q = dctx.constrain(q, "dp", None, None, None)
    k = dctx.constrain(k, "dp", None, None, None)
    v = dctx.constrain(v, "dp", None, None, None)
    if min(q_block, Sq) % msz == 0 and Sq > 1:
        return q, k, v, ("dp", None, None, "model", None)
    return q, k, v, ("dp", None, None, None, None)

NEG_INF = -1e30


def select_logits(logits: Array, logits_at=None) -> Array:
    """Pick positions per row from (B, S, V) logits.

    ``logits_at=None`` keeps the legacy contract (last position).  Under
    right-padded bucketed prefill the last position is a padding token, so
    the serving engine passes the true last-token index per row (``n-1``,
    scalar or (B,)); it is consumed as a traced operand, so varying true
    lengths inside one bucket never force a retrace.

    A 2-D ``logits_at`` of shape (B, T) selects T positions per row and
    returns (B, T, V) — one speculative-verify call reads the logits at
    all γ+1 trailing span positions this way instead of γ+1 calls.
    """
    if logits_at is None:
        return logits[:, -1]
    idx = jnp.asarray(logits_at, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (logits.shape[0],))
    if idx.ndim == 1:
        return jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
    return jnp.take_along_axis(logits, idx[:, :, None], axis=1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: Array, rotary_dim: int, theta: float) -> Tuple[Array, Array]:
    """positions (..., S) -> cos/sin (..., S, rotary_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (B, S, H, D); cos/sin (B, S, D_rot/2). Rotates the first D_rot dims
    (paired as [0::2], [1::2])."""
    d_rot = 2 * cos.shape[-1]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    y = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([y, xp], axis=-1) if xp.shape[-1] else y


# ---------------------------------------------------------------------------
# Blocked online-softmax attention
# ---------------------------------------------------------------------------

def blocked_attention(
    q: Array,                      # (B, Sq, H, D)
    k: Array,                      # (B, Skv, KH, D)
    v: Array,                      # (B, Skv, KH, Dv)
    *,
    causal: bool = True,
    q_offset: Array | int = 0,     # global position of q[0] (decode/prefill)
    kv_len: Optional[Array] = None,  # valid kv entries (cache fill level)
    q_block: int = 512,
    kv_block: int = 1024,
    window: Optional[int] = None,  # sliding-window attention (zamba long-ctx)
    block_spec=None,               # sharding hint for (B,KH,G,qb,D) tiles
) -> Array:
    B, Sq, H, D = q.shape
    _, Skv, KH, Dv = v.shape
    assert H % KH == 0
    G = H // KH
    scale = D ** -0.5

    q_block = min(q_block, max(Sq, 1))
    kv_block = min(kv_block, max(Skv, 1))
    sq_p = -(-Sq // q_block) * q_block
    skv_p = -(-Skv // kv_block) * kv_block

    qh = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    kh = jnp.pad(k, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))
    vh = jnp.pad(v, ((0, 0), (0, skv_p - Skv), (0, 0), (0, 0)))

    # (B,S,H,D) -> (B,KH,G,S,D) / (B,KH,S,D)
    qh = qh.transpose(0, 2, 1, 3).reshape(B, KH, G, sq_p, D) * scale
    kh = kh.transpose(0, 2, 1, 3)
    vh = vh.transpose(0, 2, 1, 3)

    nq, nk = sq_p // q_block, skv_p // kv_block
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq_p)
    kv_pos = jnp.arange(skv_p)
    # kv_len may be scalar or per-batch (B,) (serving slots fill unevenly)
    kv_lim = jnp.broadcast_to(
        jnp.asarray(Skv if kv_len is None else kv_len), (B,))
    kv_valid = kv_pos[None, :] < kv_lim[:, None]            # (B, skv_p)

    # stack blocks for scan: kv (nk, B, KH, kb, D)
    k_blk = jnp.moveaxis(kh.reshape(B, KH, nk, kv_block, D), 2, 0)
    v_blk = jnp.moveaxis(vh.reshape(B, KH, nk, kv_block, Dv), 2, 0)
    kpos_blk = kv_pos.reshape(nk, kv_block)
    kval_blk = jnp.moveaxis(kv_valid.reshape(B, nk, kv_block), 1, 0)

    def q_body(qb, qpos_b):
        # qb (B,KH,G,qb,D); qpos_b (qb,)
        if block_spec is not None:
            qb = dctx.constrain(qb, *block_spec)
        m0 = jnp.full((B, KH, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KH, G, q_block, Dv), jnp.float32)

        def kv_body(carry, blk):
            m, l, acc = carry
            kc, vc, kpos_c, kval_c = blk
            s = jnp.einsum("bkgqd,bksd->bkgqs", qb, kc,
                           preferred_element_type=jnp.float32)
            mask = kval_c[:, None, None, None, :]          # (B,1,1,1,kb)
            if causal:
                mask = mask & (kpos_c[None, :] <= qpos_b[:, None])[None, None, None]
            if window is not None:
                mask = mask & (kpos_c[None, :] > qpos_b[:, None] - window)[None, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksv->bkgqv", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      (k_blk, v_blk, kpos_blk, kval_blk))
        out_b = acc / jnp.maximum(l, 1e-30)[..., None]
        if block_spec is not None:
            out_b = dctx.constrain(out_b, *block_spec)
        return out_b

    q_blk = jnp.moveaxis(qh.reshape(B, KH, G, nq, q_block, D), 3, 0)
    qpos_blk = q_pos.reshape(nq, q_block)
    out = jax.lax.map(lambda args: q_body(*args), (q_blk, qpos_blk))
    # (nq,B,KH,G,qb,Dv) -> (B, Sq, H, Dv)
    out = jnp.moveaxis(out, 0, 3).reshape(B, KH, G, sq_p, Dv)
    out = out.reshape(B, H, sq_p, Dv).transpose(0, 2, 1, 3)[:, :Sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention sublayer (with KV cache)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array        # (B, S_max, KH, D)
    v: Array        # (B, S_max, KH, D)
    length: Array   # (B,) int32 — filled entries per serving slot


def init_kv_cache(batch: int, max_len: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Paged KV cache
# ---------------------------------------------------------------------------
#
# The contiguous cache pins a dense (B, max_len, KH, D) strip per slot; the
# paged cache replaces it with ONE global page pool shared by every slot plus
# a per-slot page table:
#
#   pool   (n_pages + 1, page_size, KH, D)   row n_pages = SCRATCH page
#   table  (B, max_pages) int32              scratch-filled where unallocated
#   length (B,) int32                        same fill contract as KVCache
#
# Model code NEVER mutates tables — the serving engine owns them on the host
# (allocation, copy-on-write, freeing) and syncs them in as operands.  The
# scratch row absorbs every write a contiguous cache would mask or drop:
# free slots with stale fill counters, span tails past max_len, unallocated
# blocks.  Reads gather `pool[table]`, which reconstructs EXACTLY the
# contiguous (B, max_pages*page_size, ...) view — same shape, same dtype, so
# the downstream masked-softmax attention lowers to the same XLA reduction
# tree and paged fp decode reproduces contiguous decode's logits (DESIGN.md
# §11 gives the argument; tests/test_paged_serving.py asserts it).
#
# With kv_dtype="int8" the pool rows are int8 with a per-token absmax scale
# (`kernels.ops.quantize_activations` — the PR 5 A8 machinery), dequantized
# at the gather; the per-element error is bounded by scale/2.


class PagedKVCache(NamedTuple):
    kp: Array                       # (n_pages+1, page_size, KH, D)
    vp: Array                       # (n_pages+1, page_size, KH, D)
    k_scale: Optional[Array]        # (n_pages+1, page_size) f32 iff int8 pool
    v_scale: Optional[Array]
    table: Array                    # (B, max_pages) int32
    length: Array                   # (B,) int32


def init_paged_kv_cache(batch: int, max_len: int, kv_heads: int,
                        head_dim: int, *, page_size: int, n_pages: int,
                        dtype=jnp.bfloat16, kv_dtype=None) -> PagedKVCache:
    if max_len % page_size:
        raise ValueError(
            f"page_size {page_size} must divide max_len {max_len}")
    pool_dtype = jnp.int8 if kv_dtype == "int8" else dtype
    scale = (jnp.zeros((n_pages + 1, page_size), jnp.float32)
             if kv_dtype == "int8" else None)
    return PagedKVCache(
        kp=jnp.zeros((n_pages + 1, page_size, kv_heads, head_dim), pool_dtype),
        vp=jnp.zeros((n_pages + 1, page_size, kv_heads, head_dim), pool_dtype),
        k_scale=scale,
        v_scale=scale,
        table=jnp.full((batch, max_len // page_size), n_pages, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def paged_write_ids(table: Array, length: Array, S: int, page_size: int,
                    scratch: int) -> Tuple[Array, Array]:
    """Page ids + within-page offsets for S tokens appended at each slot's
    fill level.  Positions past max_len (stale free-slot counters, span
    tails) and unallocated blocks route to the scratch page — the paged
    equivalent of the contiguous paths' masking / mode="drop"."""
    mp = table.shape[1]
    idx = length[:, None] + jnp.arange(S)[None, :]            # (B, S)
    blk = jnp.minimum(idx // page_size, mp - 1)
    pid = jnp.take_along_axis(table, blk, axis=1)
    pid = jnp.where(idx >= mp * page_size, scratch, pid)
    return pid, idx % page_size


def pool_write(pool: Array, scale: Optional[Array], pid: Array, off: Array,
               rows: Array) -> Tuple[Array, Optional[Array]]:
    """Scatter new rows (B, S, feat...) into pool[pid, off].  For an int8
    pool each token row is absmax-quantized (scale stored alongside);
    duplicate (pid, off) pairs only ever target scratch, whose contents
    are never read unmasked."""
    if scale is None:
        return pool.at[pid, off].set(rows.astype(pool.dtype)), None
    flat = rows.reshape(rows.shape[:2] + (-1,))
    xq, sc = kops.quantize_activations(flat.astype(jnp.float32))
    return (pool.at[pid, off].set(xq.reshape(rows.shape)),
            scale.at[pid, off].set(sc[..., 0]))


def pool_view(pool: Array, scale: Optional[Array], table: Array,
              out_dtype) -> Array:
    """Gather each slot's pages into the contiguous-equivalent
    (B, max_pages*page_size, feat...) view.  fp pools come back verbatim
    (bitwise the contiguous cache at valid positions); int8 pools
    dequantize through their per-token scales into ``out_dtype``."""
    g = pool[table]                                 # (B, mp, ps, feat...)
    if scale is not None:
        sc = scale[table].reshape(g.shape[:3] + (1,) * (g.ndim - 3))
        g = (g.astype(jnp.float32) * sc).astype(out_dtype)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def gqa_attention(
    p: Dict[str, Any],
    x: Array,                      # (B, S, D)
    cfg,
    cache: Optional[KVCache] = None,
    positions: Optional[Array] = None,
    span: bool = False,
) -> Tuple[Array, Optional[KVCache]]:
    """Standard GQA attention with optional qk-norm, qkv-bias, window.

    With a cache: appends S new tokens at cache.length and attends over the
    full cache (decode / chunked prefill).  Without: causal self-attention.

    ``span=True`` (speculative verify, S > 1): the S tokens append at each
    slot's OWN fill level (per-slot scatter, not the uniform-start chunked
    prefill) and attention runs the same full-cache masked-softmax path as
    single-token decode, so a γ-token span is bitwise the computation of γ
    successive decode steps.  Writes past the cache end are dropped — the
    admission budget guarantees every *accepted* span position is in
    bounds, and the rollback zeroes whatever a rejected tail wrote.
    """
    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = nn.dense(p["q"], x, "q").reshape(B, S, H, hd)
    k = nn.dense(p["k"], x, "k").reshape(B, S, KH, hd)
    v = nn.dense(p["v"], x, "v").reshape(B, S, KH, hd)

    if cfg.qk_norm:
        q = nn.rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = nn.rms_norm(p["k_norm"], k, cfg.norm_eps)

    if positions is None:
        if cache is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        else:
            positions = cache.length[:, None] + jnp.arange(S)[None, :]
    rot = cfg.rotary_dim or hd
    cos, sin = rope_angles(positions, rot, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    block_spec = None
    if S > 1 and not span:
        # train / prefill (decode and span-decode shard via the cache's
        # own specs)
        q, k, v, block_spec = attn_constrain(q, k, v, cfg.q_block)

    window = getattr(cfg, "attn_window", None)
    brange = jnp.arange(B)

    if cache is None:
        out = blocked_attention(q, k, v, causal=True, window=window,
                                q_block=cfg.q_block, kv_block=cfg.kv_block,
                                block_spec=block_spec)
        new_cache = None
    elif isinstance(cache, PagedKVCache):
        # Paged decode / span-verify: append through the page table, then
        # run the SAME masked attention as the contiguous branches over the
        # gathered view — the view has the contiguous cache's exact shape
        # and (for fp pools) bit pattern at valid positions, so paged fp
        # decode is parity-exact with the contiguous cache.
        if window is not None:
            raise NotImplementedError(
                "paged KV cache does not support attn_window configs")
        if S > 1 and not span:
            raise NotImplementedError(
                "paged caches take no chunked prefill: the engine prefills "
                "contiguous fragments and page-inserts them")
        ps = cache.kp.shape[1]
        pid, off = paged_write_ids(cache.table, cache.length, S, ps,
                                   cache.kp.shape[0] - 1)
        kp, k_scale = pool_write(cache.kp, cache.k_scale, pid, off, k)
        vp, v_scale = pool_write(cache.vp, cache.v_scale, pid, off, v)
        new_len = cache.length + S
        k_all = pool_view(kp, k_scale, cache.table, q.dtype)
        v_all = pool_view(vp, v_scale, cache.table, q.dtype)
        if S == 1:
            out = _decode_attention(q, k_all, v_all, new_len, None)
        else:
            out = _span_decode_attention(q, k_all, v_all, cache.length, None)
        new_cache = PagedKVCache(kp, vp, k_scale, v_scale,
                                 cache.table, new_len)
    elif window is not None and cache.k.shape[1] <= window:
        # Ring cache for sliding-window attention (cache holds exactly the
        # window; slot = absolute_position % W).  Keys are stored post-RoPE,
        # so slot order doesn't matter for the masked softmax.
        W = cache.k.shape[1]
        if S == 1:
            slot = jax.lax.rem(cache.length, W)              # (B,)
            k_all = cache.k.at[brange, slot].set(k[:, 0].astype(cache.k.dtype))
            v_all = cache.v.at[brange, slot].set(v[:, 0].astype(cache.v.dtype))
            new_len = cache.length + 1
            valid = jnp.minimum(new_len, W)
            out = _decode_attention(q, k_all, v_all, valid, window=None)
            new_cache = KVCache(k_all, v_all, new_len)
        else:
            # single-shot prefill into a ring (requires empty cache)
            out = blocked_attention(q, k, v, causal=True, window=window,
                                    q_block=cfg.q_block, kv_block=cfg.kv_block,
                                    block_spec=block_spec)
            if S >= W:
                k_keep, v_keep = k[:, S - W:], v[:, S - W:]
                shift = S % W
                k_all = jnp.roll(k_keep, shift, axis=1).astype(cache.k.dtype)
                v_all = jnp.roll(v_keep, shift, axis=1).astype(cache.v.dtype)
            else:
                k_all = jax.lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
                v_all = jax.lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
            new_cache = KVCache(k_all, v_all, cache.length + S)
    elif S == 1:
        # decode: per-slot scatter at each slot's own fill level
        idx = cache.length                                   # (B,)
        k_all = cache.k.at[brange, idx].set(k[:, 0].astype(cache.k.dtype))
        v_all = cache.v.at[brange, idx].set(v[:, 0].astype(cache.v.dtype))
        new_len = cache.length + 1
        out = _decode_attention(q, k_all, v_all, new_len, window)
        new_cache = KVCache(k_all, v_all, new_len)
    elif span:
        # speculative verify: S tokens at per-slot fill levels.  mode="drop"
        # (not the scatter default of clamping) so a span running past
        # max_len near the end of a slot's budget cannot overwrite the last
        # real K/V row — dropped positions belong to draft tokens that can
        # never be accepted (the admission budget bounds accepted history
        # at max_len).
        idx = cache.length[:, None] + jnp.arange(S)[None, :]   # (B, S)
        k_all = cache.k.at[brange[:, None], idx].set(
            k.astype(cache.k.dtype), mode="drop")
        v_all = cache.v.at[brange[:, None], idx].set(
            v.astype(cache.v.dtype), mode="drop")
        out = _span_decode_attention(q, k_all, v_all, cache.length, window)
        new_cache = KVCache(k_all, v_all, cache.length + S)
    else:
        # chunked prefill: uniform fill level assumed across the batch
        start = cache.length[0]
        k_all = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, start, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, start, 0, 0))
        new_len = cache.length + S
        out = blocked_attention(q, k_all, v_all, causal=True,
                                q_offset=start, kv_len=new_len,
                                window=window, q_block=cfg.q_block,
                                kv_block=cfg.kv_block, block_spec=block_spec)
        new_cache = KVCache(k_all, v_all, new_len)

    out = out.reshape(B, S, H * hd)
    return nn.dense(p["o"], out, "o"), new_cache


def _span_decode_attention(q, k_cache, v_cache, base_len, window=None):
    """Multi-token decode (speculative verify): q (B,S,H,D) against the
    full cache; row s of slot b attends positions < base_len[b] + s + 1
    (its own K/V included, like decode).  Mirrors `_decode_attention`'s
    masked-softmax formulation op for op — same einsum contraction per
    output element, same NEG_INF mask + jax.nn.softmax — so verify logits
    are bitwise the logits of S successive single-token decode steps
    (greedy speculative decoding stays lossless at the bit level)."""
    B, S, H, D = q.shape
    _, Skv, KH, Dv = v_cache.shape
    G = H // KH
    qh = q.transpose(0, 2, 1, 3).reshape(B, KH, G, S, D) * (D ** -0.5)
    s = jnp.einsum("bkgqd,bskd->bkgqs", qh.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(Skv)
    lim = jnp.asarray(base_len)[:, None] + jnp.arange(S)[None, :] + 1  # (B,S)
    mask = pos[None, None, None, None, :] < lim[:, None, None, :, None]
    if window is not None:
        mask = mask & (pos[None, None, None, None, :]
                       > lim[:, None, None, :, None] - 1 - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskv->bkgqv", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, H, S, Dv).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def _decode_attention(q, k_cache, v_cache, kv_len, window=None):
    """Single-token decode: q (B,1,H,D) vs full cache — direct masked path.
    kv_len: (B,) valid entries per slot."""
    B, _, H, D = q.shape
    _, S, KH, Dv = v_cache.shape
    G = H // KH
    # operands stay in cache dtype (bf16); MXU accumulates in f32 — avoids
    # materializing an f32 copy of the whole cache (2x decode HBM traffic)
    qh = q.reshape(B, KH, G, D) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S)
    lim = jnp.broadcast_to(jnp.asarray(kv_len), (B,))[:, None, None, None]
    mask = pos[None, None, None, :] < lim
    if window is not None:
        mask = mask & (pos[None, None, None, :] > lim - 1 - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskv->bkgv", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


def attention_init(rng, cfg, dtype=jnp.float32):
    H, KH, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    r = nn.split_rngs(rng, 4)
    p = {
        "q": nn.dense_init(r[0], D, H * hd, bias=cfg.qkv_bias, dtype=dtype),
        "k": nn.dense_init(r[1], D, KH * hd, bias=cfg.qkv_bias, dtype=dtype),
        "v": nn.dense_init(r[2], D, KH * hd, bias=cfg.qkv_bias, dtype=dtype),
        "o": nn.dense_init(r[3], H * hd, D, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rms_norm_init(hd, dtype)
        p["k_norm"] = nn.rms_norm_init(hd, dtype)
    return p


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_mlp(p: Dict[str, Any], x: Array) -> Array:
    g = nn.dense(p["gate"], x, "gate")
    u = nn.dense(p["up"], x, "up")
    h = dctx.constrain(jax.nn.silu(g) * u, "dp", None, "model")
    return nn.dense(p["down"], h, "down")


def swiglu_init(rng, d_model: int, d_ff: int, dtype=jnp.float32):
    r = nn.split_rngs(rng, 3)
    return {
        "gate": nn.dense_init(r[0], d_model, d_ff, dtype=dtype),
        "up": nn.dense_init(r[1], d_model, d_ff, dtype=dtype),
        "down": nn.dense_init(r[2], d_ff, d_model, dtype=dtype),
    }


def gelu_mlp(p: Dict[str, Any], x: Array) -> Array:
    h = jax.nn.gelu(nn.dense(p["up"], x, "up"))
    h = dctx.constrain(h, "dp", None, "model")
    return nn.dense(p["down"], h, "down")


def gelu_mlp_init(rng, d_model: int, d_ff: int, dtype=jnp.float32):
    r = nn.split_rngs(rng, 2)
    return {
        "up": nn.dense_init(r[0], d_model, d_ff, dtype=dtype),
        "down": nn.dense_init(r[1], d_ff, d_model, dtype=dtype),
    }
