"""Encoder–decoder backbone (seamless-m4t-medium text/unit path).

The speech frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, D).  Decoder = causal self-attn +
cross-attn + MLP.  Serving caches the decoder self-attention KV and the
cross-attention K/V (projected once from the encoder output at prefill).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import modules as nn
from . import layers as L

Array = jax.Array


class EncDecCache(NamedTuple):
    self_kv: Any       # stacked L.KVCache over dec layers
    cross_k: Array     # (L_dec, B, S_src, KH, hd)
    cross_v: Array
    enc_len: Array


def _cross_init(rng, cfg, dtype):
    H, KH, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    r = nn.split_rngs(rng, 4)
    return {
        "q": nn.dense_init(r[0], D, H * hd, dtype=dtype),
        "k": nn.dense_init(r[1], D, KH * hd, dtype=dtype),
        "v": nn.dense_init(r[2], D, KH * hd, dtype=dtype),
        "o": nn.dense_init(r[3], H * hd, D, dtype=dtype),
    }


def encdec_init(rng, cfg) -> Dict[str, Any]:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    r_embed, r_enc, r_dec, r_head = jax.random.split(rng, 4)

    def enc_block(r):
        r1, r2 = jax.random.split(r)
        return {
            "ln1": nn.rms_norm_init(cfg.d_model),
            "attn": L.attention_init(r1, cfg, dtype),
            "ln2": nn.rms_norm_init(cfg.d_model),
            "mlp": L.gelu_mlp_init(r2, cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_block(r):
        r1, r2, r3 = jax.random.split(r, 3)
        return {
            "ln1": nn.rms_norm_init(cfg.d_model),
            "attn": L.attention_init(r1, cfg, dtype),
            "ln_x": nn.rms_norm_init(cfg.d_model),
            "cross": _cross_init(r2, cfg, dtype),
            "ln2": nn.rms_norm_init(cfg.d_model),
            "mlp": L.gelu_mlp_init(r3, cfg.d_model, cfg.d_ff, dtype),
        }

    return {
        "embed": nn.embed_init(r_embed, cfg.vocab, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(enc_block)(
            jnp.stack(jax.random.split(r_enc, cfg.enc_layers))),
        "dec_blocks": jax.vmap(dec_block)(
            jnp.stack(jax.random.split(r_dec, cfg.dec_layers))),
        "enc_norm": nn.rms_norm_init(cfg.d_model),
        "final_norm": nn.rms_norm_init(cfg.d_model),
        "lm_head": nn.dense_init(r_head, cfg.d_model, cfg.vocab, dtype=dtype),
    }


def _cross_attention(p, x, enc_kv, cfg, enc_len=None):
    """x (B,St,D) queries over cached encoder K/V (B,Ss,KH,hd)."""
    B, St, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = nn.dense(p["q"], x, "q").reshape(B, St, H, hd)
    k, v = enc_kv
    q, k, v, bspec = L.attn_constrain(q, k.astype(x.dtype),
                                      v.astype(x.dtype), cfg.q_block)
    out = L.blocked_attention(q, k, v, causal=False, kv_len=enc_len,
                              q_block=cfg.q_block, kv_block=cfg.kv_block,
                              block_spec=bspec)
    return nn.dense(p["o"], out.reshape(B, St, H * hd), "o")


def encode(params, cfg, frames: Array, unroll: bool = False) -> Array:
    """frames (B, S_src, D) -> encoder states. Bidirectional self-attn."""
    x = frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    def body_fn(h, p_i):
        hn = nn.rms_norm(p_i["ln1"], h, cfg.norm_eps)
        B, S, _ = hn.shape
        H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        with nn.scope("attn"):
            q = nn.dense(p_i["attn"]["q"], hn, "q").reshape(B, S, H, hd)
            k = nn.dense(p_i["attn"]["k"], hn, "k").reshape(B, S, KH, hd)
            v = nn.dense(p_i["attn"]["v"], hn, "v").reshape(B, S, KH, hd)
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            cos, sin = L.rope_angles(pos, cfg.rotary_dim or hd, cfg.rope_theta)
            q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
            q, k, v, bspec = L.attn_constrain(q, k, v, cfg.q_block)
            a = L.blocked_attention(q, k, v, causal=False,
                                    q_block=cfg.q_block, kv_block=cfg.kv_block,
                                    block_spec=bspec)
            h = h + nn.dense(p_i["attn"]["o"], a.reshape(B, S, H * hd), "o")
        hn = nn.rms_norm(p_i["ln2"], h, cfg.norm_eps)
        with nn.scope("mlp"):
            h = h + L.gelu_mlp(p_i["mlp"], hn)
        return h

    if unroll or not cfg.scan_layers:
        for i in range(cfg.enc_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["enc_blocks"])
            with nn.scope(f"enc.{i}"):
                x = body_fn(x, p_i)
    else:
        body = (lambda h, p_i: (body_fn(h, p_i), None))
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return nn.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(p, x, cfg, enc_kv, enc_len, cache):
    h = nn.rms_norm(p["ln1"], x, cfg.norm_eps)
    with nn.scope("attn"):
        a, new_cache = L.gqa_attention(p["attn"], h, cfg, cache)
    x = x + a
    h = nn.rms_norm(p["ln_x"], x, cfg.norm_eps)
    with nn.scope("cross"):
        x = x + _cross_attention(p["cross"], h, enc_kv, cfg, enc_len)
    h = nn.rms_norm(p["ln2"], x, cfg.norm_eps)
    with nn.scope("mlp"):
        x = x + L.gelu_mlp(p["mlp"], h)
    return x, new_cache


def _project_cross_kv(params, cfg, enc_out):
    """Per-decoder-layer cross K/V from encoder states (cached at prefill)."""
    B, Ss, _ = enc_out.shape
    KH, hd = cfg.n_kv_heads, cfg.head_dim

    def one(p_i):
        k = nn.dense(p_i["cross"]["k"], enc_out, "cross_k").reshape(B, Ss, KH, hd)
        v = nn.dense(p_i["cross"]["v"], enc_out, "cross_v").reshape(B, Ss, KH, hd)
        return k, v

    return jax.lax.map(one, params["dec_blocks"])


def decode_blocks(params, cfg, x, enc_out=None, cross_kv=None, enc_len=None,
                  caches=None, unroll: bool = False):
    if cross_kv is None:
        cross_kv = _project_cross_kv(params, cfg, enc_out)
    ck, cv = cross_kv

    if unroll or not cfg.scan_layers:
        new_caches = []
        for i in range(cfg.dec_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["dec_blocks"])
            c_i = (None if caches is None
                   else jax.tree_util.tree_map(lambda a: a[i], caches))
            with nn.scope(f"dec.{i}"):
                x, c_new = _dec_layer(p_i, x, cfg, (ck[i], cv[i]), enc_len, c_i)
            new_caches.append(c_new)
        stacked = (None if caches is None else jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_caches))
        return x, stacked, (ck, cv)

    def body(h, xs):
        p_i, ck_i, cv_i, c_i = xs
        h, c_new = _dec_layer(p_i, h, cfg, (ck_i, cv_i), enc_len, c_i)
        return h, c_new

    if cfg.remat:
        body = jax.checkpoint(body)
    x, new_caches = jax.lax.scan(
        body, x, (params["dec_blocks"], ck, cv, caches))
    return x, new_caches, (ck, cv)


def encdec_loss(params, cfg, batch: Dict[str, Array], unroll: bool = False):
    """batch: frames (B,Ss,D), tokens (B,St)."""
    enc_out = encode(params, cfg, batch["frames"], unroll=unroll)
    x = nn.embed(params["embed"], batch["tokens"])
    x, _, _ = decode_blocks(params, cfg, x, enc_out=enc_out, unroll=unroll)
    x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.dense(params["lm_head"], x, "lm_head")
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = batch["tokens"][:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean(), {"nll": nll.mean()}


def init_encdec_cache(cfg, batch: int, max_len: int, src_len: int,
                      dtype=jnp.bfloat16) -> EncDecCache:
    one = L.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim, dtype)
    self_kv = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape), one)
    return EncDecCache(
        self_kv=self_kv,
        cross_k=jnp.zeros((cfg.dec_layers, batch, src_len,
                           cfg.n_kv_heads, cfg.head_dim), dtype),
        cross_v=jnp.zeros((cfg.dec_layers, batch, src_len,
                           cfg.n_kv_heads, cfg.head_dim), dtype),
        enc_len=jnp.zeros((batch,), jnp.int32),
    )


def encdec_prefill(params, cfg, frames, tokens, cache: EncDecCache,
                   unroll: bool = False, logits_at=None):
    """Decoder prefill over cached encoder states.

    ``logits_at`` (scalar or (B,) positions) selects which decoder
    position's logits are returned — required when the token prompt is
    right-padded to a length bucket, where position -1 is padding."""
    enc_out = encode(params, cfg, frames, unroll=unroll)
    x = nn.embed(params["embed"], tokens)
    x, self_kv, (ck, cv) = decode_blocks(
        params, cfg, x, enc_out=enc_out, caches=cache.self_kv, unroll=unroll)
    x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.dense(params["lm_head"], x, "lm_head")
    new_cache = EncDecCache(self_kv=self_kv,
                            cross_k=ck.astype(cache.cross_k.dtype),
                            cross_v=cv.astype(cache.cross_v.dtype),
                            enc_len=jnp.full((frames.shape[0],), frames.shape[1], jnp.int32))
    return L.select_logits(logits, logits_at), new_cache


def encdec_decode_step(params, cfg, token: Array, cache: EncDecCache,
                       unroll: bool = False):
    if token.ndim == 1:
        token = token[:, None]
    x = nn.embed(params["embed"], token)
    x, self_kv, _ = decode_blocks(
        params, cfg, x, cross_kv=(cache.cross_k, cache.cross_v),
        enc_len=cache.enc_len, caches=cache.self_kv, unroll=unroll)
    x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.dense(params["lm_head"], x, "lm_head")
    new_cache = cache._replace(self_kv=self_kv)
    return logits[:, -1], new_cache
