"""Decoder-LM assembly for all families (dense / moe / rwkv / hybrid).

One config-driven implementation:
  * layers stacked + `lax.scan` (fast compile at 64 layers, remat-friendly);
    an unrolled eager mode (`unroll=True`) gives per-layer scope names for
    calibration taps;
  * caches are per-layer pytrees stacked along the layer axis and threaded
    through the scan as xs/ys;
  * hybrid (Zamba2-style) runs an outer unrolled loop over shared-attention
    sites with inner scans over the Mamba2 trunk.

Entry points: init_params, forward, lm_loss, init_cache, prefill, decode_step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from . import modules as nn
from . import layers as L
from . import mla as mla_lib
from . import moe as moe_lib
from . import mamba2 as m2
from . import rwkv6 as rwkv

Array = jax.Array


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Per-family block init / apply
# ---------------------------------------------------------------------------

def _block_init(rng, cfg, dtype):
    if cfg.family == "rwkv":
        p = rwkv.rwkv_init(rng, cfg, dtype)
        p["ln1"] = nn.layer_norm_init(cfg.d_model)
        p["ln2"] = nn.layer_norm_init(cfg.d_model)
        return p
    if cfg.family == "hybrid":
        p = m2.mamba_init(rng, cfg, dtype)
        p["ln"] = nn.rms_norm_init(cfg.d_model)
        return p
    r1, r2 = jax.random.split(rng)
    p = {"ln1": nn.rms_norm_init(cfg.d_model),
         "ln2": nn.rms_norm_init(cfg.d_model)}
    if cfg.use_mla:
        p["attn"] = mla_lib.mla_init(r1, cfg, dtype)
    else:
        p["attn"] = L.attention_init(r1, cfg, dtype)
    if cfg.family == "moe":
        p["mlp"] = moe_lib.moe_init(r2, cfg, dtype)
    elif cfg.mlp_type == "gelu":
        p["mlp"] = L.gelu_mlp_init(r2, cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = L.swiglu_init(r2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _attn_block(p, x, cfg, cache, span=False):
    h = nn.rms_norm(p["ln1"], x, cfg.norm_eps)
    with nn.scope("attn"):
        if cfg.use_mla:
            a, new_cache = mla_lib.mla_attention(p["attn"], h, cfg, cache,
                                                 span=span)
        else:
            a, new_cache = L.gqa_attention(p["attn"], h, cfg, cache,
                                           span=span)
    x = x + a
    h = nn.rms_norm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    with nn.scope("mlp"):
        if cfg.family == "moe":
            m, aux = moe_lib.moe_mlp(p["mlp"], h, cfg)
        elif cfg.mlp_type == "gelu":
            m = L.gelu_mlp(p["mlp"], h)
        else:
            m = L.swiglu_mlp(p["mlp"], h)
    return x + m, new_cache, aux


def _rwkv_block(p, x, cfg, cache):
    h = nn.layer_norm(p["ln1"], x, cfg.norm_eps)
    with nn.scope("tm"):
        a, state, last_tm = rwkv.time_mix(p["tm"], h, cfg, cache)
    x = x + a
    h2 = nn.layer_norm(p["ln2"], x, cfg.norm_eps)
    with nn.scope("cm"):
        c, last_cm = rwkv.channel_mix(p["cm"], h2, cache)
    x = x + c
    new_cache = None
    if cache is not None:
        T = h.shape[1]
        new_cache = rwkv.RWKVCache(state=state,
                                   prev_tm=last_tm.astype(cache.prev_tm.dtype),
                                   prev_cm=last_cm.astype(cache.prev_cm.dtype),
                                   length=cache.length + T)
    return x, new_cache, jnp.zeros((), jnp.float32)


def _mamba_layer(p, x, cfg, cache):
    h = nn.rms_norm(p["ln"], x, cfg.norm_eps)
    with nn.scope("mamba"):
        m, new_cache = m2.mamba_block(p, h, cfg, cache)
    return x + m, new_cache, jnp.zeros((), jnp.float32)


def block_apply(p, x, cfg, cache=None, span=False):
    x = dctx.constrain(x, "dp", None, None)
    if cfg.family == "rwkv":
        out = _rwkv_block(p, x, cfg, cache)
    elif cfg.family == "hybrid":
        out = _mamba_layer(p, x, cfg, cache)
    else:
        out = _attn_block(p, x, cfg, cache, span=span)
    return (dctx.constrain(out[0], "dp", None, None),) + out[1:]


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_params(rng, cfg) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    r_embed, r_blocks, r_head, r_site = jax.random.split(rng, 4)
    rngs = jax.random.split(r_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda r: _block_init(r, cfg, dtype))(rngs)
    params = {
        "embed": nn.embed_init(r_embed, cfg.vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": (nn.layer_norm_init(cfg.d_model)
                       if cfg.family == "rwkv"
                       else nn.rms_norm_init(cfg.d_model)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(r_head, cfg.d_model, cfg.vocab,
                                          dtype=dtype)
    if cfg.family == "hybrid" and cfg.attn_every > 0:
        # one shared transformer block (attn+MLP) + per-site LoRA deltas
        rs = jax.random.split(r_site, cfg.n_sites + 2)
        params["shared_attn"] = L.attention_init(rs[0], cfg, dtype)
        params["shared_attn"]["ln"] = nn.rms_norm_init(cfg.d_model)
        params["shared_attn"]["ln2"] = nn.rms_norm_init(cfg.d_model)
        params["shared_attn"]["mlp"] = L.swiglu_init(
            rs[-1], cfg.d_model, cfg.d_ff, dtype)
        lora_r = 32
        H, KH, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model

        def site_init(r):
            ra, rb = jax.random.split(r)
            return {
                "lora_a": jax.random.normal(ra, (D, lora_r), dtype) * (D ** -0.5),
                "lora_b": jax.random.normal(rb, (lora_r, H * hd), dtype) * 0.01,
            }
        params["site_lora"] = jax.vmap(site_init)(
            jnp.stack(jax.random.split(rs[1], cfg.n_sites)))
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _run_blocks(params, x, cfg, caches, unroll: bool, span: bool = False):
    """Apply all layers; returns (x, new_caches, aux_sum)."""
    blocks = params["blocks"]

    if cfg.family == "hybrid" and cfg.attn_every > 0:
        return _run_hybrid(params, x, cfg, caches, unroll)

    if unroll or not cfg.scan_layers:
        aux_sum = jnp.zeros((), jnp.float32)
        new_layers = []
        for i in range(cfg.n_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
            c_i = (None if caches is None
                   else jax.tree_util.tree_map(lambda a: a[i], caches))
            with nn.scope(f"layers.{i}"):
                x, c_new, aux = block_apply(p_i, x, cfg, c_i, span=span)
            aux_sum = aux_sum + aux
            if caches is not None:
                new_layers.append(c_new)
        new_caches = None
        if caches is not None:
            new_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_layers)
        return x, new_caches, aux_sum

    if caches is None:
        def body(carry, p_i):
            h, aux_sum = carry
            h, _, aux = block_apply(p_i, h, cfg, None)
            return (h, aux_sum + aux), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_sum), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), blocks)
        return x, None, aux_sum

    # Serving path: caches ride in the scan CARRY, not xs/ys — XLA aliases
    # while-loop carries in place, so each layer's update writes only its
    # own slice instead of copying the whole multi-GB cache between the
    # xs and ys buffers every step (§Perf iteration: ~4x decode HBM traffic).
    def body(carry, p_i):
        h, aux_sum, all_caches, li = carry
        c_i = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
            all_caches)
        h, c_new, aux = block_apply(p_i, h, cfg, c_i, span=span)
        all_caches = jax.tree_util.tree_map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, li, 0),
            all_caches, c_new)
        return (h, aux_sum + aux, all_caches, li + 1), None

    (x, aux_sum, new_caches, _), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), caches, jnp.int32(0)), blocks)
    return x, new_caches, aux_sum


def _shared_attention(params, x, cfg, site: int, cache):
    """Zamba2-style shared transformer block with per-site LoRA delta."""
    sp = params["shared_attn"]
    h = nn.rms_norm(sp["ln"], x, cfg.norm_eps)
    lora = jax.tree_util.tree_map(lambda a: a[site], params["site_lora"])
    with nn.scope(f"shared_attn.site{site}"):
        out, new_cache = L.gqa_attention(sp, h, cfg, cache)
        delta = (h @ lora["lora_a"].astype(h.dtype)) @ lora["lora_b"].astype(h.dtype)
        # LoRA delta folded into the attention output projection input
        out = out + nn.dense(sp["o"], delta, "o_lora")
        x = x + out
        h2 = nn.rms_norm(sp["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu_mlp(sp["mlp"], h2)
    return x, new_cache


def _run_hybrid(params, x, cfg, caches, unroll: bool):
    """attn_every-layer Mamba2 segments with a shared attention site before
    each segment.  caches: {'mamba': stacked(L), 'attn': stacked(n_sites)}."""
    n_sites = cfg.n_sites
    per = cfg.attn_every
    blocks = params["blocks"]
    aux_sum = jnp.zeros((), jnp.float32)
    m_caches = caches["mamba"] if caches is not None else None
    a_caches = caches["attn"] if caches is not None else None
    new_m, new_a = [], []

    for site in range(n_sites):
        a_c = (None if a_caches is None
               else jax.tree_util.tree_map(lambda a: a[site], a_caches))
        x, a_new = _shared_attention(params, x, cfg, site, a_c)
        if a_caches is not None:
            new_a.append(a_new)
        lo, hi = site * per, min((site + 1) * per, cfg.n_layers)
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], blocks)
        seg_c = (None if m_caches is None
                 else jax.tree_util.tree_map(lambda a: a[lo:hi], m_caches))
        if unroll or not cfg.scan_layers:
            for j in range(hi - lo):
                p_i = jax.tree_util.tree_map(lambda a: a[j], seg)
                c_i = (None if seg_c is None
                       else jax.tree_util.tree_map(lambda a: a[j], seg_c))
                with nn.scope(f"layers.{lo + j}"):
                    x, c_new, aux = block_apply(p_i, x, cfg, c_i)
                aux_sum = aux_sum + aux
                if seg_c is not None:
                    new_m.append(c_new)
        else:
            def body(carry, xs):
                h = carry
                p_i, c_i = xs
                h, c_new, _ = block_apply(p_i, h, cfg, c_i)
                return h, c_new
            if cfg.remat:
                body = jax.checkpoint(body)
            x, seg_new = jax.lax.scan(body, x, (seg, seg_c))
            if m_caches is not None:
                new_m.append(seg_new)

    new_caches = None
    if caches is not None:
        if unroll or not cfg.scan_layers:
            mstack = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_m)
        else:
            mstack = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_m)
        astack = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_a)
        new_caches = {"mamba": mstack, "attn": astack}
    return x, new_caches, aux_sum


def forward(
    params: Dict[str, Any],
    cfg,
    tokens: Optional[Array] = None,        # (B, S) int32
    prefix_embeds: Optional[Array] = None,  # (B, P, D) modality stub
    caches=None,
    unroll: bool = False,
    span: bool = False,
) -> Tuple[Array, Any, Array]:
    """Returns (logits (B, S_total, V), new_caches, aux_loss).

    ``span=True`` (requires caches): the S tokens append at each slot's own
    cache fill level with decode-identical attention — the speculative
    verify path (see decode_span)."""
    parts = []
    if prefix_embeds is not None:
        parts.append(prefix_embeds.astype(_dtype(cfg)))
    if tokens is not None:
        parts.append(nn.embed(params["embed"], tokens))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    x, new_caches, aux = _run_blocks(params, x, cfg, caches, unroll, span)
    x = (nn.layer_norm(params["final_norm"], x, cfg.norm_eps)
         if cfg.family == "rwkv"
         else nn.rms_norm(params["final_norm"], x, cfg.norm_eps))
    if cfg.tie_embeddings:
        nn._maybe_record("lm_head", x)
        logits = x @ params["embed"]["embedding"].astype(x.dtype).T
    else:
        logits = nn.dense(params["lm_head"], x, "lm_head")
    logits = dctx.constrain(logits, "dp", None, "model")
    return logits, new_caches, aux


def lm_loss(params, cfg, batch: Dict[str, Array], unroll: bool = False):
    """Next-token loss. batch: tokens (B,S) [+ prefix_embeds (B,P,D)]."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    logits, _, aux = forward(params, cfg, tokens, prefix, unroll=unroll)
    P = 0 if prefix is None else prefix.shape[1]
    logits_t = logits[:, P:-1].astype(jnp.float32)      # predict tokens[1:]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits_t, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    else:
        loss = nll.mean()
    return loss + 0.01 * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------

def validate_paged_support(cfg) -> None:
    """Which configs can serve from a paged KV cache.  The gated set
    mirrors `validate_span_support` plus windowed attention: paging needs
    position-indexed, fill-masked, non-ring cache storage (the page table
    replays the contiguous layout exactly; a ring cache or recurrent
    state has no per-position rows to page)."""
    if cfg.family in ("rwkv", "hybrid"):
        raise NotImplementedError(
            f"paged KV cache: the {cfg.family} family keeps recurrent "
            f"state, not per-position K/V rows — there is nothing to page")
    if cfg.family == "encdec":
        raise NotImplementedError(
            "paged KV cache: encdec serving is unsupported (ServingEngine "
            "rejects the family at construction)")
    if cfg.attn_window is not None:
        raise NotImplementedError(
            "paged KV cache: sliding-window ring caches index slots by "
            "position % W, which a page table does not reproduce")


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *,
               page_size: Optional[int] = None,
               n_pages: Optional[int] = None, kv_dtype=None):
    """Stacked per-layer caches.  With ``page_size`` set, attention layers
    get paged pools + tables instead of contiguous strips (``n_pages``
    defaults to exactly contiguous capacity, batch * max_len tokens;
    ``kv_dtype='int8'`` stores resident pages quantized)."""
    L_ = cfg.n_layers

    def stack(make_one, n):
        one = make_one()
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)

    if page_size is not None:
        validate_paged_support(cfg)
        if n_pages is None:
            n_pages = batch * (max_len // page_size)
        if cfg.use_mla:
            return stack(lambda: mla_lib.init_paged_mla_cache(
                batch, max_len, cfg, page_size=page_size, n_pages=n_pages,
                dtype=dtype, kv_dtype=kv_dtype), L_)
        return stack(lambda: L.init_paged_kv_cache(
            batch, max_len, cfg.n_kv_heads, cfg.head_dim,
            page_size=page_size, n_pages=n_pages, dtype=dtype,
            kv_dtype=kv_dtype), L_)

    if cfg.family == "rwkv":
        return stack(lambda: rwkv.init_rwkv_cache(batch, cfg, dtype), L_)
    if cfg.family == "hybrid":
        window = cfg.attn_window or max_len
        attn_len = min(max_len, window)
        return {
            "mamba": stack(lambda: m2.init_mamba_cache(batch, cfg, dtype), L_),
            "attn": stack(lambda: L.init_kv_cache(
                batch, attn_len, cfg.n_kv_heads, cfg.head_dim, dtype),
                cfg.n_sites),
        }
    if cfg.use_mla:
        return stack(lambda: mla_lib.init_mla_cache(batch, max_len, cfg, dtype), L_)
    return stack(lambda: L.init_kv_cache(
        batch, max_len, cfg.n_kv_heads, cfg.head_dim, dtype), L_)


def prefill(params, cfg, tokens, caches, prefix_embeds=None, unroll=False,
            logits_at=None):
    """Prefill the cache with a full prompt; returns (logits (B, V), cache).

    ``logits_at`` (scalar or (B,) positions into the sequence axis) selects
    which position's logits are returned — required when the prompt is
    right-padded to a length bucket, where position -1 is padding."""
    logits, caches, _ = forward(params, cfg, tokens, prefix_embeds,
                                caches=caches, unroll=unroll)
    return L.select_logits(logits, logits_at), caches


def decode_step(params, cfg, token: Array, caches, unroll: bool = False):
    """token (B,) or (B,1) -> (logits (B,V), new caches)."""
    if token.ndim == 1:
        token = token[:, None]
    logits, caches, _ = forward(params, cfg, token, caches=caches,
                                unroll=unroll)
    return logits[:, -1], caches


def validate_span_support(cfg) -> None:
    """Single source of truth for which configs support span decode —
    i.e. where an S-token span call is exactly S successive decode steps
    and a rejected tail can be rolled back.  Both the `decode_span`
    primitive and the serving engine's speculation gate
    (serve/speculative.validate_spec_support) call this, so the two can
    never drift."""
    if cfg.family == "encdec":
        raise NotImplementedError(
            "span decode: encdec serving is unsupported (ServingEngine "
            "rejects the family at construction)")
    if cfg.family in ("rwkv", "hybrid"):
        raise NotImplementedError(
            f"span decode: the {cfg.family} family folds every token into "
            f"recurrent state (rwkv wkv / the hybrid's mamba2 SSM), which "
            f"cannot be rolled back after a rejected speculation window; "
            f"serve it without speculation")
    if cfg.family == "moe":
        raise NotImplementedError(
            "span decode: moe's capacity-bounded router couples the span "
            "tokens (cap and the group-local cumsum depend on token "
            "count), so span logits would differ from successive decode "
            "steps and greedy speculation would not be lossless; serve "
            "moe without speculation")
    if cfg.attn_window is not None:
        raise NotImplementedError(
            "span decode: a sliding-window ring cache keeps only the LAST "
            "W keys (slot = position % W) — a span write would clobber "
            "evicted keys and rollback cannot restore them; serve "
            "windowed configs without speculation")


def decode_span(params, cfg, tokens: Array, caches, unroll: bool = False):
    """Append S = tokens.shape[1] tokens at each slot's OWN fill level and
    return the logits at every span position: (B, S, V), new caches.

    The speculative-verify step: one call yields the target model's
    predictions after each of the γ+1 trailing tokens, bitwise identical
    to running S successive decode_step calls (the attention path mirrors
    decode exactly — see layers._span_decode_attention).  Configs where
    that equivalence cannot hold are rejected by
    ``validate_span_support``."""
    validate_span_support(cfg)
    logits, caches, _ = forward(params, cfg, tokens, caches=caches,
                                unroll=unroll, span=True)
    return logits, caches
