"""RWKV6 ("Finch") block: time-mix with data-dependent per-channel decay +
squared-ReLU channel-mix.

Train/prefill use a GLA-style chunked form of the WKV recurrence (log-space
decay ratios inside a chunk, state carried across chunks); decode is the
exact O(1) recurrence.  Chunked vs recurrent parity is tested.

Recurrence (per head, key dim N, value dim N):
    out_t = r_t . (S_{t-1} + (u ∘ k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(wlog_t)), wlog_t = bias + LoRA(x_t)   (data-dependent).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from . import modules as nn

Array = jax.Array


class RWKVCache(NamedTuple):
    state: Array     # (B, H, N, N) wkv state
    prev_tm: Array   # (B, D) last input of time-mix (token shift)
    prev_cm: Array   # (B, D) last input of channel-mix
    length: Array


def rwkv_dims(cfg):
    H = cfg.d_model // cfg.rwkv_head_dim
    return H, cfg.rwkv_head_dim


def init_rwkv_cache(batch: int, cfg, dtype=jnp.bfloat16) -> RWKVCache:
    H, N = rwkv_dims(cfg)
    return RWKVCache(
        state=jnp.zeros((batch, H, N, N), jnp.float32),
        prev_tm=jnp.zeros((batch, cfg.d_model), dtype),
        prev_cm=jnp.zeros((batch, cfg.d_model), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def rwkv_init(rng, cfg, dtype=jnp.float32):
    D = cfg.d_model
    H, N = rwkv_dims(cfg)
    r = nn.split_rngs(rng, 10)
    return {
        "tm": {
            "mix": 0.5 * jnp.ones((5, D), jnp.float32),  # r,k,v,g,w shift mix
            "r": nn.dense_init(r[0], D, D, dtype=dtype),
            "k": nn.dense_init(r[1], D, D, dtype=dtype),
            "v": nn.dense_init(r[2], D, D, dtype=dtype),
            "g": nn.dense_init(r[3], D, D, dtype=dtype),
            "w_lora_a": nn.dense_init(r[4], D, cfg.decay_lora, dtype=dtype),
            "w_lora_b": nn.dense_init(r[5], cfg.decay_lora, D, dtype=dtype,
                                      scale=0.01),
            "w_bias": jnp.full((D,), -1.0, jnp.float32),
            "u_bonus": jnp.zeros((H, N), jnp.float32),
            "ln_x": nn.layer_norm_init(D),
            "o": nn.dense_init(r[6], D, D, dtype=dtype),
        },
        "cm": {
            "mix": 0.5 * jnp.ones((2, D), jnp.float32),  # k, r
            "k": nn.dense_init(r[7], D, cfg.d_ff, dtype=dtype),
            "v": nn.dense_init(r[8], cfg.d_ff, D, dtype=dtype),
            "r": nn.dense_init(r[9], D, D, dtype=dtype),
        },
    }


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """shifted_t = x_{t-1} (prev for t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, logw, u, chunk: int, init_state=None):
    """Chunked WKV. r,k,v (B,T,H,N); logw (B,T,H,N) = log decay (<0);
    u (H,N). Returns (out (B,T,H,N), final_state (B,H,N,N))."""
    B, T, H, N = r.shape
    pad = (-T) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk
    shp = (B, nc, chunk, H, N)
    rc, kc, vc, lw = (a.reshape(shp).astype(jnp.float32) for a in (r, k, v, logw))

    cw = jnp.cumsum(lw, axis=2)                        # inclusive cumsum
    cw_prev = cw - lw                                  # exclusive (cum_{t-1})
    total = cw[:, :, -1]                               # (B,nc,H,N)

    # intra-chunk: out_t += sum_{s<t} (r_t ∘ e^{cwprev_t - cw_s}).k_s v_s
    r_t = rc * jnp.exp(cw_prev)
    k_s = kc * jnp.exp(-cw)
    att = jnp.einsum("bcthn,bcshn->bchts", r_t, k_s)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    att = jnp.where(tri[None, None, None], att, 0.0)
    out = jnp.einsum("bchts,bcshn->bcthn", att, vc)
    # diagonal bonus term: (r_t ∘ u ∘ k_t) . v_t
    diag = jnp.einsum("bcthn,hn,bcthn->bcth", rc, u.astype(jnp.float32), kc)
    out = out + diag[..., None] * vc

    # chunk state contribution: sum_s (k_s ∘ e^{total - cw_s}) v_s^T
    k_dec = kc * jnp.exp(total[:, :, None] - cw)
    chunk_state = jnp.einsum("bcshn,bcshm->bchnm", k_dec, vc)

    if init_state is None:
        init_state = jnp.zeros((B, H, N, N), jnp.float32)

    def carry(S, inp):
        cs, tot = inp                                  # (B,H,N,N), (B,H,N)
        S_in = S
        S = S * jnp.exp(tot)[..., None] + cs
        return S, S_in

    final, S_in = jax.lax.scan(
        carry, init_state,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                    # (B,nc,H,N,N)

    # carried-state term: out_t += (r_t ∘ e^{cwprev_t}) . S_in
    out = out + jnp.einsum("bcthn,bchnm->bcthm", r_t, S_in)
    return out.reshape(B, Tp, H, N)[:, :T], final


def time_mix(p, x, cfg, cache: Optional[RWKVCache] = None):
    B, T, D = x.shape
    H, N = rwkv_dims(cfg)
    prev = cache.prev_tm if cache is not None else None
    xs = _token_shift(x, prev)
    mix = p["mix"]

    def mixed(i):
        m = mix[i][None, None, :].astype(x.dtype)
        return x * m + xs * (1.0 - m)

    r = nn.dense(p["r"], mixed(0), "r").reshape(B, T, H, N)
    k = nn.dense(p["k"], mixed(1), "k").reshape(B, T, H, N)
    v = nn.dense(p["v"], mixed(2), "v").reshape(B, T, H, N)
    g = nn.dense(p["g"], mixed(3), "g")
    wlog = (p["w_bias"][None, None, :].astype(jnp.float32)
            + nn.dense(p["w_lora_b"],
                       jnp.tanh(nn.dense(p["w_lora_a"], mixed(4), "w_lora_a")),
                       "w_lora_b").astype(jnp.float32))
    logw = -jnp.exp(wlog).reshape(B, T, H, N)          # log decay, < 0

    r = dctx.constrain(r, "dp", None, "model", None)
    k = dctx.constrain(k, "dp", None, "model", None)
    v = dctx.constrain(v, "dp", None, "model", None)
    logw = dctx.constrain(logw, "dp", None, "model", None)

    if cache is not None and T == 1:
        S = cache.state
        r1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
        u = p["u_bonus"].astype(jnp.float32)
        out = jnp.einsum("bhn,bhnm->bhm", r1, S) \
            + jnp.einsum("bhn,hn,bhn,bhm->bhm", r1, u, k1, v1)
        S = S * jnp.exp(logw[:, 0])[..., None] \
            + jnp.einsum("bhn,bhm->bhnm", k1, v1)
        out = out[:, None]
        final = S
    else:
        init = cache.state if cache is not None else None
        out, final = _wkv_chunked(r, k, v, logw, p["u_bonus"],
                                  cfg.rwkv_chunk, init)

    out = out.reshape(B, T, D).astype(x.dtype)
    out = nn.layer_norm(p["ln_x"], out)
    out = out * jax.nn.silu(g)
    y = nn.dense(p["o"], out, "o")
    return y, final, x[:, -1]


def channel_mix(p, x, cache: Optional[RWKVCache] = None):
    prev = cache.prev_cm if cache is not None else None
    xs = _token_shift(x, prev)
    mix = p["mix"]
    xk = x * mix[0][None, None].astype(x.dtype) + xs * (1 - mix[0][None, None]).astype(x.dtype)
    xr = x * mix[1][None, None].astype(x.dtype) + xs * (1 - mix[1][None, None]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(nn.dense(p["k"], xk, "k")))
    k = dctx.constrain(k, "dp", None, "model")
    y = jax.nn.sigmoid(nn.dense(p["r"], xr, "r")) * nn.dense(p["v"], k, "v")
    return y, x[:, -1]


# Layer assembly (pre-norm residual pattern around time_mix/channel_mix)
# lives in transformer.py so norms/residuals are uniform across families.
