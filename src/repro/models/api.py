"""Family-agnostic model API: init / loss / cache / prefill / decode, plus
the ShapeDtypeStruct input-spec builders the dry-run lowers against."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell

from . import encdec as ed
from . import transformer as tf

Array = jax.Array


def init_params(rng, cfg: ModelConfig):
    if cfg.family == "encdec":
        return ed.encdec_init(rng, cfg)
    return tf.init_params(rng, cfg)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array],
            unroll: bool = False):
    if cfg.family == "encdec":
        return ed.encdec_loss(params, cfg, batch, unroll=unroll)
    return tf.lm_loss(params, cfg, batch, unroll=unroll)


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: Optional[int] = None, dtype=jnp.bfloat16, *,
               page_size: Optional[int] = None,
               n_pages: Optional[int] = None, kv_dtype=None):
    """Family-dispatched cache allocation.

    encdec REQUIRES ``src_len``: the cross-attention strips are written
    once at prefill from exactly ``src_len`` encoder rows and never grow,
    so sizing them to anything else (the old ``max_len`` fallback) only
    wastes HBM per slot.  ``page_size``/``n_pages``/``kv_dtype`` switch
    attention families to the paged layout (see transformer.init_cache).
    """
    if cfg.family == "encdec":
        if page_size is not None:
            tf.validate_paged_support(cfg)  # raises: encdec is not paged
        if src_len is None:
            raise ValueError(
                "make_cache: encdec needs the actual src_len — the cross "
                "cache is written once at prefill and never grows, so "
                "there is no meaningful default")
        return ed.init_encdec_cache(cfg, batch, max_len, src_len, dtype)
    return tf.init_cache(cfg, batch, max_len, dtype, page_size=page_size,
                         n_pages=n_pages, kv_dtype=kv_dtype)


def prefill_step(params, cfg: ModelConfig, batch: Dict[str, Array], cache,
                 unroll: bool = False, logits_at=None):
    """``logits_at`` (scalar or (B,) positions) selects which position's
    logits are returned instead of the default last position — the serving
    engine passes ``true_len - 1`` when prompts are right-padded to a
    length bucket."""
    if cfg.family == "encdec":
        return ed.encdec_prefill(params, cfg, batch["frames"],
                                 batch["tokens"], cache, unroll=unroll,
                                 logits_at=logits_at)
    return tf.prefill(params, cfg, batch["tokens"], cache,
                      prefix_embeds=batch.get("prefix_embeds"), unroll=unroll,
                      logits_at=logits_at)


def decode_step(params, cfg: ModelConfig, token: Array, cache,
                unroll: bool = False):
    if cfg.family == "encdec":
        return ed.encdec_decode_step(params, cfg, token, cache, unroll=unroll)
    return tf.decode_step(params, cfg, token, cache, unroll=unroll)


def validate_span_support(cfg: ModelConfig) -> None:
    """Raise NotImplementedError unless span decode is exactly equivalent
    to successive decode steps on this config (see transformer.py)."""
    tf.validate_span_support(cfg)


def validate_paged_support(cfg: ModelConfig) -> None:
    """Raise NotImplementedError unless this config can serve from a
    paged KV cache (see transformer.py)."""
    tf.validate_paged_support(cfg)


def decode_span(params, cfg: ModelConfig, tokens: Array, cache,
                unroll: bool = False):
    """Speculative verify: append tokens (B, S) at each slot's own cache
    fill level; returns (logits (B, S, V), cache) — the logits at all S
    trailing positions from ONE call, bitwise S successive decode_steps.
    Unsupported configs are rejected by ``validate_span_support``."""
    return tf.decode_span(params, cfg, tokens, cache, unroll=unroll)


# ---------------------------------------------------------------------------
# Input specs for the dry-run (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Inputs for the cell's entry point (train loss / prefill / decode).

    For modality archs the frontend is a stub: `prefix_embeds` / `frames`
    stand in for the precomputed patch/frame embeddings.
    """
    B, S = cell.global_batch, cell.seq_len
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if cell.kind == "train":
        if cfg.family == "encdec":
            return {"frames": _sds((B, S, cfg.d_model), act),
                    "tokens": _sds((B, S), jnp.int32)}
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.modality == "vision":
            P = int(S * cfg.prefix_frac)
            batch = {"tokens": _sds((B, S - P), jnp.int32),
                     "prefix_embeds": _sds((B, P, cfg.d_model), act)}
        return batch

    if cell.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": _sds((B, S, cfg.d_model), act),
                    "tokens": _sds((B, S), jnp.int32)}
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.modality == "vision":
            P = int(S * cfg.prefix_frac)
            batch = {"tokens": _sds((B, S - P), jnp.int32),
                     "prefix_embeds": _sds((B, P, cfg.d_model), act)}
        return batch

    # decode: one new token + cache of seq_len
    return {"token": _sds((B,), jnp.int32)}


def cache_specs(cfg: ModelConfig, cell: ShapeCell, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree of the (filled) cache a decode/prefill cell uses."""
    B, S = cell.global_batch, cell.seq_len
    src = S if cfg.family == "encdec" else None
    cache = jax.eval_shape(
        lambda: make_cache(cfg, B, S, src_len=src, dtype=dtype))
    return cache
