"""Mixture-of-Experts MLP with capacity-bounded, shape-static dispatch.

Token routing uses the group-local take/scatter-add formulation
(GShard-style but gather-based, no (T,E,C) one-hot einsum): tokens are
split into `moe_groups` groups (the launcher sets groups == DP shards so
all dispatch math is shard-local); within a group, top-k assignments get
positions via a cumsum over a (Tg*k, E) one-hot, assignments beyond the
expert capacity are dropped, dispatch/combine are a take and a scatter-add.
Expert FFNs run as stacked einsums so the expert axis shards over `model`
(EP) — XLA inserts the all-to-all at the group<->expert boundary.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from . import modules as nn

Array = jax.Array


def _expert_weight(w, dtype):
    """Expert weights may be a stacked (Prepared)QuantizedTensor (leading E
    axis — the serving engine prepares quantized leaves at construction).

    Quantized tensors store paper layout (out, in); the expert einsums
    consume (in, out), so dequantized weights are always swapped back."""
    from repro.core.quantized import QuantizedTensor
    from repro.kernels.plan import PreparedQuantizedTensor
    if isinstance(w, (QuantizedTensor, PreparedQuantizedTensor)):
        deq = jax.vmap(lambda q: q.dequantize(dtype))(w)   # (E, out, in)
        return jnp.swapaxes(deq, 1, 2)                     # (E, in, out)
    return w.astype(dtype)


def moe_init(rng, cfg, dtype=jnp.float32):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    r = nn.split_rngs(rng, 5)
    s_in = D ** -0.5
    s_hid = F ** -0.5
    p = {
        "router": nn.dense_init(r[0], D, E, dtype=jnp.float32),
        "w_gate": jax.random.normal(r[1], (E, D, F), dtype) * s_in,
        "w_up": jax.random.normal(r[2], (E, D, F), dtype) * s_in,
        "w_down": jax.random.normal(r[3], (E, F, D), dtype) * s_hid,
    }
    if cfg.n_shared_experts > 0:
        from .layers import swiglu_init
        p["shared"] = swiglu_init(r[4], D, F * cfg.n_shared_experts, dtype)
    return p


def moe_mlp(p: Dict[str, Any], x: Array, cfg) -> Tuple[Array, Array]:
    """x (B, S, D) -> (y, aux_loss). Routing is per token, top_k experts."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = cfg.moe_groups
    T = B * S
    assert T % G == 0, (T, G)
    Tg = T // G
    cap = int(-(-Tg * K // E) * cfg.capacity_factor)
    cap = max(cap, 1)

    xf = x.reshape(G, Tg, D)

    logits = nn.dense(p["router"], xf.astype(jnp.float32), "router")  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                     # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- positions within each expert (group-local cumsum) ------------------
    flat_e = gate_idx.reshape(G, Tg * K)                  # assignment -> expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (G, Tg*K, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1              # position per expert
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < cap                                      # capacity-dropped?

    # ---- dispatch: (G, E, cap) slot -> source token ---------------------------
    tok_ids = jnp.broadcast_to(
        jnp.arange(Tg)[None, :, None], (G, Tg, K)).reshape(G, Tg * K)
    slot = flat_e * cap + pos                             # (G, Tg*K)
    slot = jnp.where(keep, slot, E * cap)                 # overflow -> sentinel
    d_tok = jnp.full((G, E * cap + 1), Tg, jnp.int32)     # sentinel token id Tg
    d_tok = jax.vmap(lambda d, s, t: d.at[s].set(t))(d_tok, slot, tok_ids)
    d_tok = d_tok[:, : E * cap]                           # (G, E*cap)

    x_pad = jnp.concatenate([xf, jnp.zeros((G, 1, D), xf.dtype)], axis=1)
    dispatched = jnp.take_along_axis(
        x_pad, d_tok[..., None], axis=1).reshape(G, E, cap, D)

    # ---- expert FFN (E shards over `model`) ------------------------------------
    # Two activation-sharding regimes (DESIGN.md §5):
    #  * training / prefill (many tokens): tokens sharded over dp, expert
    #    hidden replicated — the all-gather of activations amortizes;
    #  * decode (few tokens): WEIGHT-STATIONARY — expert hidden F sharded
    #    over dp to match the serve-mode weight sharding, so no expert
    #    weight is ever gathered (57 GB/step/device for deepseek-v2).
    nn.record_expert_inputs("expert_in", dispatched)
    decode_like = x.shape[1] == 1
    if decode_like:
        dispatched = dctx.constrain(dispatched, None, "model", None, None)
    else:
        dispatched = dctx.constrain(dispatched, "dp", "model", None, None)
    w_gate = _expert_weight(p["w_gate"], x.dtype)
    w_up = _expert_weight(p["w_up"], x.dtype)
    h_g = jnp.einsum("gecd,edf->gecf", dispatched, w_gate)
    h_u = jnp.einsum("gecd,edf->gecf", dispatched, w_up)
    h = jax.nn.silu(h_g) * h_u
    h = (dctx.constrain(h, None, "model", None, "dp") if decode_like
         else dctx.constrain(h, "dp", "model", None, None))
    nn.record_expert_inputs("expert_mid", h)
    out = jnp.einsum("gecf,efd->gecd", h,
                     _expert_weight(p["w_down"], x.dtype))
    out = (dctx.constrain(out, None, "model", None, None) if decode_like
           else dctx.constrain(out, "dp", "model", None, None))

    # ---- combine: scatter-add back to tokens, weighted by gates -----------------
    gates_flat = jnp.where(keep, gate_vals.reshape(G, Tg * K), 0.0)
    out_flat = out.reshape(G, E * cap, D)
    src = jnp.take_along_axis(
        out_flat, jnp.minimum(slot, E * cap - 1)[..., None], axis=1)
    src = src * gates_flat[..., None].astype(out.dtype)
    src = jnp.where(keep[..., None], src, 0.0)
    y = jax.vmap(lambda acc, t, s: acc.at[t].add(s))(
        jnp.zeros((G, Tg, D), out.dtype), tok_ids, src)
    y = y.reshape(B, S, D)

    # ---- shared experts + aux loss ------------------------------------------------
    if "shared" in p:
        from .layers import swiglu_mlp
        with nn.scope("shared"):
            y = y + swiglu_mlp(p["shared"], x)

    # load-balance loss (Switch): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    return y.astype(x.dtype), aux
