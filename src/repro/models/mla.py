"""Multi-head Latent Attention (DeepSeek-V2) with compressed-KV cache.

Train/prefill: full expansion (q via low-rank down/up, k/v expanded from the
shared 512-d latent).  Decode: the *absorbed* form — scores are taken
directly against the latent cache (c_kv, k_pe), so the per-token cache cost
is kv_lora + rope_head_dim (576 floats for the 236B config) instead of
2*H*head_dim (32768): the paper-exact MLA memory win.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from . import modules as nn
from .layers import (NEG_INF, apply_rope, blocked_attention, paged_write_ids,
                     pool_view, pool_write, rope_angles)

Array = jax.Array


class MLACache(NamedTuple):
    c_kv: Array     # (B, S_max, kv_lora)
    k_pe: Array     # (B, S_max, rope_head_dim)
    length: Array


def init_mla_cache(batch: int, max_len: int, cfg, dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        k_pe=jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


class PagedMLACache(NamedTuple):
    """Paged compressed-KV cache: same pool/table/scratch contract as
    `layers.PagedKVCache`, with rank-3 pools for the latent strips."""
    cp: Array                       # (n_pages+1, page_size, kv_lora)
    pp: Array                       # (n_pages+1, page_size, rope_head_dim)
    c_scale: Optional[Array]        # (n_pages+1, page_size) f32 iff int8
    p_scale: Optional[Array]
    table: Array                    # (B, max_pages) int32
    length: Array                   # (B,) int32


def init_paged_mla_cache(batch: int, max_len: int, cfg, *, page_size: int,
                         n_pages: int, dtype=jnp.bfloat16,
                         kv_dtype=None) -> PagedMLACache:
    if max_len % page_size:
        raise ValueError(
            f"page_size {page_size} must divide max_len {max_len}")
    pool_dtype = jnp.int8 if kv_dtype == "int8" else dtype
    scale = (jnp.zeros((n_pages + 1, page_size), jnp.float32)
             if kv_dtype == "int8" else None)
    return PagedMLACache(
        cp=jnp.zeros((n_pages + 1, page_size, cfg.kv_lora), pool_dtype),
        pp=jnp.zeros((n_pages + 1, page_size, cfg.rope_head_dim), pool_dtype),
        c_scale=scale,
        p_scale=scale,
        table=jnp.full((batch, max_len // page_size), n_pages, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def mla_init(rng, cfg, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = nn.split_rngs(rng, 6)
    return {
        "q_down": nn.dense_init(r[0], D, cfg.q_lora, dtype=dtype),
        "q_norm": nn.rms_norm_init(cfg.q_lora, dtype),
        "q_up": nn.dense_init(r[1], cfg.q_lora, H * (dn + dr), dtype=dtype),
        "kv_down": nn.dense_init(r[2], D, cfg.kv_lora + dr, dtype=dtype),
        "kv_norm": nn.rms_norm_init(cfg.kv_lora, dtype),
        "kv_up": nn.dense_init(r[3], cfg.kv_lora, H * (dn + dv), dtype=dtype),
        "o": nn.dense_init(r[4], H * dv, D, dtype=dtype),
    }


def _project_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    cq = nn.rms_norm(p["q_norm"], nn.dense(p["q_down"], x, "q_down"), cfg.norm_eps)
    q = nn.dense(p["q_up"], cq, "q_up").reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    return q_nope, q_pe


def _latent_kv(p, x, cfg, positions):
    ckv_full = nn.dense(p["kv_down"], x, "kv_down")
    c_kv = nn.rms_norm(p["kv_norm"], ckv_full[..., : cfg.kv_lora], cfg.norm_eps)
    k_pe = ckv_full[..., cfg.kv_lora:]
    cos, sin = rope_angles(positions, cfg.rope_head_dim, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_pe


def mla_attention(
    p: Dict[str, Any],
    x: Array,
    cfg,
    cache: Optional[MLACache] = None,
    positions: Optional[Array] = None,
    span: bool = False,
) -> Tuple[Array, Optional[MLACache]]:
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim

    if positions is None:
        if cache is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        else:
            positions = cache.length[:, None] + jnp.arange(S)[None, :]

    q_nope, q_pe = _project_q(p, x, cfg, positions)
    c_kv, k_pe = _latent_kv(p, x, cfg, positions)

    if cache is not None:
        new_len = cache.length + S
        if isinstance(cache, PagedMLACache):
            # Paged absorbed decode / span-verify: append latents through
            # the page table, gather the contiguous-equivalent view, run
            # the SAME absorbed attention as the contiguous branches.
            if S > 1 and not span:
                raise NotImplementedError(
                    "paged caches take no chunked prefill: the engine "
                    "prefills contiguous fragments and page-inserts them")
            ps = cache.cp.shape[1]
            pid, off = paged_write_ids(cache.table, cache.length, S, ps,
                                       cache.cp.shape[0] - 1)
            cp, c_scale = pool_write(cache.cp, cache.c_scale, pid, off, c_kv)
            pp, p_scale = pool_write(cache.pp, cache.p_scale, pid, off, k_pe)
            c_all = pool_view(cp, c_scale, cache.table, x.dtype)
            pe_all = pool_view(pp, p_scale, cache.table, x.dtype)
            new_cache = PagedMLACache(cp, pp, c_scale, p_scale,
                                      cache.table, new_len)
            if S == 1:
                out = _absorbed_decode(p, q_nope, q_pe, c_all, pe_all,
                                       new_len, cfg)
            else:
                out = _absorbed_span(p, q_nope, q_pe, c_all, pe_all,
                                     cache.length, cfg)
            return nn.dense(p["o"], out.reshape(B, S, H * dv), "o"), new_cache
        if S == 1:
            brange = jnp.arange(B)
            idx = cache.length
            c_all = cache.c_kv.at[brange, idx].set(
                c_kv[:, 0].astype(cache.c_kv.dtype))
            pe_all = cache.k_pe.at[brange, idx].set(
                k_pe[:, 0].astype(cache.k_pe.dtype))
            new_cache = MLACache(c_all, pe_all, new_len)
            out = _absorbed_decode(p, q_nope, q_pe, c_all, pe_all, new_len, cfg)
            return nn.dense(p["o"], out.reshape(B, S, H * dv), "o"), new_cache
        if span:
            # speculative verify: S latents appended at PER-SLOT fill
            # levels (mode="drop" past the cache end, like layers.py), then
            # the absorbed decode generalized over the span axis — bitwise
            # the computation of S successive absorbed decode steps.
            brange = jnp.arange(B)
            idx = cache.length[:, None] + jnp.arange(S)[None, :]
            c_all = cache.c_kv.at[brange[:, None], idx].set(
                c_kv.astype(cache.c_kv.dtype), mode="drop")
            pe_all = cache.k_pe.at[brange[:, None], idx].set(
                k_pe.astype(cache.k_pe.dtype), mode="drop")
            new_cache = MLACache(c_all, pe_all, new_len)
            out = _absorbed_span(p, q_nope, q_pe, c_all, pe_all,
                                 cache.length, cfg)
            return nn.dense(p["o"], out.reshape(B, S, H * dv), "o"), new_cache
        start = cache.length[0]
        c_all = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, start, 0))
        pe_all = jax.lax.dynamic_update_slice(
            cache.k_pe, k_pe.astype(cache.k_pe.dtype), (0, start, 0))
        new_cache = MLACache(c_all, pe_all, new_len)
        c_kv, k_pe, kv_len, q_off = c_all, pe_all, new_len, start
    else:
        new_cache, kv_len, q_off = None, None, 0

    # ---- expanded path (train / prefill) ------------------------------------
    Skv = c_kv.shape[1]
    kv = nn.dense(p["kv_up"], c_kv.astype(x.dtype), "kv_up").reshape(
        B, Skv, H, dn + dv)
    kv = dctx.constrain(kv, "dp", None, "model", None)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :].astype(x.dtype),
                                  (B, Skv, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    q = dctx.constrain(q, "dp", None, "model", None)
    k = dctx.constrain(k, "dp", None, "model", None)
    v = dctx.constrain(v, "dp", None, "model", None)
    out = blocked_attention(q, k, v, causal=True, q_offset=q_off,
                            kv_len=kv_len, q_block=cfg.q_block,
                            kv_block=cfg.kv_block,
                            block_spec=("dp", "model", None, None, None))
    return nn.dense(p["o"], out.reshape(B, S, H * dv), "o"), new_cache


def _absorbed_decode(p, q_nope, q_pe, c_all, pe_all, kv_len, cfg):
    """Decode against the latent cache without expanding K/V.

    score(s) = (W_uk^T q_nope) . c_s + q_pe . k_pe_s
    out      = W_uv^T-weighted latent context.
    """
    B, _, H, dn = q_nope.shape
    dv = cfg.v_head_dim
    kv_up = nn.materialize_kernel(p["kv_up"])        # (kv_lora, H*(dn+dv))
    kv_up = kv_up.reshape(cfg.kv_lora, H, dn + dv)
    w_uk, w_uv = kv_up[..., :dn], kv_up[..., dn:]

    scale = (dn + cfg.rope_head_dim) ** -0.5
    qf = q_nope[:, 0]
    q_abs = jnp.einsum("bhd,lhd->bhl", qf, w_uk.astype(qf.dtype),
                       preferred_element_type=jnp.float32)
    s = jnp.einsum("bhl,bsl->bhs", q_abs.astype(c_all.dtype), c_all,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", q_pe[:, 0].astype(pe_all.dtype),
                       pe_all, preferred_element_type=jnp.float32)
    s = s * scale
    lim = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
    mask = jnp.arange(c_all.shape[1])[None, None, :] < lim[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", prob.astype(c_all.dtype), c_all,
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bhl,lhv->bhv", ctx.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(q_nope.dtype)  # (B,1,H,dv)


def _absorbed_span(p, q_nope, q_pe, c_all, pe_all, base_len, cfg):
    """`_absorbed_decode` generalized over a span axis: q (B,S,H,·), row s
    of slot b attends latents at positions < base_len[b] + s + 1.  Every
    einsum mirrors the decode contraction per output element (same order,
    same casts), so an S-token verify is bitwise S absorbed decodes."""
    B, S, H, dn = q_nope.shape
    dv = cfg.v_head_dim
    kv_up = nn.materialize_kernel(p["kv_up"])        # (kv_lora, H*(dn+dv))
    kv_up = kv_up.reshape(cfg.kv_lora, H, dn + dv)
    w_uk, w_uv = kv_up[..., :dn], kv_up[..., dn:]

    scale = (dn + cfg.rope_head_dim) ** -0.5
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk.astype(q_nope.dtype),
                       preferred_element_type=jnp.float32)
    s = jnp.einsum("bqhl,bsl->bqhs", q_abs.astype(c_all.dtype), c_all,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bqhr,bsr->bqhs", q_pe.astype(pe_all.dtype),
                       pe_all, preferred_element_type=jnp.float32)
    s = s * scale
    lim = jnp.asarray(base_len)[:, None] + jnp.arange(S)[None, :] + 1  # (B,S)
    mask = (jnp.arange(c_all.shape[1])[None, None, None, :]
            < lim[:, :, None, None])
    s = jnp.where(mask, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bqhs,bsl->bqhl", prob.astype(c_all.dtype), c_all,
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    return out.astype(q_nope.dtype)  # (B,S,H,dv)
