"""Mamba2 block (SSD — state-space duality) with chunked parallel scan.

Train/prefill use the chunked SSD form (intra-chunk quadratic attention-like
term + inter-chunk state recurrence over chunks); decode is the O(1)
recurrent update.  Both paths are validated against each other in tests.

Shapes: d_inner = expand*d_model, heads H = d_inner/headdim P, state N.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from . import modules as nn

Array = jax.Array


class MambaCache(NamedTuple):
    conv: Array    # (B, conv_w-1, conv_dim) — last inputs of the causal conv
    ssm: Array     # (B, H, P, N) state
    length: Array


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, H, conv_dim


def init_mamba_cache(batch: int, cfg, dtype=jnp.bfloat16) -> MambaCache:
    d_inner, H, conv_dim = mamba_dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, H, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def mamba_init(rng, cfg, dtype=jnp.float32):
    D = cfg.d_model
    d_inner, H, conv_dim = mamba_dims(cfg)
    r = nn.split_rngs(rng, 4)
    return {
        "in_proj": nn.dense_init(
            r[0], D, 2 * d_inner + 2 * cfg.ssm_state + H, dtype=dtype),
        "conv_w": jax.random.normal(r[1], (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": nn.rms_norm_init(d_inner, dtype),
        "out_proj": nn.dense_init(r[2], d_inner, D, dtype=dtype),
    }


def _split_in_proj(p, x, cfg):
    d_inner, H, conv_dim = mamba_dims(cfg)
    zxbcdt = nn.dense(p["in_proj"], x, "in_proj")
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def _conv_apply(p, seq):
    """Causal depthwise conv along time. seq (B, T, C) already left-padded."""
    w = p["conv_w"].astype(seq.dtype)      # (K, C)
    K = w.shape[0]
    out = sum(seq[:, i: seq.shape[1] - (K - 1) + i] * w[i][None, None, :]
              for i in range(K))
    return out + p["conv_b"].astype(seq.dtype)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD. x (B,T,H,P); dt (B,T,H); A (H,); Bm/Cm (B,T,N).

    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    Recurrence: S_t = exp(dt_t A_h) S_{t-1} + dt_t x_t B_t^T ;  y_t = S_t C_t.
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                  # (B,nc,l,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                       # inclusive
    total = cum[:, :, -1:, :]                          # (B,nc,1,H)

    # intra-chunk: y_t += sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s
    # (mask BEFORE exp: masked entries have ratio > 0 and would overflow,
    # poisoning the cotangent of `where` with 0*inf = NaN)
    ratio = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    ratio = jnp.where(tri[None, None, :, :, None], ratio, -1e30)
    decay = jnp.exp(ratio)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc,
                    preferred_element_type=jnp.float32)
    w_ts = (cb[..., None] * decay * dtc[:, :, None, :, :]).astype(x.dtype)
    y = jnp.einsum("bctsh,bcshp->bcthp", w_ts, xc,
                   preferred_element_type=jnp.float32)

    # chunk -> state contribution: sum_s exp(total - cum_s) dt_s B_s x_s^T
    sdecay = (jnp.exp(total - cum) * dtc).astype(x.dtype)  # (B,nc,l,H)
    chunk_state = jnp.einsum("bcsh,bcsn,bcshp->bchpn",
                             sdecay, Bc, xc,
                             preferred_element_type=jnp.float32)

    # inter-chunk recurrence over c
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def carry_fn(S, inputs):
        cs, tot = inputs                                # (B,H,P,N), (B,H)
        S_out = S                                       # state entering chunk
        S = S * jnp.exp(tot)[:, :, None, None] + cs
        return S, S_out

    tot_c = jnp.moveaxis(total[:, :, 0, :], 1, 0)       # (nc,B,H)
    cs_c = jnp.moveaxis(chunk_state, 1, 0)              # (nc,B,H,P,N)
    final, S_in = jax.lax.scan(carry_fn, init_state, (cs_c, tot_c))
    S_in = jnp.moveaxis(S_in, 0, 1)                     # (B,nc,H,P,N)

    # carried-state term: y_t += exp(cum_t) C_t . S_in
    y = y + jnp.einsum("bclh,bcln,bchpn->bclhp",
                       jnp.exp(cum).astype(x.dtype), Cc,
                       S_in.astype(x.dtype),
                       preferred_element_type=jnp.float32)

    y = y.reshape(Bsz, Tp, H, P)[:, :T]
    return y, final


def mamba_block(
    p: Dict[str, Any],
    x: Array,                      # (B, T, D)
    cfg,
    cache: Optional[MambaCache] = None,
) -> Tuple[Array, Optional[MambaCache]]:
    B, T, D = x.shape
    d_inner, H, conv_dim = mamba_dims(cfg)
    P, N = cfg.ssm_headdim, cfg.ssm_state

    z, xbc, dt_raw = _split_in_proj(p, x, cfg)
    # channel-shard the conv/SSD activation stream over the TP axis
    # (zamba params are FSDP-only, so without this every device holds the
    # full (B,T,conv_dim) stream — 16x redundant HBM traffic).  The conv is
    # depthwise, so each segment (x | B | C) convolves independently — that
    # keeps every sharded tensor's slice boundaries aligned (no resharding
    # collectives from slicing across shards).
    z = dctx.constrain(z, "dp", None, "model")

    if cache is not None:
        left = cache.conv.astype(xbc.dtype)
    else:
        left = jnp.zeros((B, cfg.ssm_conv - 1, conv_dim), xbc.dtype)
    seq = jnp.concatenate([left, xbc], axis=1)
    new_conv = seq[:, -(cfg.ssm_conv - 1):] if cfg.ssm_conv > 1 else left

    def conv_seg(lo, hi):
        sub = {"conv_w": p["conv_w"][:, lo:hi], "conv_b": p["conv_b"][lo:hi]}
        part = dctx.constrain(seq[..., lo:hi], "dp", None, "model")
        return jax.nn.silu(_conv_apply(sub, part))

    xs = conv_seg(0, d_inner).reshape(B, T, H, P)
    xs = dctx.constrain(xs, "dp", None, "model", None)
    Bm = conv_seg(d_inner, d_inner + N)
    Cm = conv_seg(d_inner + N, conv_dim)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["a_log"])

    if cache is not None and T == 1:
        # O(1) recurrent decode step
        S = cache.ssm
        dA = jnp.exp(dt[:, 0] * A[None, :])            # (B,H)
        dx = dt[:, 0, :, None] * xs[:, 0].astype(jnp.float32)   # (B,H,P)
        S = S * dA[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", dx, Bm[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", S, Cm[:, 0].astype(jnp.float32))
        y = y[:, None]                                  # (B,1,H,P)
        final = S
    else:
        init = cache.ssm if cache is not None else None
        y, final = _ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init)

    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    y = nn.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = nn.dense(p["out_proj"], y, "out_proj")

    new_cache = None
    if cache is not None:
        new_cache = MambaCache(conv=new_conv.astype(cache.conv.dtype),
                               ssm=final, length=cache.length + T)
    return out, new_cache
