"""End-to-end system behaviour: train a small LM, calibrate, CLAQ-quantize,
and reproduce the paper's orderings (Tables 1/3/4 trend-level); quantized
serving equals quantized evaluation; heuristic AP search (App. G)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, MatrixInfo, ORConfig
from repro.core.search import heuristic_ap_search
from repro.data import DataConfig, SyntheticCorpus, calibration_set
from repro.launch.quantize import calibrate, quantize_model_params
from repro.models import api
from repro.optim import OptimConfig, init_opt_state
from repro.train import make_train_step

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def trained():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=256,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimConfig(lr=1e-2, warmup_steps=5, total_steps=80)
    opt = init_opt_state(params, ocfg)
    data = SyntheticCorpus(DataConfig(vocab=256, seq_len=64, batch=8, seed=0))
    step = jax.jit(make_train_step(cfg, ocfg))
    for s in range(60):
        params, opt, _ = step(params, opt, {"tokens": data.batch_at(s)})
    calib = calibration_set(vocab=256, n_segments=8, seq_len=64)
    hess = calibrate(params, cfg, calib, batch_size=4)
    eval_batch = {"tokens": data.batch_at(1000)}
    return cfg, params, hess, eval_batch


def _ppl(cfg, params, batch):
    _, met = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(params, batch)
    return float(jnp.exp(met["nll"]))


def test_tap_names_cover_all_block_matrices(trained):
    cfg, params, hess, _ = trained
    for i in range(cfg.n_layers):
        for name in ("attn.q", "attn.k", "attn.v", "attn.o",
                     "mlp.gate", "mlp.up", "mlp.down"):
            assert f"layers.{i}.{name}" in hess


def test_paper_orderings(trained):
    cfg, params, hess, eval_batch = trained
    ppl_fp = _ppl(cfg, params, eval_batch)

    def q(qcfg):
        qp, rep = quantize_model_params(params, cfg, hess, qcfg)
        return _ppl(cfg, qp, eval_batch), rep

    ppl_claq3, _ = q(CLAQConfig(bits=3, method="kmeans", kmeans_iters=6,
                                gptq_blocksize=32))
    ppl_gptq3, _ = q(CLAQConfig(bits=3, method="uniform", gptq_blocksize=32))
    ppl_claq2, rep2 = q(CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                                   gptq_blocksize=32))
    ppl_fusion, rep = q(CLAQConfig(bits=2, method="kmeans", kmeans_iters=6,
                                   gptq_blocksize=32,
                                   ap=APConfig(2.2, 2, 4),
                                   orr=ORConfig(0.1)))
    # Table 1 trend: fp <= CLAQ <= GPTQ at 3-bit
    assert ppl_fp <= ppl_claq3 * 1.001
    assert ppl_claq3 <= ppl_gptq3 * 1.05
    # Fusion beats pure 2-bit (Tables 3/4 trend) on the quantization
    # objective.  At this toy scale the single-batch eval ppl difference
    # between 2.0 and 2.26 effective bits is noise-dominated (the proxy
    # improves ~15-20% while ppl moves <1% either way), so the trend is
    # asserted on the objective and ppl only guards a no-regression band.
    assert rep.total_proxy_loss < rep2.total_proxy_loss
    assert ppl_fusion < ppl_claq2 * 1.01
    assert 2.0 < rep.mean_effective_bits < 2.6


def test_quantized_serving_matches_quantized_eval(trained):
    cfg, params, hess, _ = trained
    qp, _ = quantize_model_params(
        params, cfg, hess, CLAQConfig(bits=4, method="kmeans",
                                      kmeans_iters=5, gptq_blocksize=32))
    toks = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    from repro.models import transformer as tf
    full_logits, _, _ = tf.forward(qp, cfg, toks)
    cache = api.make_cache(cfg, 1, 32, dtype=jnp.float32)
    logits_p, cache = api.prefill_step(qp, cfg, {"tokens": toks[:, :6]}, cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, 5]),
                               rtol=5e-2, atol=5e-2)
    logits_d, cache = api.decode_step(qp, cfg, toks[:, 6], cache)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full_logits[:, 6]),
                               rtol=5e-2, atol=5e-2)


def test_moe_expert_quantization(trained):
    """MoE experts (3-D stacked weights) quantize with per-expert Hessians."""
    cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                              vocab=128, n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    calib = calibration_set(vocab=128, n_segments=4, seq_len=32)
    hess = calibrate(params, cfg, calib, batch_size=2)
    assert any("expert_in_0" in k for k in hess)
    qp, rep = quantize_model_params(
        params, cfg, hess, CLAQConfig(bits=4, method="kmeans",
                                      kmeans_iters=4, gptq_blocksize=32))
    from repro.core.quantized import QuantizedTensor
    leaves = jax.tree_util.tree_leaves(
        qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    assert any(isinstance(l, QuantizedTensor) for l in leaves)
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(
        0, 128, size=(2, 32)), jnp.int32)}
    loss_q, _ = api.loss_fn(qp, cfg, batch)
    loss_fp, _ = api.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss_q)
    assert float(loss_q) < float(loss_fp) + 1.0


def test_heuristic_ap_search_budget():
    rng = np.random.default_rng(0)
    mats = [MatrixInfo(f"m{i}", 128, 128, float(r))
            for i, r in enumerate(rng.random(24))]
    res = heuristic_ap_search(mats, target_bits=2.5)
    assert res.avg_bits <= 2.5 + 1e-9
    assert res.score > 0
    # higher-outlier matrices get the higher-precision mixes
    by_or = sorted(mats, key=lambda m: -m.outlier_ratio)
    top_pair = res.assignment[by_or[0].name][0]
    bottom_pair = res.assignment[by_or[-1].name][0]
    assert top_pair[1] >= bottom_pair[1]
