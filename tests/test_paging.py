"""Page allocator / prefix registry pure units — no JAX, no engine.

The allocator is the single host-side authority over physical pages
(serve/paging.py); these tests pin its contract: all-or-nothing
allocation with typed ``PoolExhausted`` backpressure, refcounted
sharing, FIFO free-list reuse (determinism is load-bearing for the
paged-vs-contiguous parity suite), copy-on-write semantics, and the
prefix registry's whole-page sharing rules.
"""
import pytest

from repro.serve.paging import PageAllocator, PoolExhausted, PrefixRegistry
from repro.serve import AdmissionRejected


# ----------------------------------------------------------------- allocator

def test_alloc_free_roundtrip():
    a = PageAllocator(n_pages=4, page_size=8)
    assert a.scratch == 4 and a.n_free == 4 and a.pages_in_use == 0
    pages = a.alloc(3)
    assert pages == [0, 1, 2]
    assert a.pages_in_use == 3 and a.n_free == 1
    assert all(a.refcount(p) == 1 for p in pages)
    a.free(pages)
    assert a.pages_in_use == 0 and all(a.refcount(p) == 0 for p in pages)


def test_alloc_all_or_nothing_raises_typed_backpressure():
    a = PageAllocator(n_pages=3, page_size=8)
    a.alloc(2)
    with pytest.raises(PoolExhausted):
        a.alloc(2)
    # failed alloc must leave the pool untouched
    assert a.n_free == 1
    assert a.alloc(1) == [2]
    # PoolExhausted IS an AdmissionRejected: pool pressure rides the
    # engine's existing backpressure path unchanged
    assert issubclass(PoolExhausted, AdmissionRejected)


def test_free_list_reuse_is_fifo_deterministic():
    a = PageAllocator(n_pages=4, page_size=8)
    first = a.alloc(4)
    a.free([first[1], first[3]])       # free 1 then 3
    a.free([first[0]])                 # then 0
    # FIFO: reuse order is exactly the order pages were freed
    assert a.alloc(3) == [1, 3, 0]

    # identical admit/retire/admit cycles reproduce identical page ids
    b1, b2 = PageAllocator(8, 4), PageAllocator(8, 4)
    for b in (b1, b2):
        x = b.alloc(3)
        b.free(x[::-1])
        b.alloc(2)
    assert b1._free == b2._free and b1._refs == b2._refs


def test_refcount_shared_pages_survive_partial_release():
    a = PageAllocator(n_pages=2, page_size=8)
    (p,) = a.alloc(1)
    a.retain([p])
    a.retain([p])
    assert a.refcount(p) == 3
    a.free([p])
    a.free([p])
    assert a.refcount(p) == 1 and a.pages_in_use == 1
    a.free([p])
    assert a.refcount(p) == 0 and a.n_free == 2


def test_refcount_misuse_raises():
    a = PageAllocator(n_pages=2, page_size=8)
    with pytest.raises(ValueError):
        a.retain([0])                  # never allocated
    with pytest.raises(ValueError):
        a.free([1])
    (p,) = a.alloc(1)
    a.free([p])
    with pytest.raises(ValueError):
        a.free([p])                    # double free


def test_writable_cow_semantics():
    a = PageAllocator(n_pages=3, page_size=8)
    (p,) = a.alloc(1)
    # sole holder: write in place, nothing allocated
    page, fresh = a.writable(p)
    assert page == p and fresh is False and a.pages_in_use == 1
    # shared: fresh page, one reference dropped from the shared one
    a.retain([p])
    page, fresh = a.writable(p)
    assert fresh is True and page != p
    assert a.refcount(p) == 1 and a.refcount(page) == 1
    with pytest.raises(ValueError):
        a.writable(99)


# ------------------------------------------------------------ prefix registry

def test_registry_exact_match_shares_all_pages():
    a = PageAllocator(n_pages=8, page_size=4)
    reg = PrefixRegistry(a)
    prompt = list(range(10))           # 10 tokens -> 3 pages
    pages = a.alloc(3)
    assert reg.register(prompt, pages) is True
    assert all(a.refcount(p) == 2 for p in pages)   # holder + registry
    shared, got = reg.lookup(prompt)
    assert shared == 10 and got == pages
    # exact_ok=False: whole pages only, even on an exact match
    shared, got = reg.lookup(prompt, exact_ok=False)
    assert shared == 8 and got == pages[:2]


def test_registry_lcp_rounds_down_to_whole_pages():
    a = PageAllocator(n_pages=8, page_size=4)
    reg = PrefixRegistry(a)
    donor = list(range(10))
    pages = a.alloc(3)
    reg.register(donor, pages)
    # diverges at token 9: LCP 9 -> 2 whole pages (8 tokens)
    shared, got = reg.lookup(donor[:9] + [99, 100])
    assert shared == 8 and got == pages[:2]
    # diverges inside the first page: nothing shareable
    shared, got = reg.lookup([99] + donor[1:])
    assert shared == 0 and got == []


def test_registry_skips_short_and_duplicate_prompts():
    a = PageAllocator(n_pages=8, page_size=4)
    reg = PrefixRegistry(a)
    (p,) = a.alloc(1)
    assert reg.register([1, 2, 3], [p]) is False    # < one page
    assert a.refcount(p) == 1                       # no ref taken
    pages = a.alloc(2)
    assert reg.register([1, 2, 3, 4, 5], pages) is True
    assert reg.register([1, 2, 3, 4, 5], pages) is False
    assert all(a.refcount(q) == 2 for q in pages)   # retained ONCE


def test_registry_eviction_releases_only_unpinned_pages():
    a = PageAllocator(n_pages=4, page_size=4)
    reg = PrefixRegistry(a)
    pages = a.alloc(2)
    reg.register(list(range(8)), pages)
    a.free(pages)                      # the "request" retires
    assert len(reg) == 1 and a.pages_in_use == 2    # registry still pins
    assert reg.evict_one() is True
    assert a.pages_in_use == 0                      # now reclaimed
    assert reg.evict_one() is False                 # empty

    # a page still pinned by a live holder survives its entry's eviction
    pages = a.alloc(2)
    reg.register(list(range(100, 108)), pages)
    reg.evict_one()
    assert all(a.refcount(p) == 1 for p in pages)   # holder's ref intact
