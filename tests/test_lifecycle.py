"""Request lifecycle: state machine, bounded admission, deadlines,
preemption/resume parity, numeric-guard quarantine, fault-plan replay.

The robustness contract under test (DESIGN.md §10): every request ends in
a terminal state; backpressure and SLO misses are TYPED outcomes, not
bugs; preempted requests resume with bitwise-identical tokens (resume =
bucketed prefill of the original prompt + teacher-forced decode replay of
the generated prefix, NOT a prompt+prefix prefill — online-softmax
prefill is only ≈-equal to decode); guards quarantine exactly the
offending batch row; and a seeded fault plan replays exactly.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.serve import (AdmissionQueue, AdmissionRejected, DeadlineExceeded,
                         EngineFault, FaultInjector, IncompleteRun, Request,
                         RequestState, RetryPolicy, ServingEngine, StepClock,
                         TERMINAL_STATES)
from repro.serve.lifecycle import transition

jax.config.update("jax_platform_name", "cpu")

PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9]]


@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=64,
                              n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(fp_model, **kw):
    cfg, params = fp_model
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("min_bucket", 8)
    return ServingEngine(params, cfg, **kw)


def _vanilla_tokens(fp_model, prompts, max_new, **kw):
    eng = _engine(fp_model, **kw)
    uids = eng.add_requests(prompts, max_new_tokens=max_new)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]


# ------------------------------------------------------------------- units

def test_state_machine_enforced():
    req = Request(0, [1], 4)
    assert req.state is RequestState.QUEUED and not req.done
    transition(req, RequestState.RUNNING)
    transition(req, RequestState.PREEMPTED)
    transition(req, RequestState.QUEUED)
    transition(req, RequestState.RUNNING)
    transition(req, RequestState.FINISHED)
    assert req.done and not req.truncated
    # terminal states are absorbing; skipping RUNNING is illegal
    with pytest.raises(ValueError, match="illegal lifecycle transition"):
        transition(req, RequestState.QUEUED)
    fresh = Request(1, [1], 4)
    with pytest.raises(ValueError, match="illegal"):
        transition(fresh, RequestState.PREEMPTED)
    assert all(s in TERMINAL_STATES
               for s in (RequestState.FINISHED, RequestState.TRUNCATED,
                         RequestState.ABANDONED, RequestState.FAILED))
    assert RequestState.PREEMPTED not in TERMINAL_STATES


def test_admission_queue_bound_priority_and_expiry():
    q = AdmissionQueue(2)
    a = Request(0, [1], 4, priority=0)
    b = Request(1, [1], 4, priority=5)
    q.push(a)
    q.push(b)
    with pytest.raises(AdmissionRejected, match="queue full"):
        q.push(Request(2, [1], 4))
    assert len(q) == 2 and q.uids() == [1, 0]     # priority first
    # preempted work re-queues at the FRONT, exempt from the bound
    c = Request(3, [1], 4, priority=5)
    q.push_front(c)
    assert q.peek_best().uid == 3
    assert q.pop_best().uid == 3 and len(q) == 2
    # admissibility filter skips rows without dropping them
    assert q.pop_best(lambda r: r.priority == 0).uid == 0
    assert q.uids() == [1]
    # deadline expiry removes and returns the expired rows
    b.deadline = 1.0
    assert [r.uid for r in q.expire(2.0)] == [1]
    assert len(q) == 0 and q.pop_best() is None
    # peak depth is a high-water mark: the push_front burst set it to 3
    # and draining does not reset it
    assert q.peak_depth == 3


def test_tokens_out_and_queue_peak_depth(fp_model):
    """`tokens_out` on retired requests makes TPOT recomputable post-hoc
    (telemetry report satellite); queue_peak_depth surfaces in stats()."""
    eng = _engine(fp_model, queue_depth=4)
    uids = [eng.submit(p, max_new_tokens=4)
            for p in ([1, 2, 3], [4, 5, 6], [7, 8], [9, 10, 11])]
    eng.run_to_completion()
    fin = eng.take_finished()
    for u in uids:
        assert fin[u].tokens_out == len(fin[u].tokens) > 0
    assert eng.queue.peak_depth >= 2       # 4 requests over 2 slots queued
    assert eng.stats()["queue_peak_depth"] == eng.queue.peak_depth


def test_retry_policy_bounds_transient_faults():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise EngineFault("flaky", transient=True)
        return "ok"

    pol = RetryPolicy(max_attempts=3, backoff_s=0.0, sleep=lambda s: None)
    out, retries = pol.run(flaky)
    assert out == "ok" and retries == 2
    # non-transient faults pass straight through
    with pytest.raises(EngineFault, match="hard"):
        pol.run(lambda: (_ for _ in ()).throw(EngineFault("hard")))
    # exhausted budget re-raises the transient fault
    with pytest.raises(EngineFault, match="always"):
        pol.run(lambda: (_ for _ in ()).throw(
            EngineFault("always", transient=True)))


def test_fault_injector_plan_is_deterministic():
    a, b = FaultInjector(seed=7), FaultInjector(seed=7)
    assert a.describe() == b.describe()
    assert a.logit_faults == b.logit_faults
    assert a.pressure_spans == b.pressure_spans
    assert a.fail_steps == b.fail_steps
    assert a.arrival_counts == b.arrival_counts
    assert FaultInjector(seed=8).describe() != a.describe()
    # attempt counters are the only mutable state; reset() rewinds them
    step = next(iter(a.fail_steps))
    seq = [a.should_fail_step(step) for _ in range(4)]
    a.reset()
    assert [a.should_fail_step(step) for _ in range(4)] == seq
    assert seq[-1] is False        # bounded: eventually passes
    v = a.inject_vector(next(iter(a.logit_faults)), 4, occupied=[1, 2])
    assert v.shape == (4,) and not np.isfinite(v).all()
    assert np.isfinite(v[[0, 3]]).all()           # only occupied slots hit


# ---------------------------------------------------------- engine lifecycle

def test_step_with_zero_active_slots(fp_model):
    eng = _engine(fp_model)
    assert eng.step() == {}
    assert eng.step() == {}                       # repeatable, no state drift
    assert eng.engine_steps == 0                  # truly idle: no queue
    uid = eng.submit(PROMPTS[0], max_new_tokens=2)
    eng.step()                                    # pump admits + decodes
    eng.step()
    assert eng.take_finished()[uid].state is RequestState.FINISHED


def test_typed_admission_errors(fp_model):
    eng = _engine(fp_model, queue_depth=1)
    # direct admission beyond free slots: typed, and still a ValueError
    with pytest.raises(AdmissionRejected):
        eng.add_requests([[1]] * 3, max_new_tokens=2)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(list(range(1, 31)), max_new_tokens=8)
    # queue backpressure at the bound
    eng.submit(PROMPTS[0], max_new_tokens=2)
    with pytest.raises(AdmissionRejected, match="backpressure"):
        eng.submit(PROMPTS[1], max_new_tokens=2)
    assert eng.stats()["admission_rejections"] >= 1
    # an already-blown SLO is its own outcome
    with pytest.raises(DeadlineExceeded):
        eng.submit(PROMPTS[1], max_new_tokens=2, deadline_ms=0)


def test_deadline_abandonment_queued_and_running(fp_model):
    clock = StepClock(step_ms=10.0)
    eng = _engine(fp_model, n_slots=1, clock=clock)
    # occupy the only slot, then queue a request with a tight deadline
    blocker = eng.submit([2, 3, 4], max_new_tokens=12)
    eng.step()
    queued = eng.submit(PROMPTS[0], max_new_tokens=4, deadline_ms=25)
    clock.advance(30)
    eng.step()
    fin = eng.take_finished()
    assert fin[queued].state is RequestState.ABANDONED
    assert fin[queued].diagnostics["where"] == "queued"
    assert fin[queued].tokens == []               # never ran
    assert blocker in eng.active                  # no deadline: unaffected
    # running-side abandonment keeps the partial tokens
    running = eng.submit([7, 8], max_new_tokens=10, deadline_ms=40)
    eng.step()                                    # still blocked: queued
    clock.advance(5)
    for _ in range(11):                           # blocker retires, admits
        eng.step()
    assert running in eng.active
    clock.advance(50)
    eng.step()
    fin = eng.take_finished()
    assert fin[running].state is RequestState.ABANDONED
    assert fin[running].diagnostics["where"] == "running"
    assert len(fin[running].tokens) >= 1          # partial output survives


def test_preempt_resume_token_parity(fp_model):
    base = _vanilla_tokens(fp_model, PROMPTS, max_new=8)
    eng = _engine(fp_model)
    uids = eng.add_requests(PROMPTS, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    eng.set_cache_pressure(4)                     # below both fills
    eng.step()
    st = eng.stats()
    assert st["preemptions"] == 2 and not eng.active and st["queued"] == 2
    for u in uids:
        assert u not in eng.finished              # preempted, NOT terminal
    # under sustained pressure nothing re-admits (no admission churn)
    eng.step()
    assert eng.stats()["preemptions"] == 2 and not eng.active
    eng.set_cache_pressure(None)
    eng.run_to_completion()
    fin = eng.take_finished()
    assert [fin[u].tokens for u in uids] == base  # bitwise resume
    assert all(fin[u].state is RequestState.FINISHED for u in uids)
    assert all(fin[u].preemptions == 1 for u in uids)
    st = eng.stats()
    assert st["resumes"] == 2
    assert st["lifecycle"]["finished"] == 2
    assert st["lifecycle"]["truncated"] == 0


def test_priority_preemption_and_victim_order(fp_model):
    base_low = _vanilla_tokens(fp_model, [PROMPTS[0]], max_new=8)[0]
    eng = _engine(fp_model, n_slots=1)
    low = eng.add_requests([PROMPTS[0]], max_new_tokens=8, priority=0)[0]
    eng.step()
    hi = eng.submit([9, 9, 9], max_new_tokens=6, priority=5)
    eng.step()                                    # pump: hi evicts low
    assert hi in eng.active and low not in eng.active
    assert eng.active[hi].priority == 5
    assert eng.stats()["preemptions"] == 1
    # equal priority does NOT preempt
    eq = eng.submit([4, 4], max_new_tokens=2, priority=5)
    eng.step()
    assert hi in eng.active and eq not in eng.active
    eng.run_to_completion()
    fin = eng.take_finished()
    assert fin[low].tokens == base_low            # resumed bit-identically
    assert all(fin[u].state is RequestState.FINISHED
               for u in (low, hi, eq))


def test_on_pressure_truncate_is_opt_in(fp_model):
    eng = _engine(fp_model, on_pressure="truncate")
    uids = eng.add_requests(PROMPTS, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    eng.set_cache_pressure(4)
    eng.step()
    fin = eng.take_finished()
    assert all(fin[u].state is RequestState.TRUNCATED for u in uids)
    assert all(fin[u].diagnostics["kind"] == "cache_pressure" for u in uids)
    assert eng.stats()["preemptions"] == 0
    with pytest.raises(ValueError, match="on_pressure"):
        _engine(fp_model, on_pressure="panic")


def test_incomplete_run_attaches_partials(fp_model):
    eng = _engine(fp_model)
    uids = eng.add_requests(PROMPTS, max_new_tokens=25, eos_id=None)
    with pytest.raises(IncompleteRun, match="max_steps") as ei:
        eng.run_to_completion(max_steps=3)
    err = ei.value
    assert sorted(err.partial) == sorted(uids)
    for u in uids:
        # 1 admission token + 3 decode steps, preserved on the error
        assert err.partial[u] == eng.active[u].tokens and len(
            err.partial[u]) == 4
        assert err.states[u] is RequestState.RUNNING
    assert isinstance(err, RuntimeError)          # pre-lifecycle contract
    # non-strict keeps returning the unfinished uids
    assert eng.run_to_completion(max_steps=1, strict=False) == sorted(uids)


def test_guards_quarantine_only_offending_row(fp_model):
    # a NaN injected into ONE slot's logits mid-decode must FAIL exactly
    # that request; the other row of the same batched decode finishes
    # with tokens bit-identical to a fault-free engine
    inj = FaultInjector(seed=2, horizon=8, nan_faults=1, inf_faults=0,
                        pressure_windows=0, transient_failures=0,
                        burst_every=0, arrival_lambda=0.0)
    (fault_step,) = inj.logit_faults
    base = _vanilla_tokens(fp_model, PROMPTS, max_new=10)
    eng = _engine(fp_model, guards=True, faults=inj)
    uids = eng.add_requests(PROMPTS, max_new_tokens=10)
    eng.run_to_completion()
    fin = eng.take_finished()
    states = {u: fin[u].state for u in uids}
    failed = [u for u in uids if states[u] is RequestState.FAILED]
    ok = [u for u in uids if states[u] is RequestState.FINISHED]
    assert len(failed) == 1 and len(ok) == 1
    d = fin[failed[0]].diagnostics
    assert d["kind"] == "nonfinite_logits" and d["phase"] == "decode"
    assert d["engine_step"] == fault_step and d["nonfinite"] >= 1
    # the survivor's stream is untouched by its neighbor's quarantine
    assert fin[ok[0]].tokens == base[uids.index(ok[0])]
    # the failed row kept its pre-fault prefix (partial work preserved):
    # 1 admission token + one token per decode step before the fault
    assert (fin[failed[0]].tokens
            == base[uids.index(failed[0])][:fault_step + 1])


def test_transient_faults_need_bounded_retry(fp_model):
    mk = lambda: FaultInjector(seed=3, horizon=8, nan_faults=0,
                               inf_faults=0, pressure_windows=0,
                               transient_failures=1,
                               max_consecutive_failures=2,
                               burst_every=0, arrival_lambda=0.0)
    # without a retry policy the transient fault propagates, pre-mutation
    eng = _engine(fp_model, faults=mk())
    uids = eng.add_requests(PROMPTS, max_new_tokens=10)
    with pytest.raises(EngineFault, match="transient") as ei:
        eng.run_to_completion()
    assert ei.value.transient
    before = [list(eng.active[u].tokens) for u in uids]
    # the raise happened before any state mutation: a retried driver
    # continues to the SAME tokens as a fault-free run
    eng.run_to_completion(retry=RetryPolicy(max_attempts=3, backoff_s=0.0))
    fin = eng.take_finished()
    got = [fin[u].tokens for u in uids]
    assert [t[:len(b)] for t, b in zip(got, before)] == before
    assert got == _vanilla_tokens(fp_model, PROMPTS, max_new=10)
    assert all(fin[u].state is RequestState.FINISHED for u in uids)


def test_seeded_fault_plan_replays_exactly(fp_model):
    # full fault plan (NaN + pressure + transient failures) driven twice
    # from the same seed: terminal states, tokens, and counters must be
    # bit-identical
    def run():
        inj = FaultInjector(seed=5, horizon=16, nan_faults=1, inf_faults=1,
                            pressure_windows=1, pressure_frac=(0.3, 0.4),
                            transient_failures=1, burst_every=0,
                            arrival_lambda=0.0)
        clock = StepClock()
        eng = _engine(fp_model, guards=True, faults=inj, clock=clock)
        uids = eng.add_requests(PROMPTS, max_new_tokens=10)
        retry = RetryPolicy(max_attempts=3, backoff_s=0.0)
        for _ in range(60):
            retry.run(eng.step)
            clock.advance()
            if not eng.active and not len(eng.queue):
                break
        fin = eng.take_finished()
        assert sorted(fin) == sorted(uids)        # every request terminal
        return ([(fin[u].state.value, fin[u].tokens) for u in uids],
                eng.stats()["lifecycle"], eng.stats()["preemptions"])

    assert run() == run()
