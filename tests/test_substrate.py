"""Substrate tests: data determinism, optimizer, train loop learning +
microbatch equivalence, checkpoint fault tolerance, serving engine."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticCorpus, calibration_set
from repro.models import api
from repro.optim import (OptimConfig, apply_updates, compress_int8,
                         decompress_int8, init_opt_state, schedule)
from repro.serve import ServingEngine
from repro.train import make_train_step

jax.config.update("jax_platform_name", "cpu")


# --------------------------------------------------------------------- data

def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab=977, seq_len=33, batch=6, seed=4)
    c1, c2 = SyntheticCorpus(cfg), SyntheticCorpus(cfg)
    np.testing.assert_array_equal(c1.batch_at(17), c2.batch_at(17))
    assert not np.array_equal(c1.batch_at(17), c1.batch_at(18))
    assert int(c1.batch_at(5).max()) < 977


def test_data_sharding_partitions_batch():
    cfg = DataConfig(vocab=500, seq_len=16, batch=8, seed=1)
    full = SyntheticCorpus(cfg).batch_at(3)
    parts = [SyntheticCorpus(cfg, shard=i, num_shards=4).batch_at(3)
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_data_families_differ():
    a = SyntheticCorpus(DataConfig(500, 32, 4, seed=0, name="c4like")).batch_at(0)
    b = SyntheticCorpus(DataConfig(500, 32, 4, seed=0, name="wikilike")).batch_at(0)
    assert not np.array_equal(a, b)


def test_calibration_set_matches_paper_protocol():
    c = calibration_set(vocab=1000, n_segments=16, seq_len=64)
    assert c.shape == (16, 64)


# ---------------------------------------------------------------- optimizer

def test_schedule_warmup_and_decay():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(jnp.asarray(5), cfg)) < 1.0
    assert abs(float(schedule(jnp.asarray(10), cfg)) - 1.0) < 1e-6
    assert float(schedule(jnp.asarray(100), cfg)) < 1e-3


def test_adamw_decreases_quadratic():
    cfg = OptimConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    st = init_opt_state(params, cfg)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, st, _ = apply_updates(params, g, st, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_int8_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    q, scale = compress_int8(g)
    back = decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-7


# -------------------------------------------------------------------- train

def test_training_learns_and_microbatch_consistent():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=256,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    data = SyntheticCorpus(DataConfig(vocab=256, seq_len=64, batch=8, seed=0))

    step1 = jax.jit(make_train_step(cfg, ocfg, n_microbatches=1))
    step2 = jax.jit(make_train_step(cfg, ocfg, n_microbatches=2))

    # single-step equivalence of grad accumulation (same params/opt in)
    opt = init_opt_state(params, ocfg)
    p1, _, m1 = step1(params, opt, {"tokens": data.batch_at(0)})
    p2, _, m2 = step2(params, opt, {"tokens": data.batch_at(0)})
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree_util.tree_leaves(p1),
                            jax.tree_util.tree_leaves(p2)))
    assert d < 5e-3, d

    # learning
    opt = init_opt_state(params, ocfg)
    losses = []
    for s in range(30):
        params, opt, m = step1(params, opt, {"tokens": data.batch_at(s)})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_async_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = {"a": jnp.arange(5, dtype=jnp.float32),
                 "nested": {"b": jnp.ones((3, 3), jnp.bfloat16)}}
        for s in (1, 2, 3):
            mgr.save(s, state, blocking=(s != 3))
        mgr.wait()
        assert sorted(mgr._list_steps()) == [2, 3]   # keep=2 GC
        step, restored = mgr.restore_latest(state)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(state["a"]))
        assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_corrupt_tail_falls_back():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        state = {"w": jnp.arange(8, dtype=jnp.float32)}
        mgr.save(10, state)
        mgr.save(20, {"w": state["w"] * 2})
        # corrupt the newest checkpoint's arrays (torn write)
        path = os.path.join(d, "step_0000000020", "arrays.npz")
        with open(path, "r+b") as f:
            f.seek(30)
            f.write(b"\x00" * 20)
        step, restored = mgr.restore_latest(state)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(8, dtype=np.float32))


def test_checkpoint_resume_is_exact():
    """Restart mid-run reproduces the uninterrupted trajectory exactly
    (step-indexed data + exact state restore)."""
    cfg = dataclasses.replace(get_smoke_config("qwen2_1p5b"), vocab=128,
                              n_layers=1)
    ocfg = OptimConfig(lr=5e-3, warmup_steps=2, total_steps=20)
    data = SyntheticCorpus(DataConfig(vocab=128, seq_len=32, batch=4, seed=0))
    step_fn = jax.jit(make_train_step(cfg, ocfg))

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            params, opt, m = step_fn(params, opt, {"tokens": data.batch_at(s)})
        return params, opt, float(m["loss"])

    params0 = api.init_params(jax.random.PRNGKey(0), cfg)
    opt0 = init_opt_state(params0, ocfg)
    _, _, loss_straight = run(params0, opt0, 0, 10)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        p, o, _ = run(params0, opt0, 0, 5)
        mgr.save(5, {"p": p, "o": o})
        # simulate preemption: restore into fresh templates
        fresh_p = api.init_params(jax.random.PRNGKey(9), cfg)
        fresh_o = init_opt_state(fresh_p, ocfg)
        st = mgr.restore(5, {"p": fresh_p, "o": fresh_o})
        _, _, loss_resumed = run(st["p"], st["o"], 5, 10)
    assert abs(loss_resumed - loss_straight) < 1e-5


# ------------------------------------------------------------------ serving

def test_engine_matches_sequential_decode():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12, 13, 14]]

    # reference: one-at-a-time greedy decode
    ref_tokens = []
    for pr in prompts:
        cache = api.make_cache(cfg, 1, 64, dtype=jnp.float32)
        logits, cache = api.prefill_step(
            params, cfg, {"tokens": jnp.asarray([pr], jnp.int32)}, cache)
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(4):
            logits, cache = api.decode_step(
                params, cfg, jnp.asarray([toks[-1]], jnp.int32), cache)
            toks.append(int(jnp.argmax(logits[0])))
        ref_tokens.append(toks)

    eng = ServingEngine(params, cfg, n_slots=4, max_len=64)
    uids = [eng.add_request(p, max_new_tokens=5) for p in prompts]
    eng.run_to_completion()
    # engine retired requests; compare recorded tokens
    all_reqs = {}
    # recover from uids via order of admission
    # (requests recorded in ref order)
    for uid, pr, ref in zip(uids, prompts, ref_tokens):
        pass
    # engine stores finished requests only in user space; re-run capturing
    eng2 = ServingEngine(params, cfg, n_slots=4, max_len=64)
    reqs = []
    for p in prompts:
        uid = eng2.add_request(p, max_new_tokens=5)
        reqs.append(eng2.active[uid])
    eng2.run_to_completion()
    for req, ref in zip(reqs, ref_tokens):
        assert req.tokens == ref, (req.tokens, ref)


def test_engine_slot_reuse():
    cfg = dataclasses.replace(get_smoke_config("qwen2_1p5b"), vocab=64,
                              n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, n_slots=2, max_len=32)
    done = []
    pending = [[1, 2], [3, 4], [5, 6], [7, 8]]
    while pending or eng.active:
        while pending and eng.free:
            uid = eng.add_request(pending.pop(0), max_new_tokens=3)
            done.append(eng.active[uid])
        eng.step()
    assert all(r.done for r in done) and len(done) == 4
