"""Trace-driven replay (DESIGN.md §13): trace synthesis/round-trip,
the scheduling report and its structural validator, byte-identical
deterministic replay under a preempt/resume storm, and the CLI paths
(`python -m repro.serve.replay`, `launch/serve.py --replay-trace`).

The determinism contract under test: one seed + a StepClock yields a
byte-identical report AND event stream across independent runs —
including runs where the fault injector's pressure windows preempt and
resume requests mid-flight.
"""
import dataclasses
import json

import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.serve import (Arrival, FaultInjector, Replayer, ServingEngine,
                         StepClock, Telemetry, load_trace, save_trace,
                         synthesize_trace, validate_report)
from repro.serve import replay as replay_cli

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(fp_model, telemetry=None, faults=False, seed=0):
    cfg, params = fp_model
    inj = None
    if faults:
        # pressure-only plan: the windows' limit falls below running
        # fills, so replay exercises preempt/resume deterministically
        inj = FaultInjector(seed=seed + 7, horizon=32, nan_faults=0,
                            inf_faults=0, transient_failures=0,
                            pressure_windows=2, pressure_frac=(0.15, 0.25))
    return ServingEngine(params, cfg, n_slots=3, max_len=48, min_bucket=8,
                         clock=StepClock(10.0), telemetry=telemetry,
                         faults=inj, on_pressure="preempt")


# -------------------------------------------------------------------- trace

def test_synthesize_trace_is_seed_deterministic():
    a = synthesize_trace(seed=5, steps=20)
    b = synthesize_trace(seed=5, steps=20)
    assert a == b and len(a) > 0
    assert synthesize_trace(seed=6, steps=20) != a
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    # a deadline_frac slice of arrivals carries a tight SLO
    assert any(x.deadline_ms is not None for x in a)
    assert any(x.deadline_ms is None for x in a)


def test_trace_jsonl_roundtrip(tmp_path):
    trace = synthesize_trace(seed=1, steps=16)
    p = tmp_path / "trace.jsonl"
    save_trace(str(p), trace)
    assert load_trace(str(p)) == trace
    # optional fields are omitted from the JSON when defaulted
    line = json.loads(p.read_text().splitlines()[0])
    assert "priority" not in line or line["priority"] != 0
    # load sorts by arrival time (same multiset, non-decreasing t; the
    # sort is stable, so equal-t burst arrivals may keep written order)
    shuffled = tmp_path / "shuffled.jsonl"
    save_trace(str(shuffled), list(reversed(trace)))
    got = load_trace(str(shuffled))
    assert all(x.t <= y.t for x, y in zip(got, got[1:]))
    assert sorted(map(repr, got)) == sorted(map(repr, trace))


def test_load_trace_names_bad_line(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"t": 0.0, "prompt": [1]}\n{"t": "nope"}\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:2"):
        load_trace(str(p))


# ------------------------------------------------------------------- report

def test_replay_report_schema_and_percentiles(fp_model):
    trace = synthesize_trace(seed=0, steps=16, vocab=128, max_new=(4, 9))
    report = Replayer(_engine(fp_model, telemetry=Telemetry()), trace).run()
    validate_report(report)
    assert report["trace"]["n_arrivals"] == len(trace)
    assert report["requests"]["submitted"] == len(trace)
    # non-vacuous percentile fields
    assert report["ttft_ms"]["count"] > 0
    assert report["ttft_ms"]["p50"] <= report["ttft_ms"]["p99"]
    assert report["tokens"]["total_out"] > 0
    assert report["tokens"]["per_s_per_slot"] > 0
    assert len(report["per_request"]) == len(trace)
    # timelines sampled every engine step
    assert report["timelines"]["queue_depth"]["n"] > 0
    # TPOT is recomputable post-hoc from the per-request table
    for row in report["per_request"]:
        if row["tpot_ms"] is not None:
            assert row["tokens_out"] >= 2


def test_replay_is_byte_identical_under_preempt_storm(fp_model):
    trace = synthesize_trace(seed=2, steps=20, vocab=128, max_new=(4, 9))

    def run():
        tel = Telemetry()
        rep = Replayer(_engine(fp_model, telemetry=tel, faults=True),
                       trace).run()
        return rep, tel.events

    rep1, ev1 = run()
    rep2, ev2 = run()
    # the storm must actually preempt and resume — otherwise this proves
    # nothing about mid-flight determinism
    assert rep1["scheduling"]["preemptions"] >= 1
    assert rep1["scheduling"]["resumes"] >= 1
    assert json.dumps(rep1, sort_keys=True) == json.dumps(rep2,
                                                          sort_keys=True)
    assert json.dumps(ev1) == json.dumps(ev2)


def test_replay_without_telemetry_matches_token_streams(fp_model):
    trace = synthesize_trace(seed=3, steps=16, vocab=128, max_new=(4, 9))

    def run(tel):
        eng = _engine(fp_model, telemetry=tel, faults=True)
        rep = Replayer(eng, trace).run()
        fin = eng.take_finished()
        return rep, {u: list(r.tokens) for u, r in fin.items()}

    rep_off, toks_off = run(None)
    assert rep_off is None                 # no telemetry -> no report
    rep_on, toks_on = run(Telemetry())
    assert rep_on is not None
    assert toks_on == toks_off             # hooks are observation-only


def test_validate_report_names_every_problem(fp_model):
    trace = synthesize_trace(seed=0, steps=12, vocab=128)
    report = Replayer(_engine(fp_model, telemetry=Telemetry()), trace).run()
    bad = json.loads(json.dumps(report))
    bad["schema"] = "nope"
    bad["ttft_ms"]["p90"] = bad["ttft_ms"]["p50"] - 1.0  # non-monotone
    del bad["tokens"]["per_s_per_slot"]
    with pytest.raises(ValueError) as ei:
        validate_report(bad)
    msg = str(ei.value)
    assert "schema" in msg and "not monotone" in msg
    assert "per_s_per_slot" in msg


# ---------------------------------------------------------------------- cli

def test_replay_cli_smoke(tmp_path, capsys):
    rep_path = tmp_path / "report.json"
    tr_path = tmp_path / "trace.json"
    rc = replay_cli.main(["--smoke", "--faults", "--steps", "12",
                          "--report-json", str(rep_path),
                          "--perfetto", str(tr_path)])
    assert rc == 0
    report = validate_report(json.loads(rep_path.read_text()))
    assert report["ttft_ms"]["count"] > 0
    doc = json.loads(tr_path.read_text())
    assert doc["traceEvents"]
    out = capsys.readouterr().out
    assert "ttft_ms p50=" in out and "tokens/s/slot=" in out


def test_replay_with_contract_gate_and_telemetry(fp_model):
    """verify_contracts=True must stay green WITH telemetry attached —
    the hooks live host-side, outside every jit (PR 8 rules)."""
    cfg, params = fp_model
    tel = Telemetry()
    eng = ServingEngine(params, cfg, n_slots=2, max_len=32, min_bucket=8,
                        clock=StepClock(10.0), telemetry=tel,
                        verify_contracts=True)
    assert eng.contract_report.rules_run
    report = Replayer(eng, synthesize_trace(seed=0, steps=8,
                                            vocab=128)).run()
    validate_report(report)


def test_launch_cli_replay_and_exports(tmp_path, capsys):
    """launch/serve.py end to end: --replay-trace drives the engine off a
    JSONL trace, --report-json / --telemetry-trace / --stats emit the
    report, a Perfetto-loadable trace, and the uniform metrics view."""
    from repro.launch import serve as launch_serve
    trace_p = tmp_path / "trace.jsonl"
    save_trace(str(trace_p), synthesize_trace(seed=4, steps=10, vocab=64,
                                              max_new=(3, 6)))
    rep_p = tmp_path / "report.json"
    pf_p = tmp_path / "perfetto.json"
    launch_serve.main(["--arch", "llama1_7b", "--smoke", "--bits", "3",
                       "--slots", "2", "--max-len", "48",
                       "--min-bucket", "8",
                       "--replay-trace", str(trace_p),
                       "--report-json", str(rep_p),
                       "--telemetry-trace", str(pf_p), "--stats"])
    report = validate_report(json.loads(rep_p.read_text()))
    assert report["ttft_ms"]["count"] > 0
    doc = json.loads(pf_p.read_text())
    tracks = [e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"]
    assert sorted(tracks) == ["queue", "slot 0", "slot 1"]
    out = capsys.readouterr().out
    assert "[serve metrics]" in out
    assert "serve.lifecycle.finished" in out
