"""Contract-checker tests (repro.analysis): one mutation test per
registered rule — a deliberately violated invariant must make exactly
that rule fire with its declared id/severity — plus registry
completeness, the TraceSentinel, golden reports for a dense and an AP+OR
config, and the ``verify_contracts=True`` engine-init smoke on the bench
substrate.
"""
import dataclasses
import json
import sys
from pathlib import Path

import jax
import pytest

from conftest import REPO

from repro.analysis import (REGISTRY, Report, Severity, ast_context,
                            run_rules)
from repro.analysis.artifacts import (dense_twin_engine, plan_stats,
                                      verify_engine,
                                      weight_shard_threshold)
from repro.analysis.core import ContractViolation, Finding, Rule, register
from repro.analysis.trace_rules import TraceSentinel
from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize
from repro.models import api
from repro.serve import ServingEngine

jax.config.update("jax_platform_name", "cpu")

GOLDEN = Path(__file__).parent / "golden"


# ---------------------------------------------------------------------------
# synthetic HLO modules for the compiled-artifact mutations
# ---------------------------------------------------------------------------

def _mod(body: str, header: str = "HloModule m") -> str:
    return (f"{header}\n\n"
            "%f (p: f32[8,16]) -> f32[8,16] {\n"
            "  %w = f32[8,16]{1,0} parameter(0)\n"
            f"{body}\n"
            "  ROOT %t = f32[8,16]{1,0} add(%w, %w)\n"
            "}\n\n"
            "ENTRY %e (a: f32[8,16]) -> f32[8,16] {\n"
            "  %a = f32[8,16]{1,0} parameter(0)\n"
            "  ROOT %r = f32[8,16]{1,0} add(%a, %a)\n"
            "}\n")


_CLEAN_MOD = _mod("  %x = f32[8,16]{1,0} multiply(%w, %w)")
_ALIGNED_PLAN = {"has_plans": True, "n_permuted_groups": 0, "max_bk": 0,
                 "bm": 8, "itemsize": 4}
_PERMUTED_PLAN = {"has_plans": True, "n_permuted_groups": 1, "max_bk": 16,
                  "bm": 8, "itemsize": 4}


def _sentinel_over_budget():
    s = TraceSentinel()
    s.observe("prefill", (1, 8))
    s.observe("prefill", (1, 16))
    s.observe("prefill", (2, 8))
    return {"sentinel": s, "compile_budget": {"prefill": 2}}


def _sentinel_retrace():
    s = TraceSentinel()
    s.observe("decode", (2, False))
    return {"sentinel": s, "trace_counts": {"decode": 3}}


# Every mutation: rule id -> ctx builder that VIOLATES exactly that
# invariant.  tmp_path is used by the AST entries (they lint real files).
MUTATIONS = {
    "HLO-AG1": lambda tmp: {
        "hlo": {"decode": _mod(
            "  %ag = f32[64,16]{1,0} all-gather(%w), replica_groups={}")},
        "weight_shard_bytes": 1024},
    "HLO-CB1": lambda tmp: {
        "hlo": {"decode": _mod(
            "  %ar = f32[64,16]{1,0} all-reduce(%w), to_apply=%f")},
        "collective_budget_bytes": 1024},
    "HLO-HT1": lambda tmp: {
        "hlo": {"decode": _mod(
            "  %o = token[] outfeed(%w, token[] %tok)")}},
    "HLO-DT1": lambda tmp: {
        "hlo": {"decode": _mod(
            "  %d = f32[4,64]{1,0} convert(s8[4,64]{1,0} %q)")},
        "pool_slice_elems": 64},
    "HLO-GA1": lambda tmp: {
        "hlo": {"decode": _mod(
            "  %g = f32[2,16]{1,0} gather(%w, s32[2]{0} %i), "
            "offset_dims={1}")},
        "dense_hlo": {"decode": _CLEAN_MOD},
        "plan": dict(_ALIGNED_PLAN)},
    "HLO-CP1": lambda tmp: {
        "hlo": {"decode": _mod("  %c = f32[16,16]{1,0} copy(%w)")},
        "cache_leaf_bytes": 16 * 16 * 4},
    "HLO-DN1": lambda tmp: {
        "hlo": {"decode": _CLEAN_MOD},
        "donation_expected": True},
    "TRC-CC1": lambda tmp: _sentinel_over_budget(),
    "TRC-SG1": lambda tmp: _sentinel_retrace(),
    "AST-IM1": lambda tmp: ast_context([_write(
        tmp, "m.py", "import jax.numpy as jnp\nx = jnp.zeros((3,))\n")]),
    "AST-JT1": lambda tmp: ast_context([_write(
        tmp, "m.py",
        "import jax\n@jax.jit\ndef f(x):\n"
        "    global evil\n    evil = 1\n    return x\n")]),
    "AST-HS1": lambda tmp: ast_context([_write(
        tmp, "m.py",
        "import jax\n@jax.jit\ndef f(x):\n    return x.item()\n")]),
    "AST-DT1": lambda tmp: ast_context([_write(
        tmp, "repro/serve/sched.py",
        "import time\ndef tick():\n    return time.time()\n")]),
}


def _write(tmp: Path, rel: str, source: str) -> Path:
    p = tmp / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return p


def test_registry_is_complete():
    """Every registered rule has a mutation test — no vacuous green."""
    assert set(MUTATIONS) == set(REGISTRY)


@pytest.mark.parametrize("rule_id", sorted(MUTATIONS))
def test_mutation_fires_rule(rule_id, tmp_path):
    rule = REGISTRY[rule_id]
    rep = run_rules([rule], MUTATIONS[rule_id](tmp_path), subject=rule_id)
    assert rep.findings, f"{rule_id} did not fire on a seeded violation"
    assert all(f.rule_id == rule_id for f in rep.findings)
    assert all(f.severity is rule.severity for f in rep.findings)
    assert rep.rules_run == [rule_id]


@pytest.mark.parametrize("rule_id", sorted(MUTATIONS))
def test_rule_skips_on_empty_context(rule_id):
    """With none of its context keys present every rule reports skipped,
    never a false finding (and never a crash)."""
    rep = run_rules([REGISTRY[rule_id]], {}, subject="empty")
    assert rep.rules_skipped == [rule_id] and not rep.findings


# ---------------------------------------------------------------------------
# targeted clean-path checks (the mutation's conforming twin)
# ---------------------------------------------------------------------------

def test_gather_parity_permuted_branch():
    """Permuted plans: a tile-sized added take passes; an activation-sized
    gather or more takes than permuted groups fails."""
    rule = REGISTRY["HLO-GA1"]
    dense = {"decode": _CLEAN_MOD}
    tile = _mod("  %g = f32[2,16]{1,0} gather(%w, s32[2]{0} %i), "
                "offset_dims={1}")                      # 128 B <= 512 B cap
    ok = run_rules([rule], {"hlo": {"decode": tile}, "dense_hlo": dense,
                            "plan": dict(_PERMUTED_PLAN)})
    assert not ok.findings
    big = _mod("  %g = f32[8,512]{1,0} gather(%w, s32[8]{0} %i), "
               "offset_dims={1}")                       # 16 KiB activation
    bad = run_rules([rule], {"hlo": {"decode": big}, "dense_hlo": dense,
                             "plan": dict(_PERMUTED_PLAN)})
    assert bad.findings


def test_jit_counter_allowlist_and_suppression(tmp_path):
    """Registered trace counters may be bumped inside jitted fns, and a
    `# contract: ok` comment suppresses any AST rule on that line."""
    ok = ast_context([_write(
        tmp_path, "a.py",
        "import jax\n@jax.jit\ndef f(x):\n"
        "    global decode_traces\n    decode_traces = 1\n"
        "    global launch_count\n    launch_count = 1\n    return x\n")])
    assert not run_rules([REGISTRY["AST-JT1"]], ok).findings

    supp = ast_context([_write(
        tmp_path, "b.py",
        "import jax\n@jax.jit\ndef f(x):\n"
        "    global evil  # contract: ok - exercised in tests\n"
        "    evil = 1\n    return x\n")])
    assert not run_rules([REGISTRY["AST-JT1"]], supp).findings


def test_host_sync_rule_allows_shape_math(tmp_path):
    src = ("import jax\n@jax.jit\ndef f(x):\n"
           "    n = x.shape[0]\n"
           "    return x * float(n) + float(len(x.shape))\n")
    ctx = ast_context([_write(tmp_path, "c.py", src)])
    assert not run_rules([REGISTRY["AST-HS1"]], ctx).findings


def test_import_time_rule_ignores_function_bodies(tmp_path):
    src = ("import jax.numpy as jnp\n"
           "def f():\n    return jnp.zeros((3,))\n")
    ctx = ast_context([_write(tmp_path, "d.py", src)])
    assert not run_rules([REGISTRY["AST-IM1"]], ctx).findings


def test_determinism_rule_is_scoped(tmp_path):
    """time.time() outside the serve scope is not this rule's business."""
    ctx = ast_context([_write(
        tmp_path, "tools/bench.py",
        "import time\ndef t():\n    return time.time()\n")])
    assert not run_rules([REGISTRY["AST-DT1"]], ctx).findings


def test_determinism_rule_telemetry_carveout(tmp_path):
    """serve/telemetry.py is the ONE sanctioned clock source on serve
    paths (DESIGN.md §13): a wall-clock read there is clean, while the
    identical call in any OTHER repro/serve file still fires — both
    directions pinned so the carve-out can neither widen nor silently
    disable the rule."""
    src = "import time\ndef monotonic():\n    return time.monotonic()\n"
    ok = ast_context([_write(tmp_path, "repro/serve/telemetry.py", src)])
    assert not run_rules([REGISTRY["AST-DT1"]], ok).findings
    bad = ast_context([_write(tmp_path, "repro/serve/engine.py", src)])
    rep = run_rules([REGISTRY["AST-DT1"]], bad)
    assert rep.findings, "AST-DT1 went quiet outside the carve-out"
    assert all(f.rule_id == "AST-DT1" for f in rep.findings)


def test_donation_rule_clean_when_aliased():
    aliased = _mod("  %x = f32[8,16]{1,0} multiply(%w, %w)",
                   header="HloModule m, input_output_alias="
                          "{ {0}: (0, {}, must-alias) }")
    rep = run_rules([REGISTRY["HLO-DN1"]],
                    {"hlo": {"decode": aliased}, "donation_expected": True})
    assert not rep.findings


# ---------------------------------------------------------------------------
# framework plumbing
# ---------------------------------------------------------------------------

def test_register_rejects_duplicates_and_blank_ids():
    class Dup(Rule):
        id = "HLO-AG1"

    class Blank(Rule):
        id = ""

    with pytest.raises(ValueError, match="duplicate"):
        register(Dup())
    with pytest.raises(ValueError, match="no id"):
        register(Blank())


def test_report_renders_and_serializes():
    f = Finding("X-1", Severity.ERROR, "boom", subject="decode",
                details={"n": 3})
    rep = Report(subject="s", findings=[f], rules_run=["X-1"],
                 rules_skipped=["Y-1"])
    assert not rep.clean and rep.errors == [f]
    txt = rep.render()
    assert "VIOLATIONS" in txt and "X-1" in txt and "boom" in txt
    j = rep.to_json()
    assert j["clean"] is False and j["summary"]["ERROR"] == 1
    json.dumps(j)                                   # JSON-serializable
    with pytest.raises(ContractViolation) as ei:
        raise ContractViolation(rep)
    assert ei.value.report is rep


def test_trace_sentinel_accounting():
    s = TraceSentinel()
    s.observe("decode", (4, False))
    s.observe("decode", (4, False))
    s.observe("decode", (1, False))
    s.observe_lowering("decode")
    assert s.distinct("decode") == 2 and s.calls("decode") == 3
    snap = s.snapshot()
    assert snap["decode"] == {"distinct": 2, "calls": 3, "lowerings": 1}
    # counts within [distinct, distinct+lowerings] are clean; outside fires
    rule = REGISTRY["TRC-SG1"]
    ok = run_rules([rule], {"sentinel": s, "trace_counts": {"decode": 3}})
    assert not ok.findings
    bad = run_rules([rule], {"sentinel": s, "trace_counts": {"decode": 4}})
    assert bad.findings
    broken = run_rules([rule], {"sentinel": s, "trace_counts": {"decode": 1}})
    assert broken.findings            # counter under-reports: also a bug


# ---------------------------------------------------------------------------
# engine-integrated: live sentinel, golden reports, verify_contracts smoke
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_models():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=64,
                              n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=2,
                      gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                      orr=ORConfig(0.1))
    calib = calibration_set(vocab=cfg.vocab, n_segments=2, seq_len=16)
    qparams, _ = claq_quantize(params, cfg, calib, qcfg)
    return cfg, params, qparams


def test_engine_sentinel_tracks_traces(small_models):
    """The live engine's sentinel agrees with its trace counters and the
    bucketing budget — the runtime form of TRC-CC1/TRC-SG1."""
    cfg, params, _ = small_models
    eng = ServingEngine(params, cfg, n_slots=2, max_len=32, min_bucket=8,
                        prepare=False)
    uids = eng.add_requests([[1, 2, 3], [4, 5, 6, 7]], max_new_tokens=3)
    eng.run_to_completion()
    assert len(eng.take_finished()) == len(uids)
    assert eng.sentinel.distinct("prefill") == eng.prefill_traces
    assert eng.sentinel.distinct("decode") == eng.decode_traces
    rep = verify_engine(eng, with_baseline=False, raise_on_error=False,
                        subject="live")
    assert rep.clean, rep.render()
    assert {"TRC-CC1", "TRC-SG1"} <= set(rep.rules_run)


def _stable(report: Report):
    """Projection pinned by the goldens: which rules ran/skipped and which
    fired at what severity — byte counts and messages stay free to drift
    with XLA versions."""
    j = report.to_json()
    return {"subject": j["subject"], "clean": j["clean"],
            "rules_run": j["rules_run"],
            "rules_skipped": j["rules_skipped"],
            "findings": sorted({(f["rule"], f["severity"])
                                for f in j["findings"]})}


def _golden(name: str):
    doc = json.loads((GOLDEN / name).read_text())
    doc["findings"] = sorted(tuple(f) for f in doc["findings"])
    return doc


def test_golden_report_dense(small_models):
    cfg, params, _ = small_models
    eng = ServingEngine(params, cfg, n_slots=2, max_len=32, prepare=False)
    rep = verify_engine(eng, raise_on_error=False, subject="config:dense")
    assert _stable(rep) == _golden("contracts_dense.json")


def test_golden_report_ap_or(small_models):
    cfg, params, qparams = small_models
    eng = ServingEngine(qparams, cfg, n_slots=2, max_len=32)
    dense_eng = ServingEngine(params, cfg, n_slots=2, max_len=32,
                              prepare=False)
    assert plan_stats(eng.params)["n_permuted_groups"] > 0, \
        "AP model produced no permuted plan -> vacuous golden"
    rep = verify_engine(eng, dense_eng, raise_on_error=False,
                        subject="config:ap_or")
    assert _stable(rep) == _golden("contracts_ap_or.json")


def test_dense_twin_matches_engine_structure(small_models):
    cfg, _, qparams = small_models
    eng = ServingEngine(qparams, cfg, n_slots=2, max_len=32)
    twin = dense_twin_engine(eng)
    assert not plan_stats(twin.params)["has_plans"]
    assert (twin.n_slots, twin.max_len) == (eng.n_slots, eng.max_len)
    # twin serves: dequantized weights flow through the dense path
    twin.add_requests([[1, 2, 3]], max_new_tokens=2)
    twin.run_to_completion()


def test_weight_shard_threshold(small_models):
    cfg, _, qparams = small_models
    eng = ServingEngine(qparams, cfg, n_slots=2, max_len=32, plan_bn=32)
    assert weight_shard_threshold(eng.params, 1) is None
    t4 = weight_shard_threshold(eng.params, 4)
    assert t4 is not None and t4 > 0


def test_verify_contracts_raises_on_violation(small_models, monkeypatch):
    """End-to-end mutation: force a violating artifact through the init
    gate and the engine must refuse to come up."""
    from repro.analysis import artifacts as afx
    cfg, params, _ = small_models
    monkeypatch.setattr(
        afx, "lowered_decode_text",
        lambda engine, interpret=True: _mod(
            "  %o = token[] outfeed(%w, token[] %tok)"))
    with pytest.raises(ContractViolation, match="HLO-HT1"):
        ServingEngine(params, cfg, n_slots=2, max_len=32, prepare=False,
                      verify_contracts=True)


def test_verify_contracts_smoke_on_bench_substrate():
    """ISSUE 8 acceptance: engine init with verify_contracts=True over the
    trained bench substrate passes the artifact rules."""
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks.common import recipe, trained_model
    cfg, params, hessians = trained_model()
    from repro.launch.quantize import quantize_model_params
    qparams, _ = quantize_model_params(params, cfg, hessians,
                                       recipe("rtn3"))
    eng = ServingEngine(qparams, cfg, n_slots=2, max_len=64,
                        verify_contracts=True)
    assert eng.contract_report is not None and eng.contract_report.clean
    assert "HLO-GA1" in eng.contract_report.rules_run
