"""Overload control plane (serve/admission.py).

Pure-host units for the cost model, SLO validation, ladder gating,
hysteresis and shedding — then a seeded overload storm on a speculative
engine pinning the full 5-rung decision sequence byte-identical across
runs under the virtual StepClock.
"""
import dataclasses
import json

import pytest

from repro.serve import (AdmissionController, AdmissionQueue, SLOConfig,
                         StepCostModel)
from repro.serve.admission import (RUNG_KV_INT8, RUNG_NOMINAL, RUNG_SHED,
                                   RUNG_SPEC_HALF, RUNG_SPEC_OFF)

# ---------------------------------------------------------------- cost model


def test_cost_model_prices_actual_work():
    m = StepCostModel()
    assert m.cost_ms() == 1.0                      # idle step: base only
    assert m.cost_ms(prefill_tokens=100) == pytest.approx(6.0)
    assert m.cost_ms(decode_calls=1, draft_calls=3,
                     verify_tokens=3) == pytest.approx(1 + 4 + 3 + 3)
    # chunking the same tokens costs the same total — the model must not
    # bias the controller toward or away from chunked prefill
    whole = m.cost_ms(prefill_tokens=512)
    parts = sum(m.cost_ms(prefill_tokens=64) - m.base_ms
                for _ in range(8)) + m.base_ms
    assert whole == pytest.approx(parts)


def test_slo_config_validation():
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=0)
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=100, queue_wait_frac=0.0)
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=100, prefill_budget_tokens=0)
    with pytest.raises(ValueError):
        SLOConfig(ttft_p99_ms=100, up_patience=0)
    with pytest.raises(ValueError):
        AdmissionController(SLOConfig(ttft_p99_ms=100), mode="degrade")


# -------------------------------------------------------------- queue units


class _Req:
    def __init__(self, uid, priority=0, tokens=(), preemptions=0):
        self.uid = uid
        self.priority = priority
        self.tokens = list(tokens)
        self.deadline = None
        self.preemptions = preemptions


def test_pop_worst_is_reverse_rank_and_spares_preempted():
    q = AdmissionQueue(8)
    fresh_lo = _Req(1, priority=-1)
    fresh_hi = _Req(2, priority=1)
    preempted = _Req(3, priority=-1, tokens=[7])   # has emitted tokens
    q.push(fresh_hi)
    q.push(fresh_lo)
    q.push_front(preempted)
    # worst admissible FRESH request sheds first: lowest priority, latest
    assert q.pop_worst(lambda r: not r.tokens) is fresh_lo
    assert q.pop_worst(lambda r: not r.tokens) is fresh_hi
    # only the preempted request remains and the fresh filter spares it
    assert q.pop_worst(lambda r: not r.tokens) is None
    assert len(q) == 1 and q.pop_worst() is preempted


def test_queue_peak_depth_reset():
    q = AdmissionQueue(8)
    for i in range(5):
        q.push(_Req(i))
    for _ in range(4):
        q.pop_worst()
    assert q.peak_depth == 5 and len(q) == 1
    q.reset_peaks()                 # A/B replays must not inherit peaks
    assert q.peak_depth == 1


# ------------------------------------------------- hysteresis (fake engine)


class _FakeEngine:
    """The exact attribute surface ``on_step``/``allow_fresh`` touch —
    no jax, so the hysteresis timing is tested in isolation."""

    spec = None
    kv_dtype = None
    telemetry = None
    n_slots = 2
    last_step_cost_ms = None
    pending_prefills = 0
    prefill_backlog_tokens = 0

    def __init__(self):
        self.queue = AdmissionQueue(64)
        self.engine_steps = 0
        self.active = {}
        self._kv_int8_admission = False
        self.t = 0.0
        self.retired = []

    def _clock(self):
        return self.t

    def _retire(self, req, state, diagnostics=None):
        self.queue._items = [(o, r) for o, r in self.queue._items
                             if r is not req]
        self.retired.append((req.uid, state, diagnostics))


def _stale_fresh(uid):
    r = _Req(uid)
    r.submitted_at = -100.0          # has waited forever: breach signal
    return r


def test_hysteresis_patience_and_dwell():
    slo = SLOConfig(ttft_p99_ms=100, up_patience=2, down_patience=3,
                    min_dwell_steps=3)
    ctl = AdmissionController(slo, mode="full")
    eng = _FakeEngine()
    ctl.attach(eng)
    assert ctl.ladder == [RUNG_NOMINAL, RUNG_KV_INT8, RUNG_SHED]

    eng.queue.push(_stale_fresh(1))  # permanently breached signal
    rungs = []
    for step in range(1, 9):
        eng.engine_steps = step
        ctl.on_step(eng)
        rungs.append(ctl.rung)
    # up_patience=2 gates the first move; each later move waits out the
    # 3-step dwell: up at step 2 (hot==2), then step 5, then pinned at top
    assert rungs == [0, 1, 1, 1, 2, 2, 2, 2]
    assert eng._kv_int8_admission    # rung 1+ projects onto the engine

    # kv_int8 is CUMULATIVE under shed, and on_step at the top rung shed
    # the stale fresh request down to the n_slots target depth
    assert ctl.rung_name == RUNG_SHED
    assert len(eng.queue) <= eng.n_slots

    eng.queue._items = []            # pressure clears
    for step in range(9, 20):
        eng.engine_steps = step
        ctl.on_step(eng)
        rungs.append(ctl.rung)
    # down_patience=3 clear steps -> first step-down at 11, dwell to 14
    assert rungs[8:] == [2, 2, 1, 1, 1, 0, 0, 0, 0, 0, 0]
    assert not eng._kv_int8_admission
    # every change is a typed, replayable decision
    kinds = [d.kind for d in ctl.decisions if d.kind.startswith("rung")]
    assert kinds == ["rung_up", "rung_up", "rung_down", "rung_down"]
    assert ctl.rung_changes == 4


def test_shed_abandons_worst_first_to_target_depth():
    slo = SLOConfig(ttft_p99_ms=100, up_patience=1, min_dwell_steps=0,
                    shed_target_depth=1)
    ctl = AdmissionController(slo, mode="admission")
    eng = _FakeEngine()
    ctl.attach(eng)
    assert ctl.ladder == [RUNG_NOMINAL, RUNG_SHED]

    preempted = _Req(9, tokens=[3], preemptions=1)
    preempted.submitted_at = 0.0
    eng.queue.push_front(preempted)
    # a mid-PREFILLING preempt holds NO tokens yet must also be spared:
    # its admission debt (reserved pages, replayed chunks) is already paid
    prefilling = _Req(8, tokens=(), preemptions=1)
    prefilling.submitted_at = 0.0
    eng.queue.push_front(prefilling)
    for uid, prio in ((1, 0), (2, -1), (3, 1)):
        r = _stale_fresh(uid)
        r.priority = prio
        eng.queue.push(r)
    eng.engine_steps = 1
    ctl.on_step(eng)                 # breach -> shed rung -> shed to target
    assert ctl.rung_name == RUNG_SHED
    shed_uids = [u for u, _, _ in eng.retired]
    assert shed_uids == [2, 1, 3]    # worst-ranked fresh first
    assert all(d["kind"] == "shed" for _, _, d in eng.retired)
    # preempted work is NEVER shed — with or without emitted tokens
    assert len(eng.queue) == 2
    assert set(eng.queue.requests()) == {preempted, prefilling}
    assert ctl.sheds == 3


def test_defer_counter_matches_decision_stream():
    """``defers`` dedupes per engine step exactly like the typed decision
    log, so replay/bench counters stay comparable across the two."""
    ctl = AdmissionController(SLOConfig(ttft_p99_ms=100), mode="admission")
    eng = _FakeEngine()
    ctl.attach(eng)
    eng.engine_steps = 1
    ctl.note_defer(eng, blocked=2)   # explicit pump() ...
    ctl.note_defer(eng, blocked=2)   # ... then step()'s own pump
    eng.engine_steps = 2
    ctl.note_defer(eng, blocked=1)
    defer_events = [d for d in ctl.decisions if d.kind == "defer"]
    assert ctl.defers == len(defer_events) == 2


def test_idle_engine_always_admits():
    """Deferring fresh work on an idle engine would livelock: the
    deferred requests' own queue wait IS the breach signal."""
    ctl = AdmissionController(SLOConfig(ttft_p99_ms=100), mode="admission")
    eng = _FakeEngine()
    ctl.attach(eng)
    ctl.rung = len(ctl.ladder) - 1
    ctl._breached = True
    assert ctl.allow_fresh(eng)      # nothing running -> admit anyway
    eng.active = {1: object()}
    assert not ctl.allow_fresh(eng)  # live work to protect -> defer


def test_prefill_budget_halves_per_rung():
    slo = SLOConfig(ttft_p99_ms=100, prefill_budget_tokens=512,
                    min_prefill_tokens=32)
    ctl = AdmissionController(slo, mode="full")
    eng = _FakeEngine()
    ctl.attach(eng)
    budgets = []
    for rung in range(len(ctl.ladder)):
        ctl.rung = rung
        budgets.append(ctl.prefill_budget())
    assert budgets == [512, 256, 128]
    ctl.rung = 0
    object.__setattr__(ctl, "rung", 5)   # hypothetical deeper rung
    assert ctl.prefill_budget() == 32    # floored, never zero


def test_one_controller_per_engine():
    ctl = AdmissionController(SLOConfig(ttft_p99_ms=100))
    ctl.attach(_FakeEngine())
    with pytest.raises(ValueError, match="already attached"):
        ctl.attach(_FakeEngine())


# --------------------------------------------- capability-gated ladders


@pytest.fixture(scope="module")
def fp_model():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api
    jax.config.update("jax_platform_name", "cpu")
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(fp_model, **kw):
    from repro.serve import ServingEngine
    cfg, params = fp_model
    return ServingEngine(params, cfg, n_slots=3, max_len=64, min_bucket=8,
                         **kw)


def test_ladder_gating_capabilities(fp_model):
    import jax
    from repro.models import api
    from repro.serve import SpecConfig
    cfg, params = fp_model
    slo = SLOConfig(ttft_p99_ms=250)

    ctl = AdmissionController(slo)
    _engine(fp_model, controller=ctl)
    assert ctl.ladder == [RUNG_NOMINAL, RUNG_KV_INT8, RUNG_SHED]

    ctl = AdmissionController(slo, mode="admission")
    _engine(fp_model, controller=ctl)
    assert ctl.ladder == [RUNG_NOMINAL, RUNG_SHED]

    draft = api.init_params(jax.random.PRNGKey(99), cfg)
    ctl = AdmissionController(slo)
    eng = _engine(fp_model, controller=ctl, draft_params=draft,
                  spec=SpecConfig(gamma=4))
    assert ctl.ladder == [RUNG_NOMINAL, RUNG_SPEC_HALF, RUNG_SPEC_OFF,
                         RUNG_KV_INT8, RUNG_SHED]
    # spec_half's shrunk window mints exactly one extra verify trace,
    # and the compile budget accounts for it up front
    assert 2 in eng.verify_gammas and 4 in eng.verify_gammas
    from repro.analysis.artifacts import compile_budgets
    assert compile_budgets(eng)["verify"] == 2

    # int8-resident pages: the kv_int8 rung would be a no-op — gated out
    ctl = AdmissionController(slo)
    _engine(fp_model, controller=ctl, kv_layout="paged", page_size=8,
            kv_dtype="int8")
    assert ctl.ladder == [RUNG_NOMINAL, RUNG_SHED]


# ------------------------------------------------------ seeded storm


def test_overload_storm_rung_sequence_deterministic(fp_model):
    """The full 5-rung ladder under a seeded burst storm on a chunked
    SPECULATIVE engine: the typed decision stream — every rung change,
    shed and defer, with virtual timestamps — is byte-identical across
    two independent runs, and the ladder actually climbs to shed."""
    import jax
    from repro.models import api
    from repro.serve import (Replayer, RetryPolicy, ServingEngine,
                             SpecConfig, StepClock)
    from repro.serve.replay import overload_trace

    cfg, params = fp_model
    draft = api.init_params(jax.random.PRNGKey(99), cfg)
    trace = overload_trace(seed=5, steps=40, vocab=cfg.vocab)

    def run():
        ctl = AdmissionController(
            SLOConfig(ttft_p99_ms=120.0), mode="full")
        eng = ServingEngine(
            params, cfg, n_slots=3, max_len=64, min_bucket=8,
            draft_params=draft, spec=SpecConfig(gamma=2),
            chunked_prefill=8, controller=ctl,
            cost_model=StepCostModel(), clock=StepClock(10.0),
            queue_depth=48)
        Replayer(eng, trace, retry=RetryPolicy(backoff_s=0.0)).run()
        return eng, ctl

    eng1, ctl1 = run()
    eng2, ctl2 = run()
    assert ctl1.ladder == [RUNG_NOMINAL, RUNG_SPEC_HALF, RUNG_SPEC_OFF,
                          RUNG_KV_INT8, RUNG_SHED]
    log1, log2 = ctl1.decision_log(), ctl2.decision_log()
    assert json.dumps(log1, sort_keys=True) == \
        json.dumps(log2, sort_keys=True)
    # non-vacuous: the storm walked the ladder one rung at a time all
    # the way to shed (so every intermediate rung was exercised)
    up_rungs = [d["rung_name"] for d in log1 if d["kind"] == "rung_up"]
    assert RUNG_SHED in up_rungs
    assert up_rungs[:4] == [RUNG_SPEC_HALF, RUNG_SPEC_OFF, RUNG_KV_INT8,
                            RUNG_SHED]
    assert ctl1.sheds > 0
    # satellite: explicit peak reset between back-to-back A/B replays
    assert eng1.stats()["queue_peak_depth"] > 0
    eng1.reset_peaks()
    assert eng1.stats()["queue_peak_depth"] == len(eng1.queue) == 0
