"""GPTQ engine + CLAQ orchestration: compensation quality, reservation
exactness, stripe packaging, method orderings (paper Tables 1/3/4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (APConfig, CLAQConfig, ORConfig, gptq, proxy_loss,
                        quantize_matrix, rtn_quantize_matrix)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    rows, cols = 48, 96
    W = rng.normal(size=(rows, cols)).astype(np.float32)
    W[:, :6] += rng.standard_t(df=2, size=(rows, 6)) * 4
    X = rng.normal(size=(384, cols)).astype(np.float32)
    X[:, ::7] *= 3.0  # correlated/heteroscedastic inputs
    H = (2 * X.T @ X).astype(np.float32)
    return jnp.asarray(W), jnp.asarray(H)


def test_gptq_compensation_beats_rtn(problem):
    W, H = problem
    cfg = CLAQConfig(bits=3, method="uniform", gptq_blocksize=32)
    _, Q_gptq, st = quantize_matrix(W, H, cfg)
    Q_rtn, _, _ = rtn_quantize_matrix(W, 3, "uniform")
    assert st.proxy_loss < float(proxy_loss(W, Q_rtn, H))


def test_kmeans_beats_uniform(problem):
    W, H = problem
    km = quantize_matrix(W, H, CLAQConfig(bits=3, method="kmeans",
                                          kmeans_iters=8, gptq_blocksize=32))[2]
    un = quantize_matrix(W, H, CLAQConfig(bits=3, method="uniform",
                                          gptq_blocksize=32))[2]
    assert km.proxy_loss < un.proxy_loss


def test_fusion_beats_pure_low_bit(problem):
    W, H = problem
    fusion = quantize_matrix(W, H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
        ap=APConfig(2.2, 2, 4), orr=ORConfig(0.1)))[2]
    pure = quantize_matrix(W, H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32))[2]
    assert fusion.proxy_loss < pure.proxy_loss


def test_or_beats_ap_at_same_budget():
    """Paper §4.3.2: at equal extra budget, reserving fp outliers beats
    spending the same bits on higher precision — the effect the paper
    attributes to *element*-granular outliers that column-granular AP
    cannot capture.  Construct exactly that regime: scattered huge
    entries, not column-aligned."""
    rng = np.random.default_rng(42)
    rows, cols = 64, 96
    W = rng.normal(size=(rows, cols)).astype(np.float32)
    mask = rng.random(W.shape) < 0.02
    W[mask] += np.sign(W[mask]) * rng.uniform(8, 20, size=mask.sum())
    X = rng.normal(size=(256, cols)).astype(np.float32)
    H = jnp.asarray(2 * X.T @ X)
    W = jnp.asarray(W)
    # budget 0.5 bits: large enough that OR's integer per-column counts
    # land within ~0.1 bit of AP's achieved budget (paper uses 4096-row
    # matrices where 0.28-bit budgets round finely; here rows=64)
    orr = quantize_matrix(W, H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
        orr=ORConfig(0.5)))[2]
    ap = quantize_matrix(W, H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
        ap=APConfig(2.5, 2, 4)))[2]
    assert abs(orr.effective_bits - ap.effective_bits) < 0.15
    assert orr.proxy_loss < ap.proxy_loss


def test_reserved_entries_have_zero_error(problem):
    W, H = problem
    qt, Q, _ = quantize_matrix(W, H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=5, gptq_blocksize=32,
        orr=ORConfig(0.2)))
    deq = qt.dequantize()
    np.testing.assert_allclose(np.asarray(deq), np.asarray(Q), atol=1e-5)
    assert int(qt.out_count.sum()) > 0


def test_identity_hessian_matches_rtn_error_scale(problem):
    W, _ = problem
    _, Q, st = quantize_matrix(W, None, CLAQConfig(
        bits=4, method="uniform", gptq_blocksize=32))
    Q_rtn, _, _ = rtn_quantize_matrix(W, 4, "uniform")
    # identity Hessian => no useful compensation signal; errors comparable
    mse_rtn = float(jnp.mean((W - Q_rtn) ** 2))
    assert st.mse <= mse_rtn * 1.5


def test_frozen_codebooks_close_to_live(problem):
    W, H = problem
    live = quantize_matrix(W, H, CLAQConfig(
        bits=3, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
        codebook_mode="live"))[2]
    frozen = quantize_matrix(W, H, CLAQConfig(
        bits=3, method="kmeans", kmeans_iters=6, gptq_blocksize=32,
        codebook_mode="frozen"))[2]
    assert frozen.proxy_loss < live.proxy_loss * 3.0


def test_effective_bits_accounting(problem):
    W, H = problem
    qt, _, st = quantize_matrix(W, H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=4, gptq_blocksize=32,
        ap=APConfig(2.5, 2, 4), orr=ORConfig(0.1)))
    assert 2.4 <= st.effective_bits <= 2.8
    assert st.effective_bits_with_codebooks > st.effective_bits
    # stripes partition the columns
    assert sum(s.n_cols for s in qt.stripes) == qt.cols
    assert sorted(s.bits for s in qt.stripes) == [2, 4]


def test_hessian_accumulation():
    st = gptq.init_hessian(8)
    x1 = jnp.ones((4, 8))
    x2 = 2 * jnp.ones((2, 8))
    st = gptq.accumulate_hessian(st, x1)
    st = gptq.accumulate_hessian(st, x2)
    H = gptq.finalize_hessian(st)
    expected = 2 * (4 * 1.0 + 2 * 4.0) / 6.0
    np.testing.assert_allclose(np.asarray(H), expected, rtol=1e-6)


def test_prepare_hinv_cholesky_is_upper_factor():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(64, 16)).astype(np.float32)
    H = jnp.asarray(X.T @ X)
    U = gptq.prepare_hinv_cholesky(H, percdamp=0.01)
    Un = np.asarray(U)
    assert np.allclose(Un, np.triu(Un), atol=1e-6)       # upper triangular
    damp = 0.01 * float(jnp.mean(jnp.diag(H)))
    Hinv = np.linalg.inv(np.asarray(H) + damp * np.eye(16))
    np.testing.assert_allclose(Un.T @ Un, Hinv, rtol=2e-2, atol=2e-4)
