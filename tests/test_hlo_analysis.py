"""Unit regression pins for dist.hlo_analysis parsing helpers.

The analyzer's shape math used to be exercised only through full engine
lowerings; these tests pin `_shape_elems` / `_result_bytes` (and the
contract-checker parsers built on them) on hand-written HLO snippets, so
a parsing regression shows up as a one-line diff instead of a mysterious
byte-count drift in a 900-second multi-device test.
"""
import pytest

from repro.dist.hlo_analysis import (_result_bytes, _shape_elems,
                                     _tuple_region, analyze_hlo,
                                     collective_instructions,
                                     convert_instructions,
                                     copy_instructions, donation_aliases,
                                     gather_instructions,
                                     host_transfer_instructions)


# ---------------------------------------------------------------- _shape_elems
@pytest.mark.parametrize("dims,expected", [
    ("8,16", 128),
    ("", 1),                       # scalar f32[]
    ("4", 4),
    ("2,3,5", 30),
    ("<=8,4", 32),                 # dynamic dim: bound is the proxy
    ("2,<=16", 32),
    ("bogus", 0),                  # malformed -> 0, never raises
    ("4,x", 0),                    # one malformed dim voids the product
])
def test_shape_elems(dims, expected):
    assert _shape_elems(dims) == expected


# --------------------------------------------------------------- _result_bytes
@pytest.mark.parametrize("line,expected", [
    ("  %r = f32[8,16]{1,0} add(...)", 8 * 16 * 4),
    ("  %r = s8[32]{0} copy(...)", 32),
    ("  %r = f32[] constant(0)", 4),
    # tuple results sum their parts
    ("  ROOT %t = (f32[8]{0}, s32[4]{0}) tuple(...)", 8 * 4 + 4 * 4),
    # nested tuples keep EVERY element (the old first-')' split dropped
    # the trailing f32[4])
    ("  %t = ((f32[2]{0}, s32[]), f32[4]{0}) tuple(...)",
     2 * 4 + 4 + 4 * 4),
    # token / opaque are bookkeeping types, not HBM traffic
    ("  %t = token[] after-all()", 0),
    ("  %t = (f32[8]{0}, token[]) tuple(...)", 8 * 4),
    ("  %t = opaque[] custom-call(...)", 0),
    # dynamic result dims use the bound
    ("  %r = f32[<=8,4]{1,0} pad(...)", 8 * 4 * 4),
])
def test_result_bytes(line, expected):
    assert _result_bytes(line) == expected


def test_tuple_region_is_balanced():
    rhs = "((f32[2]{0}, s32[]), f32[4]{0}) tuple(%a, %b)"
    assert _tuple_region(rhs) == "((f32[2]{0}, s32[]), f32[4]{0})"


_MODULE = """\
HloModule step, input_output_alias={ {0}: (1, {}, must-alias), {2}: (3, {}) }

%body (p: (f32[8,16], s32[])) -> (f32[8,16], s32[]) {
  %p = (f32[8,16]{1,0}, s32[]) parameter(0)
  %w = f32[8,16]{1,0} get-tuple-element(%p), index=0
  %cp = f32[8,16]{1,0} copy(%w)
  %q = s8[8,16]{1,0} convert(f32[8,16]{1,0} %cp)
  %deq = f32[8,16]{1,0} convert(s8[8,16]{1,0} %q)
  %ag = f32[64,16]{1,0} all-gather(%w), replica_groups={}
  %g = f32[2,16]{1,0} gather(%w, s32[2]{0} %idx), offset_dims={1}
  %out = token[] outfeed(%w, token[] %tok)
  ROOT %t = (f32[8,16]{1,0}, s32[]) tuple(%cp, %i)
}

ENTRY %step (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  ROOT %r = f32[8,16]{1,0} copy(%a)
}
"""


def test_copy_instructions():
    copies = copy_instructions(_MODULE)
    assert ("copy", 8 * 16 * 4) in copies
    assert len(copies) == 2        # body copy + entry copy, each once


def test_convert_instructions():
    convs = convert_instructions(_MODULE)
    assert ("f32", "s8", 128) in convs     # quantize direction
    assert ("s8", "f32", 128) in convs     # dequantize direction


def test_collective_and_gather_instructions():
    assert ("all-gather", 64 * 16 * 4) in collective_instructions(_MODULE)
    assert ("gather", 2 * 16 * 4) in gather_instructions(_MODULE)


def test_host_transfer_instructions():
    hits = host_transfer_instructions(_MODULE)
    assert [op for op, _ in hits] == ["outfeed"]
    host_cc = ('ENTRY %e (a: f32[4]) -> f32[4] {\n'
               '  ROOT %c = f32[4]{0} custom-call(%a), '
               'custom_call_target="xla_ffi_python_cpu_callback"\n}\n')
    assert [op for op, _ in host_transfer_instructions(host_cc)] == [
        "custom-call"]
    clean = ('ENTRY %e (a: f32[4]) -> f32[4] {\n'
             '  ROOT %c = f32[4]{0} add(%a, %a)\n}\n')
    assert host_transfer_instructions(clean) == []


def test_donation_aliases():
    assert donation_aliases(_MODULE) == [(1, (0,)), (3, (2,))]
    assert donation_aliases("HloModule step\n\nENTRY %e () -> f32[] {\n"
                            "}") == []


def test_analyze_hlo_survives_tuple_and_token_types():
    res = analyze_hlo(_MODULE)
    assert res["hbm_bytes"] > 0    # parsed through tuples/tokens, no raise
