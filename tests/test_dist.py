"""Distribution tests (8 forced host devices via subprocess — the main
process keeps 1 device per the dry-run contract): row-sharded quantizer
parity, compressed DP all-reduce, small-mesh lower+compile, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.hlo_analysis import analyze_hlo, gather_instructions


def test_hlo_analyzer_counts_loops_exactly():
    def g(x):
        def inner(c, _):
            return c @ x, None
        c, _ = jax.lax.scan(inner, x, None, length=3)
        return c
    compiled = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text())
    assert res["flops"] == 3 * 2 * 64 ** 3


def test_hlo_analyzer_nested_loops():
    def g(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            return jax.lax.scan(inner, c, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]
    compiled = jax.jit(g).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text())
    assert res["flops"] == 20 * 2 * 32 ** 3


def test_hlo_analyzer_fused_elementwise_cost():
    """Elementwise ops are charged result_elems x op-weight — including
    inside fusion bodies and multiplied by loop trip counts — under the
    separate `elementwise_flops` key (dot FLOPs stay contraction-only)."""
    def g(x):
        def inner(c, _):
            # one add (weight 1) + one exp (weight 8) per iteration,
            # each producing 32*32 elements, plus the dot
            return jnp.exp(c + x) @ x, None
        c, _ = jax.lax.scan(inner, x, None, length=3)
        return c
    compiled = jax.jit(g).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text())
    assert res["flops"] == 3 * 2 * 32 ** 3           # unchanged by the ew term
    # the loop body's add+exp dominate: at least 3 * (1 + 8) * 32*32, and
    # bounded by a small multiple of it (XLA may add a few bookkeeping
    # elementwise ops, e.g. iota/compare on the induction variable)
    ew = res["elementwise_flops"]
    assert ew >= 3 * 9 * 32 * 32
    assert ew <= 3 * 9 * 32 * 32 + 3 * 4 * 32 * 32 + 1024


def test_gather_instruction_counter():
    """`gather_instructions` lists gather / dynamic-slice ops per kind
    with result bytes — fusion bodies included, each once, collectives
    (all-gather) NOT miscounted as gathers."""
    def g(x, idx):
        y = jnp.take(x, idx, axis=1)               # gather
        z = jax.lax.dynamic_slice(x, (0, 0), (8, 16))   # dynamic-slice
        return y.sum() + z.sum()
    compiled = jax.jit(g).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.int32)).compile()
    got = gather_instructions(compiled.as_text())
    kinds = [k for k, _ in got]
    assert kinds.count("gather") == 1
    # the gather's result is (8, 16) f32
    assert dict(got)["gather"] == 8 * 16 * 4

    def h(x):
        return jnp.tanh(x) * 2.0                   # purely elementwise
    compiled = jax.jit(h).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    assert gather_instructions(compiled.as_text()) == []


def test_hlo_analyzer_elementwise_weights_from_text():
    """Deterministic check on hand-written HLO: weights 1 / 4 / 8 and the
    while trip-count multiplier."""
    txt = """
body (p.0: (f32[8,4], s32[])) -> (f32[8,4], s32[]) {
  p = (f32[8,4], s32[]) parameter(0)
  t = f32[8,4] get-tuple-element(%p), index=0
  iv = s32[] get-tuple-element(%p), index=1
  a = f32[8,4] add(%t, %t)
  d = f32[8,4] divide(%a, %t)
  e = f32[8,4] exponential(%d)
  one = s32[] constant(1)
  ivn = s32[] add(%iv, %one)
  ROOT r = (f32[8,4], s32[]) tuple(%e, %ivn)
}
cond (p.1: (f32[8,4], s32[])) -> pred[] {
  p = (f32[8,4], s32[]) parameter(0)
  iv = s32[] get-tuple-element(%p), index=1
  k = s32[] constant(5)
  ROOT lt = pred[] compare(%iv, %k), direction=LT
}
ENTRY main (x.0: f32[8,4]) -> f32[8,4] {
  x = f32[8,4] parameter(0)
  zero = s32[] constant(0)
  init = (f32[8,4], s32[]) tuple(%x, %zero)
  w = (f32[8,4], s32[]) while(%init), condition=%cond, body=%body
  ROOT out = f32[8,4] get-tuple-element(%w), index=0
}
"""
    res = analyze_hlo(txt)
    # per iteration: add 32 elems, divide 4*32, exponential 8*32, and the
    # scalar induction add (1); cond: compare (1) — all x trip count 5
    assert res["elementwise_flops"] == 5 * (32 + 4 * 32 + 8 * 32 + 1 + 1)
    assert res["flops"] == 0


def test_rowsharded_quantizer_matches_single_device(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import CLAQConfig, quantize_matrix
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
X = rng.normal(size=(256, 64)).astype(np.float32)
H = jnp.asarray(2 * X.T @ X)
cfg = CLAQConfig(bits=3, method="kmeans", kmeans_iters=5, gptq_blocksize=32)
qt1, Q1, st1 = quantize_matrix(W, H, cfg)
mesh = jax.make_mesh((8,), ("model",))
qt8, Q8, st8 = quantize_matrix(W, H, cfg, mesh=mesh, shard_axis="model")
np.testing.assert_allclose(np.asarray(Q1), np.asarray(Q8), rtol=1e-4, atol=1e-5)
assert abs(st1.proxy_loss - st8.proxy_loss) / max(st1.proxy_loss, 1e-9) < 1e-3
print("rowsharded parity OK")
""")


def test_compressed_psum_error_feedback(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compat import shard_map
from repro.optim import compressed_psum
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
gs = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
err0 = jnp.zeros((8, 32), jnp.float32)

def body(g, e):
    out, new_e = compressed_psum({"g": g}, {"g": e}, "data")
    return out["g"], new_e["g"]

fn = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")), check_vma=False)
out, err = fn(gs, err0)
true_mean = np.asarray(gs).mean(axis=0)
# every shard holds the same compressed mean, error bounded by int8 step
got = np.asarray(out)
for i in range(8):
    assert np.allclose(got[i], got[0])
scale = np.abs(np.asarray(gs)).max() / 127
assert np.max(np.abs(got[0] - true_mean)) <= scale + 1e-6
# error feedback: residual equals what compression dropped
assert np.max(np.abs(np.asarray(err))) <= scale + 1e-6
print("compressed psum OK")
""")


def test_small_mesh_dryrun_lower_compile(subproc):
    """The dry-run path end-to-end on a 2x4 debug mesh with a smoke config:
    proves the sharding rules + constraints lower on multi-device."""
    subproc("""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_smoke_config, SHAPES_BY_NAME
from repro.dist import sharding as shd, context as dctx
from repro.models import api
from repro.optim import OptimConfig, OptState, init_opt_state
from repro.train import make_train_step

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_smoke_config("llama1_7b"),
                          d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                          d_ff=128, vocab=256)
param_sds = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg))
params = shd.with_shardings(param_sds, shd.spec_for_param, cfg, mesh)
ocfg = OptimConfig()
opt_sds = jax.eval_shape(lambda p: init_opt_state(p, ocfg), param_sds)
opt = OptState(
    m=shd.with_shardings(opt_sds.m, shd.spec_for_param, cfg, mesh),
    v=shd.with_shardings(opt_sds.v, shd.spec_for_param, cfg, mesh),
    step=jax.ShapeDtypeStruct((), jnp.int32,
        sharding=jax.NamedSharding(mesh, jax.sharding.PartitionSpec())),
    err=None)
batch = shd.with_shardings({"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)},
                           shd.spec_for_batch, cfg, mesh)
with mesh, dctx.use_mesh(mesh):
    step = make_train_step(cfg, ocfg)
    compiled = jax.jit(step).lower(params, opt, batch).compile()
assert compiled.memory_analysis() is not None
print("small-mesh dryrun OK")
""")


def test_multi_device_train_step_runs(subproc):
    """Actually EXECUTE a sharded train step on 8 devices (not just lower)
    and check the loss matches the single-device value."""
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.dist import sharding as shd, context as dctx
from repro.models import api
from repro.optim import OptimConfig, init_opt_state
from repro.train import make_train_step
from repro.data import DataConfig, SyntheticCorpus

cfg = dataclasses.replace(get_smoke_config("llama1_7b"),
                          d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                          d_ff=128, vocab=256, dtype="float32")
params = api.init_params(jax.random.PRNGKey(0), cfg)
ocfg = OptimConfig(lr=1e-2, warmup_steps=1, total_steps=10)
opt = init_opt_state(params, ocfg)
data = SyntheticCorpus(DataConfig(vocab=256, seq_len=32, batch=8, seed=0))
batch = {"tokens": data.batch_at(0)}

step = jax.jit(make_train_step(cfg, ocfg))
_, _, m_single = step(params, opt, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
pshard = shd.tree_shardings(params, shd.spec_for_param, cfg, mesh)
params_d = jax.device_put(params, pshard)
opt_d = init_opt_state(params_d, ocfg)
with mesh, dctx.use_mesh(mesh):
    stepd = jax.jit(make_train_step(cfg, ocfg))
    _, _, m_multi = stepd(params_d, opt_d, batch)
assert abs(float(m_single["loss"]) - float(m_multi["loss"])) < 1e-3, (
    float(m_single["loss"]), float(m_multi["loss"]))
print("multi-device execution OK", float(m_multi["loss"]))
""", devices=8, timeout=600)
