"""Per-architecture smoke tests (reduced configs): forward/train step on
CPU, output shapes + finiteness; prefill+decode vs full-forward parity;
chunked-vs-recurrent parity for the SSM families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import api
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rw

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def _batch(cfg, rng):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(rng, (B, S, cfg.d_model)),
                "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.modality == "vision":
        P = int(S * cfg.prefix_frac)
        return {"tokens": jax.random.randint(rng, (B, S - P), 0, cfg.vocab),
                "prefix_embeds": jax.random.normal(rng, (B, P, cfg.d_model))}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng, cfg)
    batch = _batch(cfg, rng)

    loss, metrics = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), arch
    assert 1.0 < float(metrics["nll"]) < 20.0, arch

    # one grad step: finite grads, params change
    g = jax.grad(lambda p: api.loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.PRNGKey(1)
    params = api.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    cache = api.make_cache(cfg, B, 64,
                           src_len=(S if cfg.family == "encdec" else None),
                           dtype=jnp.float32)
    logits, cache = jax.jit(
        lambda p, b, c: api.prefill_step(p, cfg, b, c))(params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(2):
        logits, cache = jax.jit(
            lambda p, t, c: api.decode_step(p, cfg, t, c))(params, tok, cache)
        assert jnp.all(jnp.isfinite(logits)), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama1_7b", "qwen3_32b", "rwkv6_7b",
                                  "zamba2_1p2b", "deepseek_v2_236b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(t0..tn) + decode(t_{n+1}) logits == full forward logits.

    MoE capacity dropping depends on token count, so parity tests run with
    a no-drop capacity factor (the effect itself is exercised in
    test_moe_capacity_drops below)."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    rng = jax.random.PRNGKey(2)
    params = api.init_params(rng, cfg)
    toks = jax.random.randint(rng, (B, 12), 0, cfg.vocab)

    from repro.models import transformer as tf
    full_logits, _, _ = tf.forward(params, cfg, toks)

    cache = api.make_cache(cfg, B, 32, dtype=jnp.float32)
    logits_p, cache = api.prefill_step(params, cfg, {"tokens": toks[:, :8]},
                                       cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, 7]),
                               rtol=5e-2, atol=5e-2)
    logits_d = logits_p
    for i in range(8, 12):
        logits_d, cache = api.decode_step(params, cfg, toks[:, i], cache)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full_logits[:, i]),
                                   rtol=5e-2, atol=5e-2)


def test_mamba_chunked_vs_recurrent():
    cfg = get_smoke_config("zamba2_1p2b")
    rng = jax.random.PRNGKey(3)
    p = m2.mamba_init(rng, cfg)
    x = jax.random.normal(rng, (2, 24, cfg.d_model)) * 0.3

    y_full, _ = m2.mamba_block(p, x, cfg, cache=None)

    cache = m2.init_mamba_cache(2, cfg, jnp.float32)
    outs = []
    for t in range(24):
        y, cache = m2.mamba_block(p, x[:, t:t + 1], cfg, cache)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-2, atol=2e-2)


def test_rwkv_chunked_vs_recurrent():
    cfg = get_smoke_config("rwkv6_7b")
    rng = jax.random.PRNGKey(4)
    H, N = rw.rwkv_dims(cfg)
    T = 20
    r, k, v = (jax.random.normal(jax.random.fold_in(rng, i), (2, T, H, N)) * 0.5
               for i in range(3))
    logw = -jnp.exp(jax.random.normal(rng, (2, T, H, N)) * 0.3 - 1.0)
    u = jax.random.normal(rng, (H, N)) * 0.2

    out_c, final_c = rw._wkv_chunked(r, k, v, logw, u, chunk=8)

    # exact recurrence
    S = jnp.zeros((2, H, N, N))
    outs = []
    for t in range(T):
        rt, kt, vt = r[:, t], k[:, t], v[:, t]
        o = jnp.einsum("bhn,bhnm->bhm", rt, S) + \
            jnp.einsum("bhn,hn,bhn,bhm->bhm", rt, u, kt, vt)
        S = S * jnp.exp(logw[:, t])[..., None] + \
            jnp.einsum("bhn,bhm->bhnm", kt, vt)
        outs.append(o)
    out_r = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(final_c), np.asarray(S),
                               rtol=1e-3, atol=1e-3)


def test_scan_vs_unrolled_forward_equal():
    cfg = get_smoke_config("llama1_7b")
    rng = jax.random.PRNGKey(5)
    params = api.init_params(rng, cfg)
    toks = jax.random.randint(rng, (B, 16), 0, cfg.vocab)
    from repro.models import transformer as tf
    l_scan, _, _ = tf.forward(params, cfg, toks)
    l_unroll, _, _ = tf.forward(params, cfg, toks, unroll=True)
    np.testing.assert_allclose(np.asarray(l_scan), np.asarray(l_unroll),
                               rtol=1e-4, atol=1e-4)


def test_full_configs_have_published_shapes():
    from repro.configs import get_config
    c = get_config("qwen3_32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (64, 5120, 64, 8, 25600)
    c = get_config("deepseek_v2_236b")
    assert (c.n_experts, c.top_k, c.kv_lora, c.q_lora) == (160, 6, 512, 1536)
    c = get_config("rwkv6_7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 4096, 14336, 65536)
    c = get_config("zamba2_1p2b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (38, 2048, 64)


def test_moe_capacity_drops():
    """Capacity-bounded dispatch actually drops overflow tokens (GShard
    semantics) and the output stays finite."""
    import dataclasses as dc
    from repro.models import moe as moe_lib
    cfg = get_smoke_config("qwen3_moe_30b_a3b")
    tight = dc.replace(cfg, capacity_factor=0.25)
    rng = jax.random.PRNGKey(0)
    p = moe_lib.moe_init(rng, tight)
    x = jax.random.normal(rng, (2, 16, tight.d_model))
    y_tight, _ = moe_lib.moe_mlp(p, x, tight)
    y_loose, _ = moe_lib.moe_mlp(p, x, dc.replace(cfg, capacity_factor=16.0))
    assert jnp.all(jnp.isfinite(y_tight))
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))
