"""Property tests for the 1-D K-Means codebook solver (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import kmeans as km

jax.config.update("jax_platform_name", "cpu")


def _rand_column(seed, n, heavy=False):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n,)).astype(np.float32)
    if heavy:
        x[: n // 10] *= 8.0
    return jnp.asarray(x)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(16, 300),
       bits=st.sampled_from([1, 2, 3, 4]))
def test_codes_in_range_and_centroids_sorted(seed, n, bits):
    x = _rand_column(seed, n)
    k = 2 ** bits
    cb, codes = km.kmeans_1d(x, k_max=k, iters=5)
    assert codes.shape == x.shape
    assert int(codes.min()) >= 0 and int(codes.max()) < k
    finite = np.asarray(cb)[np.isfinite(np.asarray(cb))]
    assert np.all(np.diff(finite) >= -1e-6)
    assert finite.min() >= float(x.min()) - 1e-5
    assert finite.max() <= float(x.max()) + 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(32, 200))
def test_more_bits_less_error(seed, n):
    x = _rand_column(seed, n, heavy=True)
    errs = []
    for bits in (1, 2, 3, 4):
        cb, codes = km.kmeans_1d(x, k_max=2 ** bits, iters=8)
        q = jnp.where(jnp.isfinite(cb), cb, 0.0)[codes]
        errs.append(float(jnp.sum((x - q) ** 2)))
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_exact_when_few_unique_values(seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=4).astype(np.float32)
    x = jnp.asarray(rng.choice(vals, size=128))
    cb, codes = km.kmeans_1d(x, k_max=8, iters=20)
    q = jnp.where(jnp.isfinite(cb), cb, 0.0)[codes]
    np.testing.assert_allclose(np.asarray(q), np.asarray(x), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_lloyd_iterations_do_not_increase_inertia(seed):
    x = _rand_column(seed, 128, heavy=True)
    prev = None
    for iters in (1, 3, 6, 12):
        cb, _ = km.kmeans_1d(x, k_max=8, iters=iters)
        inert = float(km.inertia(x, cb))
        if prev is not None:
            assert inert <= prev + 1e-4
        prev = inert


def test_kmeans_beats_uniform_grid_on_heavy_tails():
    """The paper's core claim for §3.1: K-Means codebooks fit the weight
    distribution better than a uniform min-max grid."""
    x = _rand_column(7, 4096, heavy=True)
    k = 8
    cb, codes = km.kmeans_1d(x, k_max=k, iters=10)
    err_km = float(km.inertia(x, cb))
    grid = jnp.linspace(float(x.min()), float(x.max()), k)
    err_uniform = float(km.inertia(x, grid))
    assert err_km < err_uniform * 0.8


def test_weight_zero_elements_are_excluded():
    x = jnp.concatenate([jnp.linspace(-1, 1, 64), jnp.asarray([100.0])])
    w = jnp.concatenate([jnp.ones(64), jnp.zeros(1)])
    cb, _ = km.kmeans_1d(x, k_max=4, iters=10, weight=w)
    finite = np.asarray(cb)[np.isfinite(np.asarray(cb))]
    assert finite.max() < 2.0  # outlier did not drag any centroid


def test_dynamic_k_valid():
    x = _rand_column(3, 256)
    cb4, _ = km.kmeans_1d(x, k_max=16, k_valid=4, iters=8)
    n_finite = int(np.isfinite(np.asarray(cb4)).sum())
    assert n_finite == 4


def test_kmeans_columns_matches_single():
    W = jnp.stack([_rand_column(i, 96) for i in range(5)], axis=1)
    cbs, codes = km.kmeans_columns(W, k_max=8, iters=6)
    for j in range(5):
        cb1, codes1 = km.kmeans_1d(W[:, j], k_max=8, iters=6)
        np.testing.assert_allclose(np.asarray(cbs[j]), np.asarray(cb1),
                                   rtol=1e-5, atol=1e-6)
