"""Opt-in int8 activation quantization + the gather-free decode hot path
(DESIGN.md §9): per-token absmax quantization units, the documented error
bound against the f32 path across bit-widths and gather modes, engine
wiring of ``act_dtype``, and the HLO-level claim the tentpole is about —
a kernel-mode decode step over integer-bit CLAQ plans compiles to the
SAME number of gather instructions as the dense model's decode step (the
quantized matmul path contributes zero; shared rule `HLO-GA1` from
`repro.analysis`)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import CLAQConfig
from repro.data import calibration_set
from repro.kernels import ops, ref as ref_lib
from repro.kernels.plan import prepare_for_inference
from repro.launch.quantize import claq_quantize
from repro.models import api
from repro.models import modules as nn
from repro.serve import ServingEngine, SpecConfig

from test_plan import _make_qt

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- units

def test_quantize_activations_units():
    x = jnp.asarray([[0.5, -2.0, 1.0, 0.0],
                     [0.0, 0.0, 0.0, 0.0],       # all-zero row: scale 1
                     [127.0, -127.0, 3.0, -3.0]], jnp.float32)
    xq, scale = ops.quantize_activations(x)
    assert xq.dtype == jnp.int8 and scale.shape == (3, 1)
    # the row max always quantizes to exactly +-127 (absmax scaling)
    assert int(jnp.max(jnp.abs(xq[0]))) == 127
    assert int(jnp.max(jnp.abs(xq[2]))) == 127
    np.testing.assert_array_equal(np.asarray(xq[1]), 0)
    assert float(scale[1, 0]) == 1.0
    # reconstruction error bounded by scale/2 per element
    err = jnp.abs(xq.astype(jnp.float32) * scale - x)
    assert bool(jnp.all(err <= scale / 2 + 1e-7))


def test_act_dtype_rejected_without_plan():
    rng = np.random.default_rng(0)
    qt = _make_qt(rng, rows=32, stripe_spec=[(2, 48)])
    x = jnp.asarray(rng.normal(size=(3, 48)).astype(np.float32))
    with pytest.raises(ValueError, match="plan"):
        ops.qmatmul(x, qt, use_kernel=True, act_dtype="int8")
    with pytest.raises(ValueError, match="act_dtype"):
        ops.prepared_qmatmul(x, prepare_for_inference(qt),
                             act_dtype="int4")


@pytest.mark.parametrize("spec,k_out", [
    ([(2, 96)], 0),                   # aligned via identity (random perm
    ([(3, 140)], 2),                  # here -> gathered; both layouts run)
    ([(2, 80), (4, 48)], 3),          # mixed precision, two launches
])
def test_int8_error_bound_all_paths(spec, k_out):
    """The int8 path's deviation from the f32 reference stays under the
    analytic bound scale/2 * ||W||_1 on every dispatch: in-kernel gather,
    XLA gather (bitwise-identical pair), and the XLA ref path."""
    rng = np.random.default_rng(sum(b for b, _ in spec) + k_out)
    qt = _make_qt(rng, rows=64, stripe_spec=spec, k_out=k_out)
    pqt = prepare_for_inference(qt)
    x = jnp.asarray(rng.normal(size=(5, qt.cols)).astype(np.float32))
    y_ref = ref_lib.ref_qmatmul(x, qt)
    bound = np.asarray(ref_lib.ref_act_int8_bound(x, qt.dequantize()))
    bound = bound * 1.01 + 1e-5       # epsilon for f32 accumulation order

    y_ker = ops.prepared_qmatmul(x, pqt, act_dtype="int8")
    y_pre = ops.prepared_qmatmul(x, pqt, gather="xla", act_dtype="int8")
    y_xla = ops.qmatmul(x, pqt, use_kernel=False, act_dtype="int8")
    assert np.array_equal(np.asarray(y_ker), np.asarray(y_pre)), \
        "int8 gather modes must match bitwise (same values, same order)"
    for y in (y_ker, y_xla):
        err = np.abs(np.asarray(y - y_ref))
        assert (err <= bound).all(), (err.max(), bound.max())
    # int8 really quantized: a generic random layout perturbs the output
    assert not np.array_equal(np.asarray(y_ker), np.asarray(y_ref))


def test_int8_bound_scales_with_activations():
    """The bound is per-token: scaling one token's activations scales
    exactly its row of the bound by the same factor, leaving other rows
    untouched."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    b0 = np.asarray(ref_lib.ref_act_int8_bound(x, W))
    b1 = np.asarray(ref_lib.ref_act_int8_bound(x.at[2].multiply(100.0), W))
    np.testing.assert_allclose(b1[2], 100.0 * b0[2], rtol=1e-5)
    np.testing.assert_array_equal(b1[[0, 1, 3]], b0[[0, 1, 3]])


# ------------------------------------------------- engine + compiled HLO

@pytest.fixture(scope="module")
def int_bit_quantized():
    """Integer-bit (3-bit, no AP/OR) quantized smoke model: every matrix
    is single-stripe with an identity permutation, so all plans are
    x-aligned — the configuration whose decode must compile gather-free."""
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=64,
                              n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = CLAQConfig(bits=3, method="uniform", gptq_blocksize=64)
    calib = calibration_set(vocab=cfg.vocab, n_segments=2, seq_len=16)
    qparams, _ = claq_quantize(params, cfg, calib, qcfg)
    return cfg, params, qparams


def test_engine_act_dtype_int8_serves(int_bit_quantized):
    cfg, _, qparams = int_bit_quantized
    eng = ServingEngine(qparams, cfg, n_slots=2, max_len=32, min_bucket=8,
                        act_dtype="int8")
    assert eng.stats()["act_dtype"] == "int8"
    uids = eng.add_requests([[1, 2, 3], [4, 5, 6, 7]], max_new_tokens=4)
    eng.run_to_completion()
    fin = eng.take_finished()
    assert all(len(fin[u].tokens) == 4 for u in uids)


def test_engine_act_dtype_validation(int_bit_quantized):
    cfg, _, qparams = int_bit_quantized
    with pytest.raises(ValueError, match="act_dtype"):
        ServingEngine(qparams, cfg, n_slots=2, max_len=32, act_dtype="int4")
    with pytest.raises(ValueError, match="prepare"):
        ServingEngine(qparams, cfg, n_slots=2, max_len=32,
                      act_dtype="int8", prepare=False)
    with pytest.raises(ValueError, match="draft_plan"):
        ServingEngine(qparams, cfg, n_slots=2, max_len=32,
                      draft_plan_bn=32)
    # draft tile overrides shape the draft's PLANS — meaningless (and
    # previously silently ignored) without preparation
    with pytest.raises(ValueError, match="prepare"):
        ServingEngine(qparams, cfg, n_slots=2, max_len=32, prepare=False,
                      draft_plan_bn=32, draft_params=qparams,
                      spec=SpecConfig(gamma=2, draft_bits=2))


def test_kernel_decode_step_adds_zero_gathers(int_bit_quantized):
    """THE hot-path claim: with the stripe-permutation gather folded into
    the kernel, a kernel-mode decode step over integer-bit CLAQ plans
    compiles to exactly as many gather instructions as the DENSE model's
    decode step — the quantized matmul path contributes none (it used to
    contribute one XLA activation gather per matmul).  Holds for f32 and
    int8 activations (quantization is elementwise).  Enforced through the
    shared HLO-GA1 rule (repro.analysis), the same check
    ``verify_contracts=True`` runs at engine init."""
    from repro.analysis import REGISTRY, run_rules
    from repro.analysis.artifacts import lowered_decode_text, plan_stats

    cfg, params, qparams = int_bit_quantized

    def decode_hlo(p, act_dtype=None):
        eng = ServingEngine(p, cfg, n_slots=2, max_len=32,
                            act_dtype=act_dtype)
        return eng, lowered_decode_text(eng)

    _, dense_txt = decode_hlo(params)
    eng_q, quant_txt = decode_hlo(qparams)
    _, quant_i8_txt = decode_hlo(qparams, act_dtype="int8")

    plan = plan_stats(eng_q.params, n_slots=2)
    assert plan["has_plans"] and plan["n_permuted_groups"] == 0, \
        "integer-bit plans must be all-aligned, else the check is vacuous"
    for txt in (quant_txt, quant_i8_txt):
        rep = run_rules([REGISTRY["HLO-GA1"]],
                        {"hlo": {"decode": txt},
                         "dense_hlo": {"decode": dense_txt}, "plan": plan})
        assert rep.rules_run == ["HLO-GA1"] and not rep.findings, \
            rep.render()
