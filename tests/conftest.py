import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 300):
    """Run `code` in a fresh python with N forced host devices (the main
    test process must keep seeing 1 device, per the dry-run contract)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess
