"""Unit tests for the quantized-leaf sharding rules (dist/sharding.py).

A PreparedQuantizedTensor is sharded AS A UNIT along N: packed code planes
split on their packed-row axis in whole (bn, bk) tiles, codebooks /
outlier tables / gather index replicated.  These tests pin the
PartitionSpecs at model sizes that do and do not divide the tile count
(``n_padded // bn``) — a non-dividing mesh must replicate the WHOLE unit,
never tear it — including stacked (L, ...) leaves, plus the stacked-cache
rule and the spec_for_param guard against quantized internals.

Rules are pure `(name, shape | unit, ax) -> PartitionSpec` functions, so
they are tested with a duck-typed MeshAxes stand-in — no multi-device
runtime needed (tests/test_dist_serving.py covers real execution).
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.quantized import QuantStripe, QuantizedTensor
from repro.dist import sharding as shd
from repro.kernels.plan import PreparedQuantizedTensor, prepare_for_inference

jax.config.update("jax_platform_name", "cpu")


def _ax(model=1, dp=1):
    return types.SimpleNamespace(model_size=model, dp_size=dp,
                                 model="model" if model > 1 else None,
                                 dp="data" if dp > 1 else None)


def _make_qt(rng, rows, stripe_spec, k_out=0):
    """Synthetic multi-stripe QuantizedTensor (same shape family as
    tests/test_plan.py)."""
    cols = sum(n for _, n in stripe_spec)
    stripes = []
    for bits, n_cols in stripe_spec:
        codes = rng.integers(0, 2 ** bits, size=(rows, n_cols)).astype(np.int32)
        cb = np.sort(rng.normal(size=(n_cols, 2 ** bits)).astype(np.float32),
                     axis=1)
        stripes.append(QuantStripe(
            packed=packing.pack_codes(jnp.asarray(codes), bits),
            codebook=jnp.asarray(cb), bits=bits))
    col_perm = jnp.asarray(rng.permutation(cols).astype(np.int32))
    if k_out > 0:
        oi = np.stack([rng.permutation(rows)[:k_out] for _ in range(cols)],
                      axis=1).astype(np.int32)
        ov = rng.normal(size=(k_out, cols)).astype(np.float32)
        cnt = rng.integers(0, k_out + 1, size=(cols,)).astype(np.int32)
    else:
        oi = np.zeros((0, cols), np.int32)
        ov = np.zeros((0, cols), np.float32)
        cnt = np.zeros((cols,), np.int32)
    return QuantizedTensor(
        stripes=tuple(stripes), col_perm=col_perm,
        out_idx=jnp.asarray(oi), out_val=jnp.asarray(ov),
        out_count=jnp.asarray(cnt), shape=(rows, cols))


def _specs_by_field(pqt, specs):
    """{field_name: [spec, ...]} for a prepared unit's spec tree."""
    out = {}
    for path, spec in jax.tree_util.tree_flatten_with_path(specs)[0]:
        name = jax.tree_util.keystr(path)
        for field in ("planes", "codebook", "out_idx", "out_val",
                      "gather_idx"):
            if f".{field}" in name:
                out.setdefault(field, []).append(spec)
    return out


# ------------------------------------------------------- prepared unit rule

def test_prepared_unit_shards_planes_along_n_when_tiles_divide():
    rng = np.random.default_rng(0)
    # rows=128, bn=32 -> 4 whole (bn, bk) tiles: divides model_size=4
    pqt = prepare_for_inference(
        _make_qt(rng, 128, [(2, 80), (4, 48)], k_out=3), bn=32)
    assert pqt.n_tiles == 4 and pqt.shards_whole_tiles(4)
    specs = _specs_by_field(pqt, shd.spec_for_quantized(pqt, _ax(model=4)))
    assert specs["planes"] and all(s == P("model", None)
                                   for s in specs["planes"])
    for field in ("codebook", "out_idx", "out_val", "gather_idx"):
        assert specs[field] and all(s == P() for s in specs[field])


@pytest.mark.parametrize("rows,model", [
    (96, 4),    # 3 tiles % 4 != 0
    (128, 3),   # 4 tiles % 3 != 0
    (32, 4),    # single tile
])
def test_prepared_unit_replicates_when_tiles_do_not_divide(rows, model):
    """A non-dividing mesh must replicate the WHOLE unit — a torn group
    (planes sharded while the codebook or gather index splits elsewhere,
    or a shard holding a partial (bn, bk) tile) is never produced."""
    rng = np.random.default_rng(rows)
    pqt = prepare_for_inference(_make_qt(rng, rows, [(2, 64)], k_out=2),
                                bn=32)
    assert not pqt.shards_whole_tiles(model)
    specs = shd.spec_for_quantized(pqt, _ax(model=model))
    assert all(s == P() for s in jax.tree_util.tree_leaves(specs))


def test_prepared_unit_stacked_leaves_shard_packed_row_axis():
    """launch.quantize stacks per-layer tensors: data leaves carry a
    leading (L,) dim while plan meta stays per-matrix.  The unit rule must
    shard the packed-row axis (-2), not the stack axis."""
    rng = np.random.default_rng(7)
    qt = _make_qt(rng, 128, [(2, 64), (4, 32)], k_out=2)
    stacked = jax.tree_util.tree_map(lambda a: jnp.stack([a, a, a]), qt)
    pqt = prepare_for_inference(stacked, bn=32)
    assert pqt.shards_whole_tiles(4)
    specs = _specs_by_field(pqt, shd.spec_for_quantized(pqt, _ax(model=4)))
    assert all(s == P(None, "model", None) for s in specs["planes"])
    for field in ("codebook", "out_idx", "out_val", "gather_idx"):
        assert all(s == P() for s in specs[field])


def test_x_idx_tables_replicate_with_the_unit():
    """The per-bk-block x index tables (the in-kernel gather's operand)
    index the ACTIVATION's K axis, so they replicate like gather_idx —
    both when the unit shards along N and when it stacks — and aligned
    plans (identity permutation) carry no tables at all, so the spec tree
    stays leaf-congruent for device_put either way."""
    rng = np.random.default_rng(29)
    qt = _make_qt(rng, 128, [(2, 80), (4, 48)], k_out=2)   # permuted
    for stack in (False, True):
        q = qt if not stack else jax.tree_util.tree_map(
            lambda a: jnp.stack([a, a]), qt)
        pqt = prepare_for_inference(q, bn=32)
        assert not pqt.x_gather_free
        specs = shd.spec_for_quantized(pqt, _ax(model=4))
        found = []
        for path, spec in jax.tree_util.tree_flatten_with_path(specs)[0]:
            if ".x_idx" in jax.tree_util.keystr(path):
                found.append(spec)
        assert found and all(s == P() for s in found)
        # spec tree must mirror the unit leaf-for-leaf (device_put contract)
        assert (jax.tree_util.tree_structure(specs)
                == jax.tree_util.tree_structure(
                    jax.tree_util.tree_map(lambda _: P(), pqt)))

    # aligned plan: x_idx is None everywhere, structure still congruent
    ident = QuantizedTensor(
        stripes=qt.stripes[:1],
        col_perm=jnp.arange(qt.stripes[0].n_cols, dtype=jnp.int32),
        out_idx=qt.out_idx[:, :qt.stripes[0].n_cols],
        out_val=qt.out_val[:, :qt.stripes[0].n_cols],
        out_count=qt.out_count[:qt.stripes[0].n_cols],
        shape=(128, qt.stripes[0].n_cols))
    pqt = prepare_for_inference(ident, bn=32)
    assert pqt.x_gather_free
    specs = shd.spec_for_quantized(pqt, _ax(model=4))
    assert (jax.tree_util.tree_structure(specs)
            == jax.tree_util.tree_structure(
                jax.tree_util.tree_map(lambda _: P(), pqt)))


def test_word_unaligned_bn_replicates():
    """A plan built with bn below the 32-row packing word (bn=16) has tile
    boundaries that fall mid-word for width-1 planes (3-bit high plane
    packs 32 rows/word: 96 rows -> 3 packed rows, unsplittable by 2), so
    the guard must replicate even though the tile COUNT divides — a
    sharded spec would crash device_put on the indivisible plane axis."""
    rng = np.random.default_rng(13)
    pqt = prepare_for_inference(_make_qt(rng, 96, [(3, 64)], k_out=1),
                                bn=16)
    assert pqt.n_tiles % 2 == 0 and not pqt.shards_whole_tiles(2)
    specs = shd.spec_for_quantized(pqt, _ax(model=2))
    assert all(s == P() for s in jax.tree_util.tree_leaves(specs))


def test_raw_quantized_tensor_replicates_as_a_unit():
    """The pre-deployment format has no tile-clean row split (3-bit packs
    two planes concatenated along packed rows) — replicate, never tear."""
    rng = np.random.default_rng(3)
    qt = _make_qt(rng, 128, [(3, 64)], k_out=2)
    specs = shd.spec_for_quantized(qt, _ax(model=4))
    leaves = jax.tree_util.tree_leaves(specs)
    assert leaves and all(s == P() for s in leaves)


def test_spec_for_quantized_rejects_plain_arrays():
    with pytest.raises(TypeError):
        shd.spec_for_quantized(jnp.zeros((4, 4)), _ax(model=4))


def test_single_device_prepared_unit_replicates():
    rng = np.random.default_rng(5)
    pqt = prepare_for_inference(_make_qt(rng, 128, [(2, 64)]), bn=32)
    specs = shd.spec_for_quantized(pqt, _ax(model=1))
    assert all(s == P() for s in jax.tree_util.tree_leaves(specs))


# ------------------------------------------------- per-leaf rule guards

def test_spec_for_param_never_tears_quantized_internals():
    """If a caller maps the generic per-leaf rule over quantized internals
    (the pre-fix failure mode: planes sharded along K, gather_idx along
    its only axis), they replicate instead."""
    ax = _ax(model=4)
    assert shd.spec_for_param(
        "['blocks']['attn']['q']['kernel'].groups[0].planes[0]",
        (2, 8, 128), None, ax) == P()
    assert shd.spec_for_param(
        "['blocks']['mlp']['up']['kernel'].gather_idx", (256,), None,
        ax) == P()
    assert shd.spec_for_param(
        "['blocks']['attn']['k']['kernel'].stripes[0].packed", (8, 64),
        None, ax) == P()
    # dense leaves keep the generic largest-divisible-dim pick
    assert shd.spec_for_param("['embed']['embedding']", (512, 128), None,
                              ax) == P("model", None)


def test_tree_shardings_routes_units_and_stays_leaf_congruent():
    """tree_shardings expands quantized units through the unit rule and
    returns a tree with one NamedSharding per array leaf — the exact
    contract device_put needs."""
    rng = np.random.default_rng(11)
    pqt = prepare_for_inference(_make_qt(rng, 128, [(2, 64)], k_out=1),
                                bn=32)
    params = {"dense": {"kernel": jnp.zeros((16, 8))},
              "q": {"kernel": pqt}}
    mesh = jax.make_mesh((1,), ("model",))
    sh = shd.tree_shardings(params, shd.spec_for_param_serve, None, mesh)
    p_leaves, p_def = jax.tree_util.tree_flatten(params)
    s_leaves, s_def = jax.tree_util.tree_flatten(sh)
    assert p_def == s_def
    assert len(s_leaves) == len(p_leaves)
    assert all(isinstance(s, jax.sharding.NamedSharding) for s in s_leaves)
    # and the annotated-SDS variant agrees leaf-for-leaf
    sds = jax.eval_shape(lambda: params)
    ann = shd.with_shardings(sds, shd.spec_for_param_serve, None, mesh)
    a_leaves, a_def = jax.tree_util.tree_flatten(ann)
    assert a_def == p_def
    assert [a.sharding for a in a_leaves] == s_leaves


# ------------------------------------------------------- stacked cache rule

def test_cache_rule_shards_slot_axis_not_layer_axis():
    """Engine/dry-run caches are stacked (L, B, ...): the serving-slot
    axis is axis 1.  dp must land there — a dp spec on axis 0 would shard
    LAYERS across the data-parallel axis."""
    ax = _ax(model=4, dp=2)
    # stacked KVCache.k (L, B, S, KH, D): slots over dp, KV heads over model
    assert shd.spec_for_cache(".k", (2, 8, 64, 4, 32), None, ax) == \
        P(None, "data", None, "model", None)
    # fill counters (L, B)
    assert shd.spec_for_cache(".length", (2, 8), None, ax) == P(None, "data")
    # MLA c_kv (L, B, S, d_c): rank 4 — the sequence axis must NOT take
    # the head ("model") sharding
    assert shd.spec_for_cache(".c_kv", (2, 8, 64, 32), None, ax) == \
        P(None, "data", None, None)
    # rwkv state (L, B, H, N, N): not a k/v leaf -> dp only
    assert shd.spec_for_cache(".state", (2, 8, 4, 16, 16), None, ax) == \
        P(None, "data", None, None, None)
    # encdec cross-attention banks (L_dec, B, S_src, KH, hd) are KV leaves
    assert shd.spec_for_cache(".cross_k", (2, 8, 64, 4, 32), None, ax) == \
        P(None, "data", None, "model", None)
    assert shd.spec_for_cache(".cross_v", (2, 8, 64, 4, 32), None, ax) == \
        P(None, "data", None, "model", None)


def test_cache_rule_divisibility_guards():
    ax = _ax(model=4, dp=2)
    # 3 slots % dp=2 != 0 -> replicated batch axis
    assert shd.spec_for_cache(".k", (2, 3, 64, 4, 32), None, ax) == \
        P(None, None, None, "model", None)
    # 3 KV heads % model=4 != 0 -> heads replicated
    assert shd.spec_for_cache(".k", (2, 8, 64, 3, 32), None, ax) == \
        P(None, "data", None, None, None)
