"""Serving-engine correctness and prefill length bucketing.

Covers the bucketing acceptance bar — N distinct prompt lengths cost at
most ``ceil(log2(max_len / min_bucket)) + 1`` prefill traces (counted by
a trace-time side effect, not estimated), and bucketed admission emits
token-for-token identical greedy output to unbucketed admission on an
AP+OR-quantized model — plus the decode-loop retirement fixes: EOS at
prefill, a one-token budget, slot reuse after retirement, and
``run_to_completion`` surfacing truncation.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize
from repro.models import api
from repro.serve import BucketingPolicy, ServingEngine

jax.config.update("jax_platform_name", "cpu")


# ----------------------------------------------------------------- policy

def test_bucket_policy_shapes():
    pol = BucketingPolicy(min_bucket=8, max_len=64)
    assert pol.buckets() == (8, 16, 32, 64)
    assert pol.max_traces() == math.ceil(math.log2(64 / 8)) + 1 == 4
    assert pol.bucket_for(1) == 8
    assert pol.bucket_for(8) == 8
    assert pol.bucket_for(9) == 16
    assert pol.bucket_for(33) == 64
    with pytest.raises(ValueError):
        pol.bucket_for(65)
    with pytest.raises(ValueError):
        pol.bucket_for(0)


def test_bucket_policy_non_pow2_max_len():
    pol = BucketingPolicy(min_bucket=16, max_len=100)
    assert pol.buckets() == (16, 32, 64, 100)
    assert pol.bucket_for(70) == 100
    assert len(pol.buckets()) == pol.max_traces() == 4


def test_bucket_policy_disabled_is_identity():
    pol = BucketingPolicy(min_bucket=8, max_len=64, enabled=False)
    assert pol.bucket_for(13) == 13


def test_bucket_policy_compile_cache_stats():
    pol = BucketingPolicy(min_bucket=8, max_len=64)
    assert pol.record(1, 8) is False      # first (batch, bucket): a trace
    assert pol.record(1, 8) is True       # same shape: compile-cache hit
    assert pol.record(2, 8) is False      # new batch size: a trace
    assert pol.stats.misses == 2 and pol.stats.hits == 1
    assert pol.stats.hit_rate == pytest.approx(1 / 3)


# ----------------------------------------------------------------- engine

@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def quantized_model(fp_model):
    """AP+OR fused CLAQ quantization (the paper's deployment format)."""
    cfg, params = fp_model
    qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4,
                      gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                      orr=ORConfig(0.1))
    calib = calibration_set(vocab=cfg.vocab, n_segments=4, seq_len=32)
    qparams, report = claq_quantize(params, cfg, calib, qcfg)
    assert 2.0 < report.mean_effective_bits < 2.6
    return cfg, qparams


def _serve(eng, prompts, max_new, eos_id=None):
    """Admit, run to completion, return token lists in prompt order."""
    uids = eng.add_requests(prompts, max_new_tokens=max_new, eos_id=eos_id)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]


def test_trace_count_bounded_by_buckets(fp_model):
    """≥6 distinct prompt lengths in [1, max_len) cost at most
    ceil(log2(max_len / min_bucket)) + 1 prefill traces.  The bound is
    also enforced by the shared TRC-CC1/TRC-SG1 rules over the engine's
    TraceSentinel — the same check ``verify_contracts=True`` runs."""
    from repro.analysis import REGISTRY, run_rules
    from repro.analysis.artifacts import compile_budgets, trace_counts

    cfg, params = fp_model
    lengths = [1, 3, 7, 9, 20, 40, 63]
    prompts = [list(range(1, n + 1)) for n in lengths]

    eng = ServingEngine(params, cfg, n_slots=2, max_len=64, min_bucket=8)
    for p in prompts:
        # one-token budget: each request retires at admission, so a
        # 2-slot engine admits any number of distinct lengths
        eng.add_request(p, max_new_tokens=1)
    bound = math.ceil(math.log2(64 / 8)) + 1
    assert eng.bucketing.max_traces() == bound
    assert eng.prefill_traces <= bound, eng.stats()
    assert eng.stats()["bucket_misses"] == eng.prefill_traces

    rep = run_rules([REGISTRY["TRC-CC1"], REGISTRY["TRC-SG1"]],
                    {"sentinel": eng.sentinel,
                     "compile_budget": compile_budgets(eng),
                     "trace_counts": trace_counts(eng)})
    assert rep.rules_run == ["TRC-CC1", "TRC-SG1"] and not rep.findings, \
        rep.render()
    assert eng.sentinel.distinct("prefill") == eng.prefill_traces

    # without bucketing every distinct length is its own compile
    eng2 = ServingEngine(params, cfg, n_slots=2, max_len=64,
                         bucketing=False)
    for p in prompts:
        eng2.add_request(p, max_new_tokens=1)
    assert eng2.prefill_traces == len(lengths)
    assert eng2.prefill_traces > eng.prefill_traces


def test_bucketed_matches_unbucketed_on_quantized_model(quantized_model):
    """Greedy tokens are identical with and without padding to buckets,
    on the AP+OR-quantized weights flowing through prepared plans."""
    cfg, qparams = quantized_model
    prompts = [[1, 2], [3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15, 16],
               [20, 21, 22, 23, 24, 25, 26, 27, 28, 29, 30, 31, 32]]

    eng_b = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_b = _serve(eng_b, prompts, max_new=6)
    eng_u = ServingEngine(qparams, cfg, n_slots=4, max_len=64,
                          bucketing=False)
    toks_u = _serve(eng_u, prompts, max_new=6)

    assert toks_b == toks_u
    assert all(len(t) == 6 for t in toks_b)
    assert eng_b.prefill_traces < eng_u.prefill_traces


def test_bucketed_matches_unbucketed_with_int8_activations(quantized_model):
    """The opt-in int8 activation path keeps the engine's structural
    invariants: activation quantization is per-token (elementwise per
    position), so bucketed admission still emits tokens bit-identical to
    unbucketed admission under act_dtype='int8' — and the int8 engine
    runs the same machinery end to end on the AP+OR plans."""
    cfg, qparams = quantized_model
    prompts = [[1, 2], [3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15, 16]]

    eng_b = ServingEngine(qparams, cfg, n_slots=3, max_len=64, min_bucket=8,
                          act_dtype="int8")
    toks_b = _serve(eng_b, prompts, max_new=5)
    eng_u = ServingEngine(qparams, cfg, n_slots=3, max_len=64,
                          bucketing=False, act_dtype="int8")
    toks_u = _serve(eng_u, prompts, max_new=5)

    assert toks_b == toks_u
    assert all(len(t) == 5 for t in toks_b)
    assert eng_b.stats()["act_dtype"] == "int8"


def test_ap_kernel_decode_gathers_are_tile_sized(quantized_model, fp_model):
    """Mixed-precision (AP) plans cannot drop indexing entirely — the
    kernel takes each tile's columns from a VMEM-resident x block.  The
    compiled kernel-mode decode step may therefore add gathers over the
    dense baseline, but every one of them must be a TILE-sized in-kernel
    take, never the old activation-sized XLA gather (whose result spans
    the whole fused K axis of a matmul).  The byte cap, the
    count-per-permuted-group cap, and the multiset diff against the dense
    baseline all live in the shared HLO-GA1 rule (repro.analysis)."""
    from repro.analysis import REGISTRY, run_rules
    from repro.analysis.artifacts import lowered_decode_text, plan_stats

    cfg, qparams = quantized_model
    _, params = fp_model

    def decode_hlo(p):
        eng = ServingEngine(p, cfg, n_slots=2, max_len=32)
        return eng, lowered_decode_text(eng)

    _, dense_txt = decode_hlo(params)
    eng_q, quant_txt = decode_hlo(qparams)

    plan = plan_stats(eng_q.params, n_slots=2)
    assert plan["n_permuted_groups"] > 0, \
        "AP model produced no permuted plan -> vacuous"
    rep = run_rules([REGISTRY["HLO-GA1"]],
                    {"hlo": {"decode": quant_txt},
                     "dense_hlo": {"decode": dense_txt}, "plan": plan})
    assert rep.rules_run == ["HLO-GA1"] and not rep.findings, rep.render()


def test_batched_admission_shares_one_prefill(fp_model):
    """Prompts in the same bucket are admitted in ONE batched prefill and
    match one-at-a-time admission token for token."""
    cfg, params = fp_model
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9, 10, 11, 12]]  # bucket 8

    eng = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_batched = _serve(eng, prompts, max_new=5)
    assert eng.prefill_traces == 1, eng.stats()

    # the admission batch size is bucketed too: a different group size in
    # the same (padded) shape class reuses the compile
    toks_again = _serve(eng, prompts + [[13, 14]], max_new=5)
    assert eng.prefill_traces == 1, eng.stats()
    assert toks_again[:3] == toks_batched

    eng1 = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_single = []
    for p in prompts:
        toks_single += _serve(eng1, [p], max_new=5)
    assert toks_batched == toks_single


def test_moe_family_admits_unpadded_and_unbatched():
    """Capacity-bounded MoE routing couples tokens across the flattened
    B*S batch: padded or co-batched rows change which valid tokens are
    capacity-dropped.  The engine must admit moe at exact lengths, one
    request per prefill, so add_requests == one-at-a-time admission."""
    cfg = dataclasses.replace(get_smoke_config("qwen3_moe_30b_a3b"),
                              vocab=64, n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3], [4, 5, 6], [7, 8, 9]]

    eng = ServingEngine(params, cfg, n_slots=4, max_len=32)
    assert not eng.bucketing.enabled
    toks_grouped = _serve(eng, prompts, max_new=2)
    # same length, but never batched together: one (1, 3) prefill each
    assert eng.bucketing.stats.per_shape == {(1, 3): 3}

    eng1 = ServingEngine(params, cfg, n_slots=4, max_len=32)
    toks_single = []
    for p in prompts:
        toks_single += _serve(eng1, [p], max_new=2)
    # prefill-sampled first tokens match isolated admission exactly (the
    # admission guarantee); later tokens may differ because the DECODE
    # batch composition differs (slots decode together here, alone in
    # eng1) and moe routing couples the decode batch too — inherent to
    # continuous batching, not an admission artifact.
    assert [t[0] for t in toks_grouped] == [t[0] for t in toks_single]
    assert all(len(t) == 2 for t in toks_grouped)


def test_windowed_dense_admits_unpadded(fp_model):
    """A sliding-window ring cache keeps the LAST W keys, so a padded
    suffix would evict valid ones: padding must gate off on attn_window."""
    cfg, params = fp_model
    wcfg = dataclasses.replace(cfg, attn_window=16)
    eng = ServingEngine(params, wcfg, n_slots=2, max_len=64)
    assert not eng.bucketing.enabled
    (toks,) = _serve(eng, [[1, 2, 3, 4, 5]], max_new=3)
    assert len(toks) == 3


def test_eos_at_prefill_retires_at_admission(fp_model):
    cfg, params = fp_model
    prompt = [5, 6, 7]
    cache = api.make_cache(cfg, 1, 64, dtype=jnp.float32)
    logits, _ = api.prefill_step(
        params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)}, cache)
    first = int(jnp.argmax(logits[0]))

    eng = ServingEngine(params, cfg, n_slots=2, max_len=64)
    uid = eng.add_request(prompt, max_new_tokens=8, eos_id=first)
    assert uid not in eng.active          # retired before any decode step
    assert eng.finished[uid].done
    assert eng.finished[uid].tokens == [first]
    assert len(eng.free) == 2             # slot returned immediately
    assert eng.step() == {}


def test_max_new_tokens_one_emits_exactly_one(fp_model):
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64)
    uid = eng.add_request([1, 2, 3, 4], max_new_tokens=1)
    assert uid in eng.finished and len(eng.finished[uid].tokens) == 1
    # budget honored exactly for >1 too: prefill token + (n-1) decode steps
    (toks,) = _serve(eng, [[1, 2, 3, 4]], max_new=2)
    assert len(toks) == 2


def test_slot_reuse_after_retirement(fp_model):
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64)
    pending = [[i + 1, i + 2] for i in range(6)]  # 6 requests, 2 slots
    admitted = []
    while pending or eng.active:
        if pending and eng.free:
            batch = [pending.pop(0)
                     for _ in range(min(len(pending), len(eng.free)))]
            admitted += eng.add_requests(batch, max_new_tokens=3)
        eng.step()
    fin = eng.take_finished()
    assert sorted(fin) == sorted(admitted) and len(fin) == 6
    assert all(r.done and len(r.tokens) == 3 for r in fin.values())
    assert sorted(eng.free) == [0, 1]


def test_admission_rejects_cache_overflow(fp_model):
    """A request whose prompt + token budget exceeds max_len must be
    rejected at admission: decode would write past the cache end, where
    the K/V update clamps/drops — silently corrupting the last cache
    position."""
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=2, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(list(range(1, 10)), max_new_tokens=8)   # 9 + 8 > 16
    # nothing was admitted, no slot leaked
    assert not eng.active and len(eng.free) == 2
    # the boundary case fits exactly (the last generated token is never
    # written back) and must run its full budget
    uid = eng.add_request(list(range(1, 9)), max_new_tokens=8)  # 8 + 8 == 16
    eng.run_to_completion()
    req = eng.take_finished()[uid]
    assert len(req.tokens) == 8 and not req.truncated


def test_cache_full_retires_truncated(fp_model):
    """Belt-and-braces guard behind admission validation: if a request's
    budget grows mid-flight (streaming extension), a full slot cache
    retires it with `truncated` set instead of decode silently
    overwriting the last K/V position."""
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=2, max_len=16)
    uid = eng.add_request(list(range(1, 9)), max_new_tokens=8)
    eng.active[uid].max_new_tokens = 100   # simulate a mid-flight extension
    eng.run_to_completion()
    req = eng.take_finished()[uid]
    assert req.done and req.truncated
    # prefill wrote 8 positions; decode may write the remaining 8, and the
    # token sampled from the last in-bounds write is still emitted
    assert len(req.tokens) == 16 - 8 + 1
    assert len(eng.free) == 2              # slot recycled


def test_run_to_completion_surfaces_truncation(fp_model):
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64)
    uid = eng.add_request([1, 2, 3], max_new_tokens=32)
    with pytest.raises(RuntimeError, match="max_steps"):
        eng.run_to_completion(max_steps=3)
    unfinished = eng.run_to_completion(max_steps=2, strict=False)
    assert unfinished == [uid]
    assert eng.run_to_completion() == []  # now finishes; [] == complete
