"""Self-speculative decoding: losslessness, rollback, gates, retirement.

The acceptance bar is the repo's parity idiom taken to the speculative
path: greedy speculative decoding must emit token streams BIT-IDENTICAL
to vanilla greedy decode — same tokens, same retirement points — for any
window length γ and any draft (the draft only sets how many tokens a
verify call retires, never what they are), on an AP+OR-quantized
draft/target pair built from ONE calibration pass.  Trace counters prove
speculation adds a constant number of compiles (draft decode, verify,
rollback) independent of how many windows run.  The multi-device (2x4
mesh) variant lives in tests/test_dist_serving.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig, draft_config
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize_with_draft
from repro.models import api
from repro.models.layers import select_logits
from repro.serve import ServingEngine, SpecConfig
from repro.serve.engine import _rollback_tail
from repro.serve.speculative import accept_greedy, validate_spec_support

jax.config.update("jax_platform_name", "cpu")

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10],
           [11, 12, 13, 14, 15, 16, 17, 18, 19]]


@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def unrelated_draft(fp_model):
    """A draft that shares NOTHING with the target (different random
    init): acceptance collapses toward zero, which exercises the
    rollback/correction path on nearly every window — losslessness must
    not depend on draft quality."""
    cfg, _ = fp_model
    return api.init_params(jax.random.PRNGKey(99), cfg)


@pytest.fixture(scope="module")
def quantized_pair(fp_model):
    """The deployment format: AP+OR target and 2-bit draft quantized from
    the SAME fp weights and the SAME tapped Hessians (one calibration)."""
    cfg, params = fp_model
    qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4,
                      gptq_blocksize=32, ap=APConfig(2.2, 2, 4),
                      orr=ORConfig(0.1))
    calib = calibration_set(vocab=cfg.vocab, n_segments=4, seq_len=32)
    (qparams, rep), (dparams, drep) = claq_quantize_with_draft(
        params, cfg, calib, qcfg, draft_bits=2)
    assert 2.0 < rep.mean_effective_bits < 2.6
    # flat 2-bit codes + OR reservation, strictly below the target
    assert drep.mean_effective_bits < rep.mean_effective_bits
    assert 2.0 <= drep.mean_effective_bits < 2.3
    return cfg, qparams, dparams


def _serve(eng, prompts, max_new, eos_id=None):
    uids = eng.add_requests(prompts, max_new_tokens=max_new, eos_id=eos_id)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]


# ------------------------------------------------------------------ units

def test_spec_config_validation():
    assert SpecConfig().gamma == 4 and SpecConfig().draft_bits == 2
    with pytest.raises(ValueError, match="gamma"):
        SpecConfig(gamma=0)
    with pytest.raises(ValueError, match="draft_bits"):
        SpecConfig(gamma=2, draft_bits=0)


def test_accept_greedy_units():
    # full acceptance appends the bonus token
    assert accept_greedy([5, 6, 7], [5, 6, 7, 8]) == (3, [5, 6, 7, 8])
    # first mismatch replaces the draft token with the target's
    assert accept_greedy([5, 9, 7], [5, 6, 7, 8]) == (1, [5, 6])
    # zero acceptance still emits one (target) token
    assert accept_greedy([5, 6], [4, 6, 7]) == (0, [4])
    with pytest.raises(ValueError, match="gamma"):
        accept_greedy([1, 2], [1, 2])


def test_draft_config_derivation():
    qcfg = CLAQConfig(bits=3, method="kmeans", kmeans_iters=7,
                      gptq_blocksize=64, ap=APConfig(3.3, 3, 4),
                      orr=ORConfig(0.1))
    d = draft_config(qcfg, 2)
    assert d.bits == 2 and d.ap is None
    assert d.orr == qcfg.orr                      # outliers kept
    assert d.kmeans_iters == 7 and d.gptq_blocksize == 64
    with pytest.raises(ValueError, match="draft_bits"):
        draft_config(qcfg, 0)


def test_select_logits_span_positions():
    logits = jnp.arange(2 * 5 * 3, dtype=jnp.float32).reshape(2, 5, 3)
    # legacy: last position / per-row scalar
    assert jnp.array_equal(select_logits(logits), logits[:, -1])
    got = select_logits(logits, jnp.asarray([[1, 3], [0, 4]]))
    assert got.shape == (2, 2, 3)
    assert jnp.array_equal(got[0, 0], logits[0, 1])
    assert jnp.array_equal(got[0, 1], logits[0, 3])
    assert jnp.array_equal(got[1, 0], logits[1, 0])
    assert jnp.array_equal(got[1, 1], logits[1, 4])


def test_rollback_tail_masks_and_rewinds():
    L, B, S, KH, D = 2, 3, 8, 2, 4
    cache = api.make_cache(
        dataclasses.replace(get_smoke_config("llama1_7b"), n_layers=L,
                            n_kv_heads=KH, head_dim=D),
        B, S, dtype=jnp.float32)
    filled = jax.tree_util.tree_map(
        lambda a: jnp.ones_like(a) if a.dtype != jnp.int32
        else jnp.full_like(a, S), cache)
    lens = jnp.asarray([0, 3, 8])
    rb = _rollback_tail(filled, lens)
    assert np.array_equal(np.asarray(rb.length),
                          np.broadcast_to([0, 3, 8], (L, B)))
    k = np.asarray(rb.k)
    for b, n in enumerate([0, 3, 8]):
        assert np.all(k[:, b, :n] == 1.0)
        assert np.all(k[:, b, n:] == 0.0)


# ------------------------------------------------------------- family gate

def test_speculation_gated_to_rollbackable_families(fp_model):
    cfg, params = fp_model
    for arch, msg in (("rwkv6_7b", "recurrent state"),
                      ("zamba2_1p2b", "recurrent state"),
                      ("qwen3_moe_30b_a3b", "router")):
        c = get_smoke_config(arch)
        with pytest.raises(NotImplementedError, match=msg):
            validate_spec_support(c)
    # sliding-window ring caches cannot roll back either
    with pytest.raises(NotImplementedError, match="ring"):
        validate_spec_support(dataclasses.replace(cfg, attn_window=16))
    # the engine applies the gate at construction
    wcfg = dataclasses.replace(cfg, attn_window=16)
    with pytest.raises(NotImplementedError, match="ring"):
        ServingEngine(params, wcfg, n_slots=2, max_len=64,
                      draft_params=params, spec=SpecConfig(gamma=2))
    # and both spec halves must arrive together
    with pytest.raises(ValueError, match="draft_params"):
        ServingEngine(params, cfg, n_slots=2, max_len=64,
                      spec=SpecConfig(gamma=2))
    with pytest.raises(ValueError, match="spec"):
        ServingEngine(params, cfg, n_slots=2, max_len=64,
                      draft_params=params)


# ----------------------------------------------------- span decode primitive

def test_decode_span_bitwise_matches_successive_decodes(fp_model):
    """The verify primitive: one span call == γ+1 successive decode steps,
    bitwise, at PER-SLOT fill levels (staggered by bucketed admission)."""
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=3, max_len=32, min_bucket=4)
    eng.add_requests([[1, 2, 3], [4, 5], [6, 7, 8, 9, 10, 11]],
                     max_new_tokens=8)
    cache = eng.cache
    span = jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab, size=(3, 4)),
        jnp.int32)

    c1, outs = cache, []
    for j in range(span.shape[1]):
        lg, c1 = api.decode_step(params, cfg, span[:, j], c1)
        outs.append(lg)
    ref = jnp.stack(outs, axis=1)
    got, c2 = api.decode_span(params, cfg, span, cache)
    assert jnp.array_equal(got, ref)
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        assert jnp.array_equal(a, b)


def test_decode_span_rejects_unsupported_configs(fp_model):
    """The primitive itself gates families whose span logits could not
    equal successive decodes (not just the engine): recurrent state, the
    moe router's span-token coupling, and ring caches (where the S>1
    write path would clobber the populated ring)."""
    cfg = get_smoke_config("rwkv6_7b")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    cache = api.make_cache(cfg, 2, 16, dtype=jnp.float32)
    with pytest.raises(NotImplementedError, match="recurrent"):
        api.decode_span(params, cfg, jnp.zeros((2, 3), jnp.int32), cache)
    dcfg, dparams = fp_model
    mcfg = get_smoke_config("qwen3_moe_30b_a3b")
    with pytest.raises(NotImplementedError, match="router"):
        api.decode_span({}, mcfg, jnp.zeros((2, 3), jnp.int32), None)
    wcfg = dataclasses.replace(dcfg, attn_window=16)
    with pytest.raises(NotImplementedError, match="ring"):
        api.decode_span(dparams, wcfg, jnp.zeros((2, 3), jnp.int32), None)


# ------------------------------------------------------------ losslessness

@pytest.mark.parametrize("gamma", [1, 2, 4])
def test_spec_matches_vanilla_on_quantized_pair(quantized_pair, gamma):
    """The flagship bar: greedy speculative == vanilla greedy,
    bit-identical, on the AP+OR target with its 2-bit one-pass draft."""
    cfg, qparams, dparams = quantized_pair
    eng_v = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_v = _serve(eng_v, PROMPTS, max_new=8)

    eng_s = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                          draft_params=dparams,
                          spec=SpecConfig(gamma=gamma, draft_bits=2))
    toks_s = _serve(eng_s, PROMPTS, max_new=8)
    assert toks_s == toks_v
    assert all(len(t) == 8 for t in toks_s)

    st = eng_s.stats()
    # constant compile budget, independent of how many windows ran:
    # one draft-decode trace, one verify trace, target decode jit unused
    assert st["verify_traces"] == 1
    assert st["draft_decode_traces"] == 1
    assert st["decode_traces"] == 0
    assert st["prefill_traces"] <= eng_s.bucketing.max_traces() * 2
    assert st["draft_prefill_traces"] == st["prefill_traces"]
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert st["tokens_per_step"] >= 1.0
    assert st["emitted_tokens"] == sum(len(t) - 1 for t in toks_s)


def test_draft_plan_tiles_tune_independently(quantized_pair):
    """Draft-specific plan tuning (ROADMAP spec item b): draft_plan_bn
    caps the DRAFT's prepared tile size without touching the target's
    plans, and — tiles being a pure layout choice — greedy speculation
    stays bit-identical to vanilla decode."""
    from repro.kernels.plan import PreparedQuantizedTensor

    cfg, qparams, dparams = quantized_pair
    eng_v = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_v = _serve(eng_v, PROMPTS, max_new=8)

    eng = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                        draft_params=dparams,
                        spec=SpecConfig(gamma=2, draft_bits=2),
                        draft_plan_bn=32)
    assert _serve(eng, PROMPTS, max_new=8) == toks_v

    def bns(tree):
        out = []
        jax.tree_util.tree_map(
            lambda l: out.append(l.bn) if isinstance(
                l, PreparedQuantizedTensor) else None,
            tree, is_leaf=lambda l: isinstance(l, PreparedQuantizedTensor))
        return out

    assert all(bn <= 32 for bn in bns(eng.draft_params))
    # the target keeps the default cap (its big matrices use bn > 32)
    assert max(bns(eng.params)) > 32


def test_spec_lossless_under_int8_activations(quantized_pair):
    """Losslessness composes with A8: activation quantization is per-token
    elementwise, so span-verify stays bitwise gamma+1 successive decodes
    under int8 too — speculative int8 tokens must equal VANILLA int8
    tokens (the composition the --act-dtype + --spec-gamma CLI serves)."""
    cfg, qparams, dparams = quantized_pair
    eng_v = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                          act_dtype="int8")
    toks_v = _serve(eng_v, PROMPTS, max_new=8)

    eng_s = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                          draft_params=dparams,
                          spec=SpecConfig(gamma=2, draft_bits=2),
                          act_dtype="int8")
    assert _serve(eng_s, PROMPTS, max_new=8) == toks_v
    assert eng_s.stats()["act_dtype"] == "int8"


def test_spec_lossless_with_unrelated_draft(fp_model, unrelated_draft):
    """Emitted tokens never depend on the draft: an unrelated draft makes
    nearly every window reject (correction path), yet the stream is
    bit-identical and every window still emits >= 1 token per request."""
    cfg, params = fp_model
    eng_v = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_v = _serve(eng_v, PROMPTS, max_new=7)
    eng_s = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                          draft_params=unrelated_draft,
                          spec=SpecConfig(gamma=3))
    toks_s = _serve(eng_s, PROMPTS, max_new=7)
    assert toks_s == toks_v
    st = eng_s.stats()
    assert st["acceptance_rate"] < 0.5          # the draft really is bad
    assert st["tokens_per_step"] >= 1.0


def test_spec_self_draft_accepts_everything(fp_model):
    """draft == target: every draft token verifies, so every window emits
    γ+1 tokens per active request and acceptance is exactly 1.0 — the
    sharpest check that propose/verify/rollback bookkeeping agrees."""
    cfg, params = fp_model
    gamma = 2
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64, min_bucket=8,
                        draft_params=params, spec=SpecConfig(gamma=gamma))
    # max_new = 1 (admission) + 2 full windows of gamma+1
    (toks,) = _serve(eng, [[1, 2, 3]], max_new=1 + 2 * (gamma + 1))
    st = eng.stats()
    assert st["acceptance_rate"] == 1.0
    assert st["engine_steps"] == 2
    assert st["tokens_per_step"] == gamma + 1


def test_spec_mla_matches_vanilla(fp_model):
    """MLA's absorbed decode has its own span generalization — parity on
    a dense+MLA config (latent cache rollback via c_kv/k_pe leaves)."""
    cfg, _ = fp_model
    mcfg = dataclasses.replace(cfg, use_mla=True, q_lora=32, kv_lora=16,
                               rope_head_dim=8, v_head_dim=16, head_dim=16)
    params = api.init_params(jax.random.PRNGKey(3), mcfg)
    draft = api.init_params(jax.random.PRNGKey(7), mcfg)
    eng_v = ServingEngine(params, mcfg, n_slots=3, max_len=64, min_bucket=8)
    toks_v = _serve(eng_v, PROMPTS[:3], max_new=6)
    eng_s = ServingEngine(params, mcfg, n_slots=3, max_len=64, min_bucket=8,
                          draft_params=draft, spec=SpecConfig(gamma=2))
    toks_s = _serve(eng_s, PROMPTS[:3], max_new=6)
    assert toks_s == toks_v


# ------------------------------------------------- retirement inside windows

def test_eos_mid_window_retires_at_exact_token(fp_model, unrelated_draft):
    """EOS appearing anywhere inside a speculation window must retire the
    request at exactly that token — accepted tokens PAST the EOS are
    discarded with the rollback, never emitted."""
    cfg, params = fp_model
    base = _serve(ServingEngine(params, cfg, n_slots=4, max_len=64,
                                min_bucket=8), PROMPTS, max_new=8)
    eos = base[1][3]       # a token mid-stream of request 1
    eng_v = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_v = _serve(eng_v, PROMPTS, max_new=8, eos_id=eos)
    eng_s = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                          draft_params=unrelated_draft,
                          spec=SpecConfig(gamma=4))
    toks_s = _serve(eng_s, PROMPTS, max_new=8, eos_id=eos)
    assert toks_s == toks_v
    # retired BY the eos (if the value happened to appear even earlier in
    # the stream, both engines cut there — parity already asserted)
    assert toks_s[1][-1] == eos and len(toks_s[1]) <= 4
    assert eos not in toks_s[1][:-1]


def test_budget_exhausted_mid_window(fp_model, unrelated_draft):
    """max_new_tokens that is NOT window-aligned (budget runs out in the
    middle of a verify window) must truncate at exactly the budget."""
    cfg, params = fp_model
    for max_new in (2, 4, 5):
        eng_v = ServingEngine(params, cfg, n_slots=4, max_len=64,
                              min_bucket=8)
        toks_v = _serve(eng_v, PROMPTS, max_new=max_new)
        eng_s = ServingEngine(params, cfg, n_slots=4, max_len=64,
                              min_bucket=8, draft_params=unrelated_draft,
                              spec=SpecConfig(gamma=3))
        toks_s = _serve(eng_s, PROMPTS, max_new=max_new)
        assert toks_s == toks_v
        assert all(len(t) == max_new for t in toks_s)


def test_cache_full_truncates_mid_window(fp_model, unrelated_draft):
    """A budget mutated past the slot cache (streaming extension) retires
    `truncated` at exactly the same token count as the vanilla engine —
    the span's out-of-bounds K/V writes are dropped, never clamped onto
    the last real position."""
    cfg, params = fp_model
    counts = []
    for spec, draft in ((None, None),
                        (SpecConfig(gamma=4), unrelated_draft)):
        eng = ServingEngine(params, cfg, n_slots=2, max_len=16,
                            draft_params=draft, spec=spec)
        uid = eng.add_request(list(range(1, 9)), max_new_tokens=8)
        eng.active[uid].max_new_tokens = 100
        eng.run_to_completion()
        req = eng.take_finished()[uid]
        assert req.done and req.truncated
        counts.append(req.tokens)
    assert counts[0] == counts[1]
    assert len(counts[0]) == 16 - 8 + 1


def test_slot_reuse_and_constant_traces_across_waves(fp_model,
                                                     unrelated_draft):
    """Waves of admissions through 2 slots: speculation's compile count
    stays at one draft-decode + one verify trace no matter how many
    windows run, and prefill traces stay inside the bucket bound."""
    cfg, params = fp_model
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64, min_bucket=8,
                        draft_params=unrelated_draft,
                        spec=SpecConfig(gamma=2))
    pending = [[i + 1, i + 2, i + 3] for i in range(6)]
    admitted = []
    while pending or eng.active:
        if pending and eng.free:
            batch = [pending.pop(0)
                     for _ in range(min(len(pending), len(eng.free)))]
            admitted += eng.add_requests(batch, max_new_tokens=5)
        eng.step()
    fin = eng.take_finished()
    assert sorted(fin) == sorted(admitted) and len(fin) == 6
    assert all(r.done and len(r.tokens) == 5 for r in fin.values())
    st = eng.stats()
    assert st["verify_traces"] == 1
    assert st["draft_decode_traces"] == 1
    assert st["engine_steps"] > 2               # several windows really ran


def test_spec_preempt_resume_parity(fp_model, unrelated_draft):
    """Preemption mid-stream under speculation: the victim's BOTH caches
    (target + draft) are cleared and rebuilt on resume — prefill of the
    original prompt plus a teacher-forced replay through the decode jits
    — so its remaining windows emit tokens bit-identical to an
    uninterrupted spec run, which is itself bit-identical to vanilla."""
    cfg, params = fp_model
    base = _serve(ServingEngine(params, cfg, n_slots=2, max_len=64,
                                min_bucket=8),
                  PROMPTS[:2], max_new=8)
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64, min_bucket=8,
                        draft_params=unrelated_draft, spec=SpecConfig(gamma=3))
    uids = eng.add_requests(PROMPTS[:2], max_new_tokens=8)
    eng.step()                                   # one window in
    eng.set_cache_pressure(3)                    # below both fills
    eng.step()
    st = eng.stats()
    assert st["preemptions"] == 2 and not eng.active
    eng.set_cache_pressure(None)
    eng.run_to_completion()
    fin = eng.take_finished()
    assert [fin[u].tokens for u in uids] == base
    assert eng.stats()["resumes"] == 2


def test_nonfinite_verify_row_quarantined_mid_window(fp_model,
                                                     unrelated_draft):
    """guards=True + an injected NaN in one slot's verify logits: that
    request emits NOTHING from the window and retires FAILED with
    diagnostics (rollback clears its slot first); the other row's window
    accepts normally and its full stream stays bit-identical to a clean
    vanilla engine."""
    from repro.serve import FaultInjector, RequestState

    cfg, params = fp_model
    base = _serve(ServingEngine(params, cfg, n_slots=2, max_len=64,
                                min_bucket=8),
                  PROMPTS[:2], max_new=10)
    inj = FaultInjector(seed=2, horizon=8, nan_faults=1, inf_faults=0,
                        pressure_windows=0, transient_failures=0,
                        burst_every=0, arrival_lambda=0.0)
    (fault_step,) = inj.logit_faults
    eng = ServingEngine(params, cfg, n_slots=2, max_len=64, min_bucket=8,
                        draft_params=unrelated_draft,
                        spec=SpecConfig(gamma=3), guards=True, faults=inj)
    uids = eng.add_requests(PROMPTS[:2], max_new_tokens=10)
    emitted_at_fault = None
    while eng.active:
        out = eng.step()
        if eng.engine_steps - 1 == fault_step:
            emitted_at_fault = out
    fin = eng.take_finished()
    failed = [u for u in uids if fin[u].state is RequestState.FAILED]
    ok = [u for u in uids if fin[u].state is RequestState.FINISHED]
    assert len(failed) == 1 and len(ok) == 1
    d = fin[failed[0]].diagnostics
    assert d["kind"] == "nonfinite_logits" and d["phase"] == "verify"
    assert d["engine_step"] == fault_step
    # the quarantined request emitted nothing from the poisoned window...
    assert failed[0] not in emitted_at_fault
    # ...its surviving prefix is a prefix of the clean stream, and the
    # neighbor's full stream is untouched
    b = base[uids.index(failed[0])]
    assert fin[failed[0]].tokens == b[:len(fin[failed[0]].tokens)]
    assert fin[ok[0]].tokens == base[uids.index(ok[0])]
