"""Outlier Order metric (§3.2) and AP/OR budget policies (§3.3/3.4)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import outlier, policy


def test_outlier_ratio_matches_numpy():
    rng = np.random.default_rng(0)
    W = rng.normal(size=(64, 32)).astype(np.float32)
    W[:8, 3] *= 50
    S = 5.0
    R = np.asarray(outlier.outlier_ratio(jnp.asarray(W), S))
    thresh = S * np.abs(W).mean()
    R_np = (np.abs(W) > thresh).mean(axis=0)
    np.testing.assert_allclose(R, R_np, atol=1e-6)
    assert R[3] == R.max()


@settings(max_examples=25, deadline=None)
@given(cols=st.integers(8, 200), frac=st.floats(0.01, 0.6),
       seed=st.integers(0, 999))
def test_top_fraction_exact_count(cols, frac, seed):
    rng = np.random.default_rng(seed)
    R = jnp.asarray(rng.random(cols).astype(np.float32))
    mask = outlier.top_fraction_mask(R, frac)
    assert int(mask.sum()) == int(round(frac * cols))


def test_topk_per_column_mask():
    rng = np.random.default_rng(1)
    W = rng.normal(size=(40, 6)).astype(np.float32)
    counts = jnp.asarray([0, 1, 3, 5, 0, 2], jnp.int32)
    mask = np.asarray(outlier.topk_per_column_mask(jnp.asarray(W), counts))
    assert np.array_equal(mask.sum(axis=0), np.asarray(counts))
    for j in range(6):
        k = int(counts[j])
        if k:
            sel = np.abs(W[:, j])[mask[:, j]]
            rest = np.abs(W[:, j])[~mask[:, j]]
            assert sel.min() >= rest.max() - 1e-6


@settings(max_examples=20, deadline=None)
@given(cols=st.integers(16, 256), target=st.floats(2.05, 3.95),
       seed=st.integers(0, 999))
def test_ap_budget(cols, target, seed):
    rng = np.random.default_rng(seed)
    R = jnp.asarray(rng.random(cols).astype(np.float32))
    bits, achieved = policy.ap_column_bits(
        R, policy.APConfig(target_bits=target, p_lo=2, p_hi=4))
    assert set(np.unique(np.asarray(bits))) <= {2, 4}
    assert abs(achieved - target) <= 2.0 / cols + 1e-6
    assert abs(float(jnp.mean(bits.astype(jnp.float32))) - achieved) < 1e-6
    # high-precision columns are exactly the top-R ones
    n_hi = int((np.asarray(bits) == 4).sum())
    if 0 < n_hi < cols:
        thresh = np.sort(np.asarray(R))[::-1][n_hi - 1]
        assert np.all(np.asarray(R)[np.asarray(bits) == 4] >= thresh - 1e-6)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(64, 512), cols=st.integers(16, 128),
       extra=st.floats(0.05, 0.3), seed=st.integers(0, 999))
def test_or_budget(rows, cols, extra, seed):
    rng = np.random.default_rng(seed)
    R = jnp.asarray(rng.random(cols).astype(np.float32))
    counts, achieved = policy.or_reserve_counts(
        R, rows, policy.ORConfig(extra_bits=extra))
    total_bits = float(counts.sum()) * policy.BITS_PER_RESERVED_OUTLIER
    assert abs(total_bits / (rows * cols) - achieved) < 1e-6
    # rounding granularity: up to 0.5 outlier/column in each class
    assert achieved <= extra + 0.5 * policy.BITS_PER_RESERVED_OUTLIER / rows + 1e-6
    assert int(counts.max()) <= rows
    # top columns get at least as many reservations
    order = np.argsort(-np.asarray(R))
    c = np.asarray(counts)[order]
    assert c[0] >= c[-1]
