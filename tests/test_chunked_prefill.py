"""Chunked prefill (serve/chunked_prefill.py).

The load-bearing claim is BITWISE token parity: splitting a prompt's
prefill into fixed-budget chunks interleaved with decode must emit
exactly the tokens monolithic prefill emits — across dense and MLA,
under preempt/resume mid-``PREFILLING``, over the paged KV layout, and
inside speculative windows — while chunk jits stay within the TRC-CC1
compile budget.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.serve import (AdmissionController, ChunkedPrefillConfig,
                         RequestState, ServingEngine, SLOConfig, SpecConfig,
                         StepClock, StepCostModel)

jax.config.update("jax_platform_name", "cpu")

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10],
           [11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21]]


@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(eng, prompts, max_new, eos_id=None):
    uids = eng.add_requests(prompts, max_new_tokens=max_new, eos_id=eos_id)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]


# ------------------------------------------------------------------- gates

def test_config_validation():
    with pytest.raises(ValueError):
        ChunkedPrefillConfig(chunk_tokens=0)
    with pytest.raises(ValueError):
        ChunkedPrefillConfig(chunk_tokens=8, budget_tokens=0)


def test_chunk_must_divide_max_len(fp_model):
    """A final chunk hanging past the cache end would make
    dynamic_update_slice clamp its start and silently shift real rows."""
    cfg, params = fp_model
    with pytest.raises(ValueError, match="divide"):
        ServingEngine(params, cfg, n_slots=2, max_len=48,
                      chunked_prefill=10)


def test_windowed_attention_rejected(fp_model):
    """Ring caches have no linear chunk positions — the gate must be
    hard, not a silent fallback to monolithic prefill."""
    cfg, params = fp_model
    wcfg = dataclasses.replace(cfg, attn_window=16)
    wparams = api.init_params(jax.random.PRNGKey(0), wcfg)
    with pytest.raises(NotImplementedError, match="chunked prefill"):
        ServingEngine(wparams, wcfg, n_slots=2, max_len=64,
                      chunked_prefill=8)


# ------------------------------------------------------------------ parity

def test_chunked_matches_monolithic_dense(fp_model):
    """Multi-length batched admission: chunk-by-chunk cache append +
    final masked insert emits tokens bit-identical to one monolithic
    prefill, within the chunk compile budget."""
    from repro.analysis import REGISTRY, run_rules
    from repro.analysis.artifacts import compile_budgets, trace_counts

    cfg, params = fp_model
    eng_m = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_m = _serve(eng_m, PROMPTS, max_new=6)

    eng_c = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                          chunked_prefill=8)
    toks_c = _serve(eng_c, PROMPTS, max_new=6)

    assert toks_c == toks_m
    st = eng_c.stats()["chunked"]
    assert st["chunks_processed"] > 0 and st["prefilling"] == 0
    # chunk jits recompile per batch bucket only — same TRC-CC1 gate the
    # prefill/decode paths already live under
    rep = run_rules([REGISTRY["TRC-CC1"], REGISTRY["TRC-SG1"]],
                    {"sentinel": eng_c.sentinel,
                     "compile_budget": compile_budgets(eng_c),
                     "trace_counts": trace_counts(eng_c)})
    assert rep.rules_run == ["TRC-CC1", "TRC-SG1"] and not rep.findings, \
        rep.render()


def test_chunked_budget_pacing_parity(fp_model):
    """A per-step token budget spreads one group's chunks across steps
    (decode interleaves between them) without changing a single token;
    a budget smaller than one chunk still guarantees progress."""
    cfg, params = fp_model
    eng_m = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_m = _serve(eng_m, PROMPTS, max_new=6)

    eng_c = ServingEngine(
        params, cfg, n_slots=4, max_len=64, min_bucket=8,
        chunked_prefill=ChunkedPrefillConfig(chunk_tokens=8,
                                             budget_tokens=8))
    toks_c = _serve(eng_c, PROMPTS, max_new=6)
    assert toks_c == toks_m
    assert eng_c.stats()["chunked"]["chunks_processed"] > 0


def test_chunked_matches_monolithic_mla(fp_model):
    """MLA prefill chunks through the latent c_kv/k_pe leaves — same
    uniform-fill branch, different cache pytree."""
    cfg, _ = fp_model
    mcfg = dataclasses.replace(cfg, use_mla=True, q_lora=32, kv_lora=16,
                               rope_head_dim=8, v_head_dim=16, head_dim=16)
    params = api.init_params(jax.random.PRNGKey(3), mcfg)
    eng_m = ServingEngine(params, mcfg, n_slots=3, max_len=64, min_bucket=8)
    toks_m = _serve(eng_m, PROMPTS[:3], max_new=6)
    eng_c = ServingEngine(params, mcfg, n_slots=3, max_len=64, min_bucket=8,
                          chunked_prefill=8)
    toks_c = _serve(eng_c, PROMPTS[:3], max_new=6)
    assert toks_c == toks_m


def test_chunked_paged_parity(fp_model):
    """Chunked groups prefill into a contiguous fragment and page in only
    at completion — pages are reserved up front (all-or-nothing), the
    table row is registered at insert."""
    cfg, params = fp_model
    paged = dict(kv_layout="paged", page_size=8)
    eng_m = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                          **paged)
    toks_m = _serve(eng_m, PROMPTS, max_new=6)
    eng_c = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                          chunked_prefill=8, **paged)
    toks_c = _serve(eng_c, PROMPTS, max_new=6)
    assert toks_c == toks_m
    st = eng_c.stats()
    # no reservation leak: residual occupancy (prefix-registry retained
    # pages) matches the monolithic engine's exactly
    assert st["paged"]["pages_in_use"] == eng_m.stats()["paged"]["pages_in_use"]
    assert st["chunked"]["chunks_processed"] > 0


def test_chunked_inside_speculative_window(fp_model):
    """Greedy speculation is lossless, so a chunked speculative engine
    must still match plain monolithic decode token for token — chunked
    admission happens while other slots sit mid-speculation-window."""
    cfg, params = fp_model
    draft = api.init_params(jax.random.PRNGKey(99), cfg)
    eng_v = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8)
    toks_v = _serve(eng_v, PROMPTS, max_new=8)
    eng_s = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                          draft_params=draft, spec=SpecConfig(gamma=2),
                          chunked_prefill=8)
    toks_s = _serve(eng_s, PROMPTS, max_new=8)
    assert toks_s == toks_v
    assert eng_s.stats()["chunked"]["chunks_processed"] > 0
    # the draft cache is chunk-filled in lockstep with the target's
    assert eng_s.stats()["chunked"]["draft_chunk_prefill_traces"] >= 1


# -------------------------------------------------- PREFILLING lifecycle

def test_preempt_resume_mid_prefilling(fp_model):
    """Preempting a request mid-``PREFILLING`` drops fragment progress
    (the batched row was never written), releases its reservation, and
    re-queues it at the front; the re-run prefill emits bitwise the
    monolithic tokens.  Surviving group members are unaffected."""
    cfg, params = fp_model
    long_a = list(range(1, 25))
    long_b = list(range(30, 52))
    eng_m = ServingEngine(params, cfg, n_slots=2, max_len=64, min_bucket=8)
    toks_m = _serve(eng_m, [long_a, long_b], max_new=6)

    eng = ServingEngine(
        params, cfg, n_slots=2, max_len=64, min_bucket=8,
        chunked_prefill=ChunkedPrefillConfig(chunk_tokens=8,
                                             budget_tokens=8))
    uids = eng.add_requests([long_a, long_b], max_new_tokens=6)
    eng.step()                                  # one 8-token chunk only
    assert eng.pending_prefills == 2
    g = eng._prefill_groups[0]
    victim = next(r for r in g.live() if r.uid == uids[0])
    assert victim.state is RequestState.PREFILLING
    assert 0 < g.progress < g.target_len
    # same two moves pump()'s pressure sweep makes
    g.cancel(victim.uid)
    eng._preempt_prefilling(victim, "test-pressure")
    assert victim.state is RequestState.QUEUED
    assert victim.slot == -1 and len(eng.queue) == 1

    eng.run_to_completion()
    fin = eng.take_finished()
    assert [fin[u].tokens for u in uids] == toks_m
    assert eng.stats()["preemptions"] >= 1
    assert sorted(eng.free) == [0, 1]


def test_prefilling_is_first_class_state(fp_model):
    """Budgeted chunking leaves requests visibly ``PREFILLING`` across
    steps (not hidden inside one admission call), and stats/telemetry
    see the partial state."""
    cfg, params = fp_model
    eng = ServingEngine(
        params, cfg, n_slots=2, max_len=64, min_bucket=8,
        chunked_prefill=ChunkedPrefillConfig(chunk_tokens=8,
                                             budget_tokens=8))
    uid = eng.add_request(list(range(1, 30)), max_new_tokens=4)
    eng.step()
    st = eng.stats()["chunked"]
    assert st["prefilling"] == 1 and st["groups_pending"] == 1
    assert eng.prefill_backlog_tokens > 0
    eng.run_to_completion()
    assert len(eng.take_finished()[uid].tokens) == 4
    assert eng.stats()["chunked"]["prefilling"] == 0


# -------------------------------------------------------------- contracts

def test_verify_contracts_green_with_chunking_and_controller(fp_model):
    """The PR 8 contract gate stays green with chunked prefill AND the
    overload controller live on the engine."""
    cfg, params = fp_model
    ctl = AdmissionController(SLOConfig(ttft_p99_ms=250.0))
    eng = ServingEngine(params, cfg, n_slots=3, max_len=64, min_bucket=8,
                        chunked_prefill=8, controller=ctl,
                        cost_model=StepCostModel(), clock=StepClock(10.0),
                        verify_contracts=True)
    toks = _serve(eng, PROMPTS[:3], max_new=5)
    assert all(len(t) == 5 for t in toks)
    assert eng.last_step_cost_ms is not None
