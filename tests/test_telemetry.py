"""Telemetry layer (DESIGN.md §13): metrics registry units, per-request
span recording through the engine's lifecycle hooks, and the
Chrome/Perfetto trace export.

The structural contract under test: telemetry is OBSERVATION-ONLY (the
token stream with a recorder attached is bit-identical to one without),
every lifecycle edge emits exactly one structured event carrying both
the clock time and the engine step, histogram percentiles are
deterministic and always inside the observed [min, max], and the
Perfetto rendering is a loadable trace_event document with one named
track per slot plus a queue track.
"""
import dataclasses
import json
import math

import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.serve import (Histogram, MetricsRegistry, ServingEngine,
                         SpecConfig, StepClock, Telemetry, perfetto_trace,
                         registry_from_stats)
from repro.serve.telemetry import Timeline

jax.config.update("jax_platform_name", "cpu")

PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [9, 10, 11, 12, 13]]


@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(fp_model, telemetry=None, **kw):
    cfg, params = fp_model
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("min_bucket", 8)
    kw.setdefault("clock", StepClock(10.0))
    return ServingEngine(params, cfg, telemetry=telemetry, **kw)


def _run(eng, prompts=PROMPTS, max_new=4):
    """Submit through the queue and step with the StepClock advancing —
    the deterministic driver loop every seeded latency test rides."""
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    while eng.active or len(eng.queue):
        eng.step()
        eng.clock.advance()
    fin = eng.take_finished()
    return {u: list(fin[u].tokens) for u in uids}


# ------------------------------------------------------------------- units

def test_histogram_percentiles_within_bucket_resolution():
    h = Histogram(lo=1e-3, hi=1e5, per_decade=8)
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    # a bucket spans 10**(1/8) ≈ 1.33x, so the reported midpoint is
    # within ~±16% of the exact rank value
    for q, exact in (("p50", 50.0), ("p90", 90.0), ("p99", 99.0)):
        assert s[q] == pytest.approx(exact, rel=0.2), (q, s)
    assert s["p50"] <= s["p90"] <= s["p99"]
    # percentiles are pure functions of the counts: re-query is identical
    assert h.percentile(0.5) == h.percentile(0.5)


def test_histogram_zero_underflow_and_overflow():
    h = Histogram(lo=1e-3, hi=10.0, per_decade=4)
    h.observe(0.0)
    h.observe(0.0)
    assert h.counts[0] == 2
    assert h.percentile(0.5) == 0.0        # clamped to observed min
    h.observe(1e9)                         # way past hi: overflow bucket
    assert h.counts[-1] == 1
    assert h.percentile(0.99) <= h.max     # clamp keeps it in range
    s = h.summary()
    assert s["max"] == 1e9 and s["min"] == 0.0


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError, match="histogram"):
        Histogram(lo=0.0)
    with pytest.raises(ValueError, match="histogram"):
        Histogram(lo=1.0, hi=0.5)
    with pytest.raises(ValueError, match="histogram"):
        Histogram(per_decade=0)


def test_registry_type_conflict_and_render():
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    reg.gauge("b").set(1.5)
    reg.histogram("lat_ms").observe(2.0)
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("a")
    assert reg.names() == ["a", "b", "lat_ms"]
    out = reg.render(title="t")
    assert out.splitlines()[0] == "[t]"
    assert "  a: 3" in out and "lat_ms: n=1" in out
    # prefix filter restricts the report
    assert "lat_ms" not in reg.render(prefix="a")


def test_registry_from_stats_projects_nested_dicts():
    reg = registry_from_stats(
        {"steps": 7, "paged": {"pages_in_use": 3, "ladder": [1, 2]},
         "guards": True, "name": "x"})
    assert reg.get("serve.steps").value == 7
    assert reg.get("serve.paged.pages_in_use").value == 3
    assert reg.get("serve.guards").value == 1          # bool -> int
    assert reg.get("serve.name").value == "x"
    assert reg.get("serve.paged.ladder") is None       # lists skipped


def test_timeline_same_step_overwrites():
    tl = Timeline()
    tl.sample(0, 0.0, 1)
    tl.sample(1, 0.01, 2)
    tl.sample(1, 0.02, 5)                  # same engine step: overwrite
    s = tl.snapshot()
    assert s["n"] == 2 and s["values"] == [1.0, 5.0]
    assert s["last"] == 5.0 and s["max"] == 5.0


def test_telemetry_attach_is_single_use(fp_model):
    tel = Telemetry()
    _engine(fp_model, telemetry=tel)
    with pytest.raises(ValueError, match="already attached"):
        _engine(fp_model, telemetry=tel)


# ------------------------------------------------- engine instrumentation

def test_engine_emits_full_lifecycle_spans(fp_model):
    tel = Telemetry()
    eng = _engine(fp_model, telemetry=tel)
    toks = _run(eng)
    kinds = {e["kind"] for e in tel.events}
    assert {"submit", "admit", "first_token", "step", "retire"} <= kinds
    # every event carries the virtual-clock time AND the engine step
    assert all("t" in e and "step" in e for e in tel.events)
    assert len(tel.records) == len(PROMPTS)
    for uid, r in tel.records.items():
        assert r["state"] == "finished"
        assert r["tokens_out"] == len(toks[uid]) > 0
        assert r["submit_step"] <= r["admit_step"] <= r["first_token_step"]
        assert r["submit_t"] <= r["admit_t"] <= r["first_token_t"]
    # retirement feeds the latency histograms: one sample per request
    for name in ("ttft_ms", "queue_wait_ms"):
        assert tel.registry.histogram(name).count == len(PROMPTS)
    # under a StepClock the derived latencies are exact step multiples
    step_ms = 10.0
    for r in tel.records.values():
        ttft = (r["first_token_t"] - r["submit_t"]) * 1e3
        assert ttft == pytest.approx(
            (r["first_token_step"] - r["submit_step"]) * step_ms)


def test_telemetry_is_observation_only(fp_model):
    base = _run(_engine(fp_model))
    instrumented = _run(_engine(fp_model, telemetry=Telemetry()))
    assert instrumented == base


def test_preempt_resume_events_and_accounting(fp_model):
    tel = Telemetry()
    eng = _engine(fp_model, telemetry=tel, on_pressure="preempt")
    uids = eng.add_requests(PROMPTS[:2], max_new_tokens=6)
    eng.step()
    eng.set_cache_pressure(3)              # below running fills: preempt
    eng.step()
    eng.set_cache_pressure(None)
    eng.run_to_completion()
    fin = eng.take_finished()
    assert all(fin[u].state.value == "finished" for u in uids)
    preempts = [e for e in tel.events if e["kind"] == "preempt"]
    resumes = [e for e in tel.events if e["kind"] == "resume"]
    assert preempts and resumes
    assert preempts[0]["reason"] and preempts[0]["uids"]
    # preempt events capture the slot BEFORE it is cleared
    assert all(s >= 0 for s in preempts[0]["slots"])
    assert sum(r["preemptions"] for r in tel.records.values()) >= 1
    # resume replays the already-generated prefix teacher-forced
    assert all(r["replayed"] >= 0 for r in resumes)


def test_spec_steps_carry_window_summaries(fp_model):
    cfg, params = fp_model
    draft = api.init_params(jax.random.PRNGKey(99), cfg)
    tel = Telemetry()
    eng = _engine(fp_model, telemetry=tel, draft_params=draft,
                  spec=SpecConfig(gamma=2, draft_bits=2))
    toks = _run(eng)
    assert _run(_engine(fp_model)) == toks   # speculation stays lossless
    steps = [e for e in tel.events if e["kind"] == "step"]
    assert steps and all(e["mode"] == "spec" for e in steps)
    for e in steps:
        w = e["window"]
        assert w["gamma"] == 2
        assert 0 <= w["accepted"] <= w["proposed"]
        assert len(e["uids"]) == len(e["tokens"]) == len(e["slots"])
    assert tel.registry.histogram("spec_accepted_per_window").count > 0


def test_perfetto_trace_structure(fp_model):
    tel = Telemetry()
    eng = _engine(fp_model, telemetry=tel, on_pressure="preempt")
    eng.add_requests(PROMPTS[:2], max_new_tokens=6)
    eng.step()
    eng.set_cache_pressure(3)
    eng.step()
    eng.set_cache_pressure(None)
    eng.run_to_completion()
    eng.take_finished()
    doc = perfetto_trace(tel)
    json.loads(json.dumps(doc))            # valid JSON document
    evs = doc["traceEvents"]
    tracks = [e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"]
    assert sorted(tracks) == ["queue", "slot 0", "slot 1"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all(e["dur"] >= 0 and e["ts"] >= 0 for e in spans)
    assert {e["name"] for e in spans} <= {"prefill", "decode", "spec",
                                          "resume"}
    # slot spans land on slot tracks (1..n_slots), never the queue track
    assert all(1 <= e["tid"] <= tel.n_slots for e in spans)
    names = {e["name"] for e in evs if e["ph"] == "i"}
    assert "submit" in names and "preempt" in names
    assert any(n.startswith("retire:") for n in names)
    counters = [e for e in evs if e["ph"] == "C"]
    assert {"queue_depth", "active_slots"} <= {e["name"] for e in counters}


def test_engine_metrics_consolidates_stats(fp_model):
    tel = Telemetry()
    eng = _engine(fp_model, telemetry=tel)
    _run(eng)
    reg = eng.metrics()
    assert reg is tel.registry             # one registry, not a copy
    assert reg.get("serve.engine_steps").value == eng.engine_steps
    assert reg.get("serve.lifecycle.finished").value == len(PROMPTS)
    out = reg.render()
    assert "serve.lifecycle.finished" in out and "ttft_ms" in out
    # works without telemetry too (fresh registry off stats())
    bare = _engine(fp_model)
    _run(bare)
    assert bare.metrics().get("serve.engine_steps").value > 0
