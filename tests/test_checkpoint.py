"""Checkpoint integrity: the per-leaf content-hash manifest must be
verified on RESTORE, failing fast with the offending leaf path — a
silently corrupted quantized plane served to the engine is the storage
flank of the robustness contract (DESIGN.md §10)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointCorrupt, CheckpointManager

jax.config.update("jax_platform_name", "cpu")


@dataclasses.dataclass
class State:
    w: jnp.ndarray
    b: jnp.ndarray
    step: jnp.ndarray


jax.tree_util.register_dataclass(State, ["w", "b", "step"], [])


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return State(w=jax.random.normal(k, (8, 16), jnp.bfloat16),
                 b=jnp.arange(16, dtype=jnp.float32),
                 step=jnp.asarray(3, jnp.int32))


def test_roundtrip_verifies_and_restores(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    mgr.save(7, st)
    assert mgr.latest_step() == 7
    out = mgr.restore(7, jax.tree_util.tree_map(jnp.zeros_like, st))
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupted_leaf_fails_fast_with_path(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    mgr.save(1, st)
    # flip bytes of one stored leaf, keeping the manifest intact —
    # exactly the silent corruption restore() must refuse to serve
    ckpt = os.path.join(str(tmp_path), f"step_{1:010d}")
    with np.load(os.path.join(ckpt, "arrays.npz")) as z:
        arrays = {k: z[k].copy() for k in z.files}
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    victim = manifest["leaves"][".w"]["key"]
    arrays[victim].reshape(-1)[0] ^= 0xFF
    np.savez(os.path.join(ckpt, "arrays.npz"), **arrays)
    with pytest.raises(CheckpointCorrupt, match=r"\.w") as ei:
        mgr.restore(1, jax.tree_util.tree_map(jnp.zeros_like, st))
    assert ei.value.leaf == ".w" and ei.value.step == 1
    # the torn checkpoint is also invisible to latest_step()
    assert mgr.latest_step() is None


def test_latest_step_falls_back_past_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(seed=1))
    mgr.save(2, _state(seed=2))
    ckpt = os.path.join(str(tmp_path), f"step_{2:010d}")
    with np.load(os.path.join(ckpt, "arrays.npz")) as z:
        arrays = {k: z[k].copy() for k in z.files}
    next(iter(arrays.values())).reshape(-1)[:4] ^= 0xFF
    np.savez(os.path.join(ckpt, "arrays.npz"), **arrays)
    assert mgr.latest_step() == 1                # newest valid, not newest
    step, out = mgr.restore_latest(
        jax.tree_util.tree_map(jnp.zeros_like, _state()))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out.b),
                                  np.asarray(_state(seed=1).b))
    with pytest.raises(CheckpointCorrupt):
        mgr.restore(2, jax.tree_util.tree_map(jnp.zeros_like, _state()))
