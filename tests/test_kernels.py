"""Pallas dequant-GEMM kernel vs the pure-jnp oracle (interpret mode):
shape/dtype/bit-width sweeps, outlier epilogue, multi-stripe AP tensors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import APConfig, CLAQConfig, ORConfig, quantize_matrix
from repro.core import packing
from repro.kernels import ops, ref as ref_lib

jax.config.update("jax_platform_name", "cpu")


def _make_stripe(rng, n, k_dim, bits, k_out=0):
    codes = rng.integers(0, 2 ** bits, size=(n, k_dim)).astype(np.int32)
    cb = np.sort(rng.normal(size=(k_dim, 2 ** bits)).astype(np.float32), axis=1)
    packed = packing.pack_codes(jnp.asarray(codes), bits)
    oi = ov = None
    if k_out:
        # distinct row ids per column (CLAQ reserves distinct top-k rows);
        # some slots invalid (-1)
        oi = np.stack([rng.permutation(n)[:k_out] for _ in range(k_dim)],
                      axis=1).astype(np.int32)
        oi[rng.random(oi.shape) < 0.2] = -1
        ov = rng.normal(size=(k_out, k_dim)).astype(np.float32)
    return packed, jnp.asarray(cb), (None if oi is None else jnp.asarray(oi)), \
        (None if ov is None else jnp.asarray(ov))


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("m,n,k_dim", [(4, 32, 64), (17, 96, 160), (1, 40, 128)])
def test_stripe_matmul_matches_oracle(bits, m, n, k_dim):
    rng = np.random.default_rng(bits * 1000 + m)
    packed, cb, _, _ = _make_stripe(rng, n, k_dim, bits)
    x = jnp.asarray(rng.normal(size=(m, k_dim)).astype(np.float32))
    y_ref = ref_lib.ref_dequant_matmul(x, packed, cb, None, None,
                                       bits=bits, n=n)
    y = ops.stripe_matmul(x, packed, cb, None, None, bits=bits, n=n,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("k_out", [1, 3, 8])
def test_outlier_epilogue(k_out):
    rng = np.random.default_rng(k_out)
    n, k_dim = 64, 96
    packed, cb, oi, ov = _make_stripe(rng, n, k_dim, 2, k_out=k_out)
    x = jnp.asarray(rng.normal(size=(5, k_dim)).astype(np.float32))
    y_ref = ref_lib.ref_dequant_matmul(x, packed, cb, oi, ov, bits=2, n=n)
    y = ops.stripe_matmul(x, packed, cb, oi, ov, bits=2, n=n, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    rng = np.random.default_rng(9)
    n, k_dim = 64, 128
    packed, cb, _, _ = _make_stripe(rng, n, k_dim, 4)
    x = jnp.asarray(rng.normal(size=(8, k_dim)).astype(np.float32)).astype(dtype)
    y_ref = ref_lib.ref_dequant_matmul(x.astype(jnp.float32), packed, cb,
                                       None, None, bits=4, n=n)
    y = ops.stripe_matmul(x.astype(jnp.float32), packed, cb, None, None,
                          bits=4, n=n, interpret=True,
                          compute_dtype=jnp.float32 if dtype == jnp.float32
                          else jnp.bfloat16)
    tol = 1e-3 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), rtol=tol, atol=tol * 10)


def test_full_quantized_tensor_qmatmul():
    """End-to-end: CLAQ-quantized matrix (AP stripes + OR outliers) through
    the kernel path equals the reference dequant matmul."""
    rng = np.random.default_rng(0)
    rows, cols = 96, 160
    W = rng.normal(size=(rows, cols)).astype(np.float32)
    W[:, :10] += rng.standard_t(df=2, size=(rows, 10)) * 4
    X = rng.normal(size=(256, cols)).astype(np.float32)
    H = jnp.asarray(2 * X.T @ X)
    qt, _, _ = quantize_matrix(jnp.asarray(W), H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=5, gptq_blocksize=32,
        ap=APConfig(2.5, 2, 4), orr=ORConfig(0.15)))
    x = jnp.asarray(rng.normal(size=(7, cols)).astype(np.float32))
    y_ref = ref_lib.ref_qmatmul(x, qt)
    y_ker = ops.qmatmul(x, qt, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)
    # XLA ref path agrees too
    y_xla = ops.qmatmul(x, qt, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_block_shape_sweep():
    rng = np.random.default_rng(2)
    n, k_dim = 128, 256
    packed, cb, _, _ = _make_stripe(rng, n, k_dim, 2)
    x = jnp.asarray(rng.normal(size=(16, k_dim)).astype(np.float32))
    y_ref = ref_lib.ref_dequant_matmul(x, packed, cb, None, None, bits=2, n=n)
    for bm, bn, bk in [(8, 32, 128), (16, 64, 256), (128, 128, 128)]:
        y = ops.stripe_matmul(x, packed, cb, None, None, bits=2, n=n,
                              bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3)
