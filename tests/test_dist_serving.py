"""Multi-device serving (8 forced host devices via subprocess): the
mesh-wired ServingEngine on a 2x4 (data x model) mesh must emit tokens
bit-identical to the single-device engine on an AP+OR-quantized model
with bucketed admission, and the compiled decode step must stay
weight-resident per shard — no all-gather of a weight-sized operand
(hlo_analysis.collective_instructions).

The PreparedQuantizedTensor units shard along N in whole (bn, bk) tiles
(plan_bn=32 so the smoke model's 128/256-row matrices split over
model=4); parity holds bitwise because N/dp sharding never splits a
contraction — each shard dequantizes and contracts its own rows.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.serve import ServingEngine
from repro.models import api
from repro.configs import get_smoke_config

jax.config.update("jax_platform_name", "cpu")


def test_trivial_mesh_engine_matches_no_mesh():
    """The mesh wiring (device_put + mesh-scoped jits) is exercised
    in-process on a 1x1 mesh: must behave exactly like mesh=None."""
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=64,
                              n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng0 = ServingEngine(params, cfg, n_slots=2, max_len=32, min_bucket=8)
    engm = ServingEngine(params, cfg, n_slots=2, max_len=32, min_bucket=8,
                         mesh=mesh)
    for eng in (eng0, engm):
        eng.add_requests([[1, 2, 3], [5, 6, 7, 8, 9]], max_new_tokens=4)
        eng.run_to_completion()
    t0 = [r.tokens for r in eng0.take_finished().values()]
    tm = [r.tokens for r in engm.take_finished().values()]
    assert t0 == tm
    assert engm.stats()["mesh"] == {"data": 1, "model": 1}


def test_mesh_preempt_resume_parity_and_cache_pinning():
    """Preemption on a mesh-wired engine: the jitted slot clear and the
    resume insert must leave the sharded cache PINNED to the engine's
    NamedShardings (no placement drift into the decode jit), and resumed
    requests must emit tokens bit-identical to the no-mesh engine."""
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=64,
                              n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3], [5, 6, 7, 8, 9]]
    eng0 = ServingEngine(params, cfg, n_slots=2, max_len=32, min_bucket=8)
    uids0 = eng0.add_requests(prompts, max_new_tokens=8)
    eng0.run_to_completion()
    fin0 = eng0.take_finished()
    base = [fin0[u].tokens for u in uids0]

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServingEngine(params, cfg, n_slots=2, max_len=32, min_bucket=8,
                        mesh=mesh)
    uids = eng.add_requests(prompts, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    eng.set_cache_pressure(4)                   # below both fills: preempt
    eng.step()
    assert eng.stats()["preemptions"] == 2 and not eng.active

    def assert_pinned():
        flat = jax.tree_util.tree_flatten_with_path(eng.cache)[0]
        want = jax.tree_util.tree_flatten_with_path(eng._cache_shardings)[0]
        for (p, leaf), (_, sh) in zip(flat, want):
            assert leaf.sharding.is_equivalent_to(sh, leaf.ndim), p

    assert_pinned()                             # after the jitted clear
    eng.set_cache_pressure(None)
    eng.step()                                  # resume both
    assert eng.stats()["resumes"] == 2
    assert_pinned()                             # after the resume insert
    eng.run_to_completion()
    fin = eng.take_finished()
    assert [fin[u].tokens for u in uids] == base
    assert all(fin[u].preemptions == 1 for u in uids)


def test_sharded_speculative_token_parity(subproc):
    """Self-speculative decoding on a 2x4 mesh: the draft/target pair
    (quantized from ONE calibration pass) served with propose/verify/
    rollback windows must emit tokens bit-identical to the single-device
    VANILLA engine — losslessness and shard-parity composed.  Spec trace
    counters stay constant (one draft decode, one verify) and the draft's
    prepared plans shard over "model" like the target's."""
    subproc("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize_with_draft
from repro.models import api
from repro.serve import ServingEngine, SpecConfig
from repro.kernels.plan import PreparedQuantizedTensor

cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                          n_layers=2)
params = api.init_params(jax.random.PRNGKey(0), cfg)
qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4, gptq_blocksize=32,
                  ap=APConfig(2.2, 2, 4), orr=ORConfig(0.1))
calib = calibration_set(vocab=cfg.vocab, n_segments=4, seq_len=32)
(qparams, rep), (dparams, drep) = claq_quantize_with_draft(
    params, cfg, calib, qcfg, draft_bits=2)
assert drep.mean_effective_bits < rep.mean_effective_bits

def serve(eng, prompts, max_new=6):
    uids = eng.add_requests(prompts, max_new_tokens=max_new)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]

wave1 = [[1, 2, 3], [4, 5, 6, 7, 8, 9], [10, 11, 12, 13, 14, 15, 16, 17, 18],
         [20, 21]]
wave2 = [[7, 7, 7, 7, 7], [9, 8, 7]]

# ground truth: single-device VANILLA greedy decode
eng0 = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                     plan_bn=32)
t0 = serve(eng0, wave1) + serve(eng0, wave2)

mesh = jax.make_mesh((2, 4), ("data", "model"))
for gamma in (2, 4):
    eng = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                        plan_bn=32, mesh=mesh, draft_params=dparams,
                        spec=SpecConfig(gamma=gamma, draft_bits=2))
    t = serve(eng, wave1) + serve(eng, wave2)
    assert t == t0, (gamma, t, t0)
    st = eng.stats()
    assert st["verify_traces"] == 1 and st["draft_decode_traces"] == 1
    assert st["decode_traces"] == 0
    print(f"gamma={gamma} sharded spec parity OK, acceptance "
          f"{st['acceptance_rate']:.2f}, {st['tokens_per_step']:.2f} tok/step")

# the draft's prepared units shard over model=4 like the target's
n_sharded = 0
def visit(leaf):
    global n_sharded
    if isinstance(leaf, PreparedQuantizedTensor) and leaf.shards_whole_tiles(4):
        n_sharded += 1
jax.tree_util.tree_map(
    visit, eng.draft_params,
    is_leaf=lambda l: isinstance(l, PreparedQuantizedTensor))
assert n_sharded > 0, "no draft unit sharded -> draft replicated everywhere"
print("draft sharded units:", n_sharded)
""", devices=8, timeout=1200)


def test_mesh_paged_decode_parity(subproc):
    """Paged KV cache on a 2x4 (data x model) mesh: pool pages shard over
    "data" (and kp/vp heads over "model"), tables replicate, and decode
    tokens stay bit-identical to the single-device CONTIGUOUS engine —
    the paged-parity claim and the shard-parity claim composed.  Also
    exercises preempt/resume on the mesh so the jitted slot clear, the
    host-side page release, and the batch-1 replay reinsert all run with
    sharded pool leaves."""
    subproc("""
import dataclasses
import jax
from repro.configs import get_smoke_config
from repro.models import api
from repro.serve import ServingEngine

cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                          n_layers=2)
params = api.init_params(jax.random.PRNGKey(0), cfg)

def serve(eng, prompts, max_new=6):
    uids = eng.add_requests(prompts, max_new_tokens=max_new)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]

wave1 = [[1, 2, 3], [4, 5, 6, 7, 8, 9], [10, 11, 12, 13, 14, 15, 16, 17, 18],
         [20, 21]]
wave2 = [[7, 7, 7, 7, 7], [9, 8, 7]]          # slot + page reuse

# ground truth: single-device CONTIGUOUS fp engine
eng0 = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                     prepare=False)
t0 = serve(eng0, wave1) + serve(eng0, wave2)

# kv_pages=31 -> pool leaves carry 32 page rows (31 + scratch), which the
# data=2 axis splits evenly; default capacity (4*64/8=32 pages -> 33 rows)
# would not.
mesh = jax.make_mesh((2, 4), ("data", "model"))
paged_kw = dict(kv_layout="paged", page_size=8, kv_pages=31)
eng = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                    prepare=False, mesh=mesh, **paged_kw)
t = serve(eng, wave1) + serve(eng, wave2)
assert t == t0, (t, t0)

# the engine's declared placement: page axis over "data", kp/vp heads
# over "model"; the page tables replicate (any slot may name any page).
# Checked on _cache_shardings, the pins every insert restores — live
# leaves may carry whatever output sharding the decode jit propagated.
flat = jax.tree_util.tree_flatten_with_path(eng._cache_shardings)[0]
specs = {jax.tree_util.keystr(p): tuple(sh.spec) for p, sh in flat}
kp = [s for p, s in specs.items() if p.endswith(".kp")]
assert kp and all(s[1] == "data" and "model" in s for s in kp), specs
tables = [s for p, s in specs.items() if p.endswith(".table")]
assert tables and all(all(e is None for e in s) for s in tables), specs

# preempt/resume with sharded pages: release + replay stays bitwise
eng2 = ServingEngine(params, cfg, n_slots=4, max_len=64, min_bucket=8,
                     prepare=False, mesh=mesh, **paged_kw)
uids = eng2.add_requests(wave1, max_new_tokens=6)
for _ in range(2):
    eng2.step()
eng2.set_cache_pressure(4)          # every fill >= 4 now -> all preempt
eng2.step()
assert eng2.stats()["preemptions"] == 4 and not eng2.active
assert not eng2._req_pages          # preemption released every page
eng2.set_cache_pressure(None)
eng2.run_to_completion()
fin = eng2.take_finished()
assert [fin[u].tokens for u in uids] == t0[:4]
assert eng2.stats()["resumes"] == 4
print("mesh paged parity OK: tokens bitwise, pages sharded over data,"
      " 4 preempted/resumed")
""", devices=8, timeout=900)


def test_sharded_engine_token_parity_and_weight_residency(subproc):
    subproc("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.core import APConfig, CLAQConfig, ORConfig
from repro.data import calibration_set
from repro.launch.quantize import claq_quantize
from repro.models import api
from repro.serve import ServingEngine
from repro.analysis import REGISTRY, run_rules
from repro.analysis.artifacts import weight_shard_threshold
from repro.dist.hlo_analysis import analyze_hlo

# --- AP+OR-quantized smoke model (the paper's deployment format) --------
cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                          n_layers=2)
params = api.init_params(jax.random.PRNGKey(0), cfg)
qcfg = CLAQConfig(bits=2, method="kmeans", kmeans_iters=4, gptq_blocksize=32,
                  ap=APConfig(2.2, 2, 4), orr=ORConfig(0.1))
calib = calibration_set(vocab=cfg.vocab, n_segments=4, seq_len=32)
qparams, report = claq_quantize(params, cfg, calib, qcfg)
assert 2.0 < report.mean_effective_bits < 2.6

def serve(eng, prompts, max_new=6):
    uids = eng.add_requests(prompts, max_new_tokens=max_new)
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]

# bucketed admission: lengths spanning several power-of-2 buckets
wave1 = [[1, 2, 3], [4, 5, 6, 7, 8, 9], [10, 11, 12, 13, 14, 15, 16, 17, 18],
         [20, 21]]
wave2 = [[7, 7, 7, 7, 7], [9, 8, 7]]          # slot reuse after retirement

# plan_bn=32: the smoke model's 128/256-row matrices split into 4/8 whole
# (bn, bk) tiles -> every quantized unit shards over model=4
eng1 = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                     plan_bn=32)
t1 = serve(eng1, wave1) + serve(eng1, wave2)

mesh = jax.make_mesh((2, 4), ("data", "model"))
eng2 = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                     plan_bn=32, mesh=mesh)
t2 = serve(eng2, wave1) + serve(eng2, wave2)

assert t1 == t2, (t1, t2)                      # bit-identical greedy tokens
assert all(len(t) == 6 for t in t1)
assert eng2.bucketing.enabled and eng2.prefill_traces >= 1

# --- decode stays weight-resident per shard -----------------------------
# threshold = largest sharded quantized plane; computed by the shared
# helper the HLO-AG1 contract rule uses (repro.analysis.artifacts)
threshold = weight_shard_threshold(eng2.params, model_parts=4)
assert threshold, "no quantized unit sharded -> vacuous check"

# --- preemption on the real 2x4 mesh: the jitted slot clear and the ----
# --- batch-1 resume replay must preserve bitwise token parity ----------
eng3 = ServingEngine(qparams, cfg, n_slots=4, max_len=64, min_bucket=8,
                     plan_bn=32, mesh=mesh)
uids3 = eng3.add_requests(wave1, max_new_tokens=6)
for _ in range(2):
    eng3.step()
eng3.set_cache_pressure(4)          # every fill >= 4 now -> all preempt
eng3.step()
st3 = eng3.stats()
assert st3["preemptions"] == 4 and not eng3.active, st3["preemptions"]
eng3.set_cache_pressure(None)
eng3.run_to_completion()
fin3 = eng3.take_finished()
t3 = [fin3[u].tokens for u in uids3]
assert t3 == t1[:4], (t3, t1[:4])
assert eng3.stats()["resumes"] == 4
print("mesh preemption parity OK: 4 preempted, 4 resumed, bitwise tokens")

txt = eng2.lower_decode().compile().as_text()
res = analyze_hlo(txt)
assert res["flops"] > 0                        # the analyzer parsed the module
rep = run_rules([REGISTRY["HLO-AG1"], REGISTRY["HLO-CB1"]],
                {"hlo": {"decode": txt}, "weight_shard_bytes": threshold})
assert rep.rules_run == ["HLO-AG1", "HLO-CB1"] and not rep.findings, (
    rep.render())
print("dist serving parity OK: decode clean under HLO-AG1/HLO-CB1,"
      " weight-shard threshold", threshold, "B")
""", devices=8, timeout=900)
