"""Paged KV cache: parity with the contiguous layout, int8 resident
pages, prefix sharing, and pool backpressure (DESIGN.md §11).

The headline claim is BITWISE: paged fp decode logits equal contiguous
decode logits exactly (the gathered page view has the contiguous cache's
shape, so XLA reduces identically, and fresh pages are zeroed so masked
rows contribute exactly 0.0) — asserted on raw decode logits, not just
argmax tokens.  Everything else (bucketed admission, preempt/resume
under pressure, speculative windows, prefix sharing with copy-on-write)
is asserted token-for-token against a contiguous reference engine.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.models.layers import (init_paged_kv_cache, paged_write_ids,
                                 pool_view, pool_write)
from repro.serve import (PoolExhausted, RequestState, ServingEngine,
                         SpecConfig)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def fp_model():
    cfg = dataclasses.replace(get_smoke_config("llama1_7b"), vocab=128,
                              n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [list(range(1, n + 1)) for n in (5, 9, 17, 3)]


def _engine(fp_model, **kw):
    cfg, params = fp_model
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("min_bucket", 8)
    kw.setdefault("prepare", False)
    return ServingEngine(params, cfg, **kw)


def _drain(eng, prompts, max_new=8, batch=True):
    if batch:
        uids = eng.add_requests(prompts, max_new_tokens=max_new)
    else:
        uids = [eng.add_request(p, max_new_tokens=max_new) for p in prompts]
    eng.run_to_completion()
    fin = eng.take_finished()
    return [fin[u].tokens for u in uids]


PAGED = dict(kv_layout="paged", page_size=8)


# ------------------------------------------------------------ bitwise parity

def test_paged_decode_logits_bitwise_equal_contiguous(fp_model):
    """Raw decode logits — not just tokens — must match bit for bit after
    bucketed admission of mixed prompt lengths."""
    eng_c = _engine(fp_model)
    eng_p = _engine(fp_model, **PAGED)
    for eng in (eng_c, eng_p):
        eng.add_requests(PROMPTS, max_new_tokens=8)
    if eng_p._paged:
        eng_p._ensure_capacity(1)
        eng_p._sync_tables()
    toks = jnp.asarray(eng_c.last_token, jnp.int32)
    lc, _, _ = eng_c._decode(eng_c.params, toks, eng_c.cache, None)
    lp, _, _ = eng_p._decode(eng_p.params, toks, eng_p.cache, None)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))


def test_paged_serving_token_parity_bucketed(fp_model):
    base = _drain(_engine(fp_model), PROMPTS)
    paged = _drain(_engine(fp_model, **PAGED), PROMPTS)
    assert base == paged


def test_paged_parity_under_preempt_resume(fp_model):
    """Cache-pressure preemption + bit-identical resume must hold with
    pages exactly as with contiguous slots."""
    def run(**kw):
        eng = _engine(fp_model, **kw)
        uids = eng.add_requests(PROMPTS, max_new_tokens=10)
        for i in range(200):
            if not eng.active and not len(eng.queue):
                break
            if i == 2:
                eng.set_cache_pressure(12)
            if i == 5:
                eng.set_cache_pressure(None)
            eng.step()
        fin = eng.take_finished()
        return [fin[u].tokens for u in uids], eng

    base, _ = run()
    paged, ep = run(**PAGED)
    assert base == paged
    assert ep.preemptions >= 1 and ep.resumes >= 1, (
        "pressure window never preempted: the parity claim is vacuous")
    # every page is released at retirement; only the prefix registry's
    # pins (kept for future sharing) may remain
    assert not ep._req_pages
    ep.prefix_registry.clear()
    assert ep.allocator.pages_in_use == 0


@pytest.mark.parametrize("gamma", [2, 4])
def test_paged_parity_speculative_window(fp_model, gamma):
    """Propose/verify/rollback over paged caches (both the draft's and
    the target's) emits exactly the vanilla greedy tokens."""
    cfg, params = fp_model
    base = _drain(_engine(fp_model), PROMPTS, max_new=9)
    eng = _engine(fp_model, draft_params=params, spec=SpecConfig(gamma=gamma),
                  **PAGED)
    assert _drain(eng, PROMPTS, max_new=9) == base
    # the window rolled both caches back cleanly: every request released
    # its pages (only registry pins for future sharing remain)
    assert not eng._req_pages
    eng.prefix_registry.clear()
    assert eng.allocator.pages_in_use == 0


# ------------------------------------------------------------------ int8 pages

def test_int8_page_roundtrip_error_bound():
    """Quantize-to-page then gather-dequant: per-element error is bounded
    by scale/2, scale = per-token-row absmax / 127."""
    rng = np.random.default_rng(0)
    B, ps, KH, D = 2, 8, 2, 16
    rows = jnp.asarray(rng.normal(size=(B, ps, KH, D)) * 3, jnp.float32)
    cache = init_paged_kv_cache(B, 32, KH, D, page_size=ps,
                                n_pages=B * 4, dtype=jnp.float32,
                                kv_dtype="int8")
    pid, off = paged_write_ids(cache.table.at[:, 0].set(
        jnp.arange(B)), jnp.zeros((B,), jnp.int32), ps, ps,
        cache.kp.shape[0] - 1)
    kp, k_scale = pool_write(cache.kp, cache.k_scale, pid, off, rows)
    got = pool_view(kp, k_scale, jnp.arange(B)[:, None], jnp.float32)
    got = np.asarray(got).reshape(B, ps, KH, D)
    flat = np.asarray(rows).reshape(B, ps, -1)
    scale = np.abs(flat).max(-1) / 127.0          # (B, ps) per token row
    err = np.abs(got - np.asarray(rows)).reshape(B, ps, -1).max(-1)
    assert np.all(err <= scale / 2 + 1e-7), (err, scale)
    # int8 is genuinely resident: the pool leaf stores int8, not fp
    assert kp.dtype == jnp.int8 and k_scale.dtype == jnp.float32


def test_int8_serving_completes_with_bounded_drift(fp_model):
    """int8 resident pages serve end to end; per-request budgets are
    honored and the engine reports the resident dtype and a ~4x byte
    saving over the fp pool."""
    toks = _drain(_engine(fp_model, **PAGED, kv_dtype="int8"), PROMPTS)
    assert [len(t) for t in toks] == [8, 8, 8, 8]
    eng = _engine(fp_model, **PAGED, kv_dtype="int8")
    st = eng.stats()["paged"]
    assert st["kv_dtype"] == "int8"
    fp_bytes = _engine(fp_model, **PAGED).stats()["paged"]["bytes_per_page"]
    assert st["bytes_per_page"] < fp_bytes / 3
    # int8 history cannot be replayed bitwise through the fp decode jit:
    # pressure must truncate, never preempt
    assert eng._preemptible is False


def test_kv_int8_rung_pressure_truncates_not_preempts(fp_model):
    """A kv_int8 admission on an fp pool is never preempted for cache
    pressure: resume replays the prefix in fp numerics, which cannot
    reproduce the int8-quantized cache history.  Pressure retires it as
    a typed truncation instead (the same contract as priority preempts
    and PREFILLING cancels, which already exclude kv_int8 victims)."""
    eng = _engine(fp_model, **PAGED)
    eng._kv_int8_admission = True        # what the controller rung projects
    uids = eng.add_requests(PROMPTS[:2], max_new_tokens=10)
    for _ in range(3):
        eng.step()
    assert all(eng.active[u].kv_int8 for u in uids)
    eng.set_cache_pressure(4)            # below both fills
    eng.step()
    fin = eng.take_finished()
    assert all(fin[u].state is RequestState.TRUNCATED for u in uids)
    assert all(fin[u].diagnostics["kind"] == "cache_pressure" for u in uids)
    assert eng.preemptions == 0


# -------------------------------------------------------------- prefix sharing

def test_prefix_sharing_parity_and_page_savings(fp_model):
    sys_p = list(range(1, 25))
    prompts = [sys_p + [30 + i] for i in range(4)]
    base = _drain(_engine(fp_model), prompts, max_new=6, batch=False)

    shared = _engine(fp_model, **PAGED)
    assert _drain(shared, prompts, max_new=6, batch=False) == base
    private = _engine(fp_model, **PAGED, share_prefixes=False)
    assert _drain(private, prompts, max_new=6, batch=False) == base

    ss, sp = shared.stats()["paged"], private.stats()["paged"]
    assert ss["prefix_hits"] == 3                  # requests 2..4 shared
    assert ss["prefix_shared_tokens"] == 3 * 24
    # copy-on-write fired when each sharer first wrote a shared page
    assert ss["cow_copies"] >= 1
    assert sp["cow_copies"] == 0 and sp["prefix_hits"] == 0
    # the whole point: fewer physical pages for the same served tokens
    assert ss["peak_pages_in_use"] < sp["peak_pages_in_use"]


def test_kv_int8_rung_prefixes_never_registered_on_fp_pool(fp_model):
    """A fake-quantized prefix must not enter the sharing registry: a
    later NOMINAL request reusing it would silently read int8 K/V and
    lose bitwise parity with an uncontrolled run."""
    sys_p = list(range(1, 25))
    eng = _engine(fp_model, **PAGED)
    eng._kv_int8_admission = True
    eng.submit(sys_p + [40], max_new_tokens=4)
    eng.run_to_completion()
    eng.take_finished()
    assert len(eng.prefix_registry) == 0     # quantized prefix not shared
    # a nominal admission on the same engine stays bit-identical to the
    # contiguous baseline (nothing to share, so it prefills in full fp)
    eng._kv_int8_admission = False
    base = _drain(_engine(fp_model), [sys_p + [41]], max_new=6, batch=False)
    assert _drain(eng, [sys_p + [41]], max_new=6, batch=False) == base
    assert eng.stats()["paged"]["prefix_hits"] == 0
    assert len(eng.prefix_registry) == 1     # nominal prefixes still register


# ----------------------------------------------------------- pool backpressure

def test_pool_exhaustion_raises_typed_at_admission(fp_model):
    eng = _engine(fp_model, **PAGED, kv_pages=6)
    with pytest.raises(PoolExhausted):
        eng.add_requests([list(range(1, 30))] * 4, max_new_tokens=4)
    # all-or-nothing: the failed batch left no page reference behind
    assert eng.allocator.pages_in_use == 0 and not eng.active


def test_pool_backpressure_drains_through_queue(fp_model):
    """A pool sized for ~one request at a time still finishes every
    submitted request: queued work waits for pages, admitted work runs."""
    eng = _engine(fp_model, **PAGED, kv_pages=8, n_slots=2)
    uids = [eng.submit(list(range(1, 18)), max_new_tokens=6)
            for _ in range(3)]
    assert eng.run_to_completion(max_steps=400) == []
    fin = eng.take_finished()
    assert all(fin[u].state.value == "finished" for u in uids)
    assert all(len(fin[u].tokens) == 6 for u in uids)
    assert not eng._req_pages
    eng.prefix_registry.clear()
    assert eng.allocator.pages_in_use == 0


def test_decode_time_exhaustion_retires_truncated_with_diagnostics(fp_model):
    """When running requests outgrow a pool with nothing left to evict or
    preempt, the starved request retires TRUNCATED with pool diagnostics
    — typed, observable backpressure, not a silent clamp."""
    eng = _engine(fp_model, **PAGED, kv_pages=4, n_slots=2,
                  on_pressure="truncate")
    uids = eng.add_requests([list(range(1, 14)), list(range(1, 14))],
                            max_new_tokens=20)
    eng.run_to_completion(max_steps=200)
    fin = eng.take_finished()
    trunc = [fin[u] for u in uids if fin[u].state.value == "truncated"]
    assert trunc, "pool never starved: the scenario is vacuous"
    assert trunc[0].diagnostics["kind"] == "pool_exhausted"


# -------------------------------------------------------------- config guards

def test_paged_config_validation(fp_model):
    cfg, params = fp_model
    with pytest.raises(ValueError):
        _engine(fp_model, kv_layout="paged", page_size=7)   # 7 ∤ 64
    with pytest.raises(ValueError):
        _engine(fp_model, page_size=8)       # paged knob, contiguous layout
    with pytest.raises(ValueError):
        _engine(fp_model, **PAGED, kv_dtype="int4")
    ring = dataclasses.replace(cfg, attn_window=16)
    with pytest.raises(NotImplementedError):
        api.make_cache(ring, 2, 64, dtype=jnp.float32, page_size=8)


def test_stats_reports_cache_utilization(fp_model):
    eng = _engine(fp_model, **PAGED)
    eng.add_requests(PROMPTS, max_new_tokens=4)
    st = eng.stats()["paged"]
    for key in ("page_size", "n_pages", "pages_in_use", "pages_free",
                "pool_utilization", "peak_pages_in_use",
                "peak_pages_per_request", "kv_dtype", "bytes_per_page",
                "bytes_resident", "bytes_pool", "bytes_contiguous_fp",
                "prefix_hits", "prefix_shared_tokens", "cow_copies",
                "page_evictions", "registry_entries"):
        assert key in st, key
    assert st["pages_in_use"] + st["pages_free"] == st["n_pages"]
    assert 0 < st["pool_utilization"] <= 1
    # capacity-equivalent pool: same bytes as the contiguous fp layout
    assert st["bytes_pool"] == st["bytes_contiguous_fp"]
    assert st["bytes_resident"] < st["bytes_pool"]
