"""Ahead-of-time inference plans (kernels/plan.py): prepared-vs-reference
parity across bit-widths, mixed-precision stripe layouts, outlier configs,
and odd shapes; plus the launch-count contract — a prepared matmul issues
exactly one pallas_call per distinct stripe bit-width."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import APConfig, CLAQConfig, ORConfig, quantize_matrix
from repro.core import packing
from repro.core.quantized import QuantStripe, QuantizedTensor
from repro.kernels import dequant_matmul as dm
from repro.kernels import ops, ref as ref_lib
from repro.kernels.plan import (PreparedQuantizedTensor, prepare_for_inference,
                                prepare_tree)

jax.config.update("jax_platform_name", "cpu")


def _make_qt(rng, rows, stripe_spec, k_out=0):
    """Synthetic multi-stripe QuantizedTensor.  stripe_spec: [(bits, n_cols)].
    Covers layouts build_quantized_tensor never emits (duplicate bit-widths,
    arbitrary stripe order) so the plan's grouping is exercised directly."""
    cols = sum(n for _, n in stripe_spec)
    stripes = []
    for bits, n_cols in stripe_spec:
        codes = rng.integers(0, 2 ** bits, size=(rows, n_cols)).astype(np.int32)
        cb = np.sort(rng.normal(size=(n_cols, 2 ** bits)).astype(np.float32),
                     axis=1)
        stripes.append(QuantStripe(
            packed=packing.pack_codes(jnp.asarray(codes), bits),
            codebook=jnp.asarray(cb), bits=bits))
    col_perm = jnp.asarray(rng.permutation(cols).astype(np.int32))
    if k_out > 0:
        oi = np.stack([rng.permutation(rows)[:k_out] for _ in range(cols)],
                      axis=1).astype(np.int32)
        ov = rng.normal(size=(k_out, cols)).astype(np.float32)
        cnt = rng.integers(0, k_out + 1, size=(cols,)).astype(np.int32)
    else:
        oi = np.zeros((0, cols), np.int32)
        ov = np.zeros((0, cols), np.float32)
        cnt = np.zeros((cols,), np.int32)
    return QuantizedTensor(
        stripes=tuple(stripes), col_perm=col_perm,
        out_idx=jnp.asarray(oi), out_val=jnp.asarray(ov),
        out_count=jnp.asarray(cnt), shape=(rows, cols))


def _check_parity(qt, m=7, seed=0, atol=1e-3):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, qt.cols)).astype(np.float32))
    pqt = prepare_for_inference(qt)
    np.testing.assert_allclose(np.asarray(pqt.dequantize()),
                               np.asarray(qt.dequantize()),
                               rtol=1e-6, atol=1e-6)
    y_ref = ref_lib.ref_qmatmul(x, qt)
    y = ops.qmatmul(x, pqt, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=atol)
    # the default kernel path folds the gather into the kernel; it must be
    # BITWISE the pre-fold XLA-gather path at every layout in this suite
    y_pre = ops.prepared_qmatmul(x, pqt, gather="xla")
    assert np.array_equal(np.asarray(y), np.asarray(y_pre)), \
        "in-kernel gather diverged bitwise from the XLA-gather path"
    y_xla = ops.qmatmul(x, pqt, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    return pqt


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("k_out", [0, 3])
def test_single_bitwidth_parity(bits, k_out):
    rng = np.random.default_rng(bits * 10 + k_out)
    qt = _make_qt(rng, rows=64, stripe_spec=[(bits, 96)], k_out=k_out)
    pqt = _check_parity(qt)
    assert len(pqt.groups) == 1


@pytest.mark.parametrize("spec", [
    [(2, 80), (4, 48)],              # the layout build_quantized_tensor emits
    [(2, 40), (3, 56), (4, 32)],     # three distinct bit-widths
    [(2, 24), (4, 40), (2, 32)],     # duplicate bit-width stripes fuse
])
@pytest.mark.parametrize("k_out", [0, 2])
def test_mixed_precision_parity(spec, k_out):
    rng = np.random.default_rng(len(spec) * 100 + k_out)
    qt = _make_qt(rng, rows=96, stripe_spec=spec, k_out=k_out)
    pqt = _check_parity(qt)
    assert len(pqt.groups) == len({b for b, _ in spec})


def test_non_multiple_of_block_shapes():
    rng = np.random.default_rng(5)
    # rows not a multiple of 32, stripe columns not multiples of 128
    qt = _make_qt(rng, rows=40, stripe_spec=[(2, 72), (4, 19)], k_out=2)
    pqt = _check_parity(qt, m=17)
    assert pqt.n_padded % 32 == 0
    for g in pqt.groups:
        assert g.k_padded % g.bk == 0


def test_end_to_end_claq_tensor_parity():
    """Full CLAQ recipe (AP stripes + OR outliers) through the plan."""
    rng = np.random.default_rng(0)
    rows, cols = 96, 160
    W = rng.normal(size=(rows, cols)).astype(np.float32)
    W[:, :10] += rng.standard_t(df=2, size=(rows, 10)) * 4
    X = rng.normal(size=(256, cols)).astype(np.float32)
    H = jnp.asarray(2 * X.T @ X)
    qt, _, _ = quantize_matrix(jnp.asarray(W), H, CLAQConfig(
        bits=2, method="kmeans", kmeans_iters=5, gptq_blocksize=32,
        ap=APConfig(2.5, 2, 4), orr=ORConfig(0.15)))
    pqt = _check_parity(qt)
    # the paper layout: one stripe per bit-class -> one group per bit-class
    assert len(pqt.groups) == len({s.bits for s in qt.stripes})


def test_launch_count_is_distinct_bitwidths():
    """Regression: the fused dispatch issues exactly one pallas_call per
    distinct stripe bit-width — NOT one per stripe."""
    rng = np.random.default_rng(9)
    spec = [(2, 40), (4, 56), (2, 24), (3, 32)]   # 4 stripes, 3 bit-widths
    qt = _make_qt(rng, rows=64, stripe_spec=spec, k_out=1)
    x = jnp.asarray(rng.normal(size=(5, qt.cols)).astype(np.float32))

    before = dm.launch_count
    y_unprepared = ops.qmatmul(x, qt, use_kernel=True, interpret=True)
    unprepared_launches = dm.launch_count - before
    assert unprepared_launches == len(spec)

    pqt = prepare_for_inference(qt)
    before = dm.launch_count
    y_prepared = ops.qmatmul(x, pqt, use_kernel=True, interpret=True)
    prepared_launches = dm.launch_count - before
    assert prepared_launches == len({b for b, _ in spec}) == 3

    # folding the gather into the kernel must not change the launch
    # contract: both gather modes issue one launch per distinct bit-width
    before = dm.launch_count
    y_pre = ops.prepared_qmatmul(x, pqt, gather="xla")
    assert dm.launch_count - before == prepared_launches
    assert np.array_equal(np.asarray(y_prepared), np.asarray(y_pre))

    np.testing.assert_allclose(np.asarray(y_prepared),
                               np.asarray(y_unprepared),
                               rtol=1e-4, atol=1e-3)


def _with_identity_perm(qt):
    return QuantizedTensor(
        stripes=qt.stripes, col_perm=jnp.arange(qt.cols, dtype=jnp.int32),
        out_idx=qt.out_idx, out_val=qt.out_val, out_count=qt.out_count,
        shape=qt.shape)


def test_identity_perm_plans_are_x_aligned():
    """Single-bit-width tensors carry an identity column permutation
    (build_quantized_tensor sorts within each bit-class), so their plans
    must drop per-column indexing entirely: x_start set, x_idx None — the
    kernel then reads raw x blocks and the matmul is gather-free."""
    rng = np.random.default_rng(21)
    qt = _with_identity_perm(
        _make_qt(rng, rows=64, stripe_spec=[(3, 200)], k_out=2))
    pqt = _check_parity(qt)
    assert pqt.x_gather_free
    assert pqt.groups[0].x_start == 0 and pqt.groups[0].x_idx is None

    # the end-to-end integer-bit recipe really hits this path
    W = rng.normal(size=(64, 96)).astype(np.float32)
    qte, _, _ = quantize_matrix(jnp.asarray(W), None, CLAQConfig(
        bits=3, method="kmeans", kmeans_iters=3, gptq_blocksize=32))
    assert prepare_for_inference(qte).x_gather_free


def test_permuted_plans_carry_block_index_tables():
    """Permuted / mixed-precision layouts fall back to per-bk-block index
    tables: x_idx holds exactly the group's slice of gather_idx (same
    fused order — the bit-identity contract), padding slots = cols."""
    rng = np.random.default_rng(22)
    qt = _make_qt(rng, rows=64, stripe_spec=[(2, 80), (4, 48)], k_out=2)
    pqt = _check_parity(qt)
    assert not pqt.x_gather_free
    off = 0
    for g in pqt.groups:
        assert g.x_start is None and g.x_idx.shape == (g.k_padded // g.bk,
                                                       g.bk)
        np.testing.assert_array_equal(
            np.asarray(g.x_idx).ravel(),
            np.asarray(pqt.gather_idx[off:off + g.k_padded]))
        off += g.k_padded
    # padded slots point at `cols` (the zero fill), never at a real column
    pad = np.asarray(pqt.groups[0].x_idx).ravel()[pqt.groups[0].k_cols:]
    assert (pad == qt.cols).all()


def test_plan_cached_on_tensor_and_prepare_tree():
    rng = np.random.default_rng(3)
    qt = _make_qt(rng, rows=32, stripe_spec=[(2, 48)])
    assert qt.prepare() is qt.prepare()

    params = {"layer": {"kernel": qt, "bias": jnp.zeros((32,))},
              "norm": {"scale": jnp.ones((48,))}}
    prepared = prepare_tree(params)
    assert isinstance(prepared["layer"]["kernel"], PreparedQuantizedTensor)
    assert prepared["norm"]["scale"].shape == (48,)
    # idempotent: preparing an already-prepared tree is the identity
    again = prepare_tree(prepared)
    assert again["layer"]["kernel"] is prepared["layer"]["kernel"]


def test_layer_stacked_tensor_preparation():
    """launch.quantize stacks per-layer QuantizedTensors (leading L dim on
    every data leaf, per-matrix `shape` meta).  Preparation must vmap over
    the stack and slice back per layer — the ServingEngine path."""
    rng = np.random.default_rng(11)
    spec = [(2, 48), (4, 32)]
    qts = [_make_qt(np.random.default_rng(100 + i), rows=64,
                    stripe_spec=spec, k_out=2) for i in range(3)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *qts)
    assert stacked.stripes[0].packed.ndim == 3

    pst = prepare_for_inference(stacked)
    assert pst.gather_idx.shape[0] == 3
    x = jnp.asarray(rng.normal(size=(5, qts[0].cols)).astype(np.float32))
    for i, qt in enumerate(qts):
        layer = jax.tree_util.tree_map(lambda a: a[i], pst)
        np.testing.assert_allclose(np.asarray(layer.dequantize()),
                                   np.asarray(qt.dequantize()),
                                   rtol=1e-6, atol=1e-6)
        y = ops.qmatmul(x, layer, use_kernel=True, interpret=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref_lib.ref_qmatmul(x, qt)),
                                   rtol=1e-4, atol=1e-3)
    # prepare_tree hits stacked leaves too (what the engine actually does)
    tree = prepare_tree({"blocks": {"kernel": stacked}})
    assert isinstance(tree["blocks"]["kernel"], PreparedQuantizedTensor)


def test_prepared_expert_weight_dequant():
    """MoE expert leaves (leading E axis) prepared by the engine must still
    materialize through models.moe._expert_weight."""
    from repro.models.moe import _expert_weight
    qts = [_make_qt(np.random.default_rng(200 + e), rows=32,
                    stripe_spec=[(2, 24), (4, 24)], k_out=1)
           for e in range(2)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *qts)
    prepared = prepare_for_inference(stacked)
    w = _expert_weight(prepared, jnp.float32)       # (E, in, out)
    assert w.shape == (2, 48, 32)
    for e, qt in enumerate(qts):
        np.testing.assert_allclose(np.asarray(w[e]),
                                   np.asarray(qt.dequantize()).T,
                                   rtol=1e-6, atol=1e-6)


def test_prepared_tensor_is_a_pytree():
    """Prepared leaves must flow through jit (the serving engine's params)."""
    rng = np.random.default_rng(4)
    qt = _make_qt(rng, rows=64, stripe_spec=[(2, 64), (4, 64)], k_out=2)
    pqt = prepare_for_inference(qt)
    x = jnp.asarray(rng.normal(size=(3, qt.cols)).astype(np.float32))

    @jax.jit
    def f(x, p):
        return ops.qmatmul(x, p, use_kernel=True, interpret=True)

    y = f(x, pqt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref_lib.ref_qmatmul(x, qt)),
                               rtol=1e-4, atol=1e-3)
    leaves = jax.tree_util.tree_leaves(pqt)
    assert all(isinstance(l, jax.Array) for l in leaves)
