"""Bit-packing roundtrip properties (incl. the 3-bit two-plane scheme)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import packing


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from([1, 2, 3, 4, 8]),
       rows=st.integers(1, 130), cols=st.integers(1, 9),
       seed=st.integers(0, 10_000))
def test_roundtrip(bits, rows, cols, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** bits, size=(rows, cols)).astype(np.int32)
    packed = packing.pack_codes(jnp.asarray(codes), bits)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (packing.packed_rows(rows, bits), cols)
    out = packing.unpack_codes(packed, bits, rows)
    assert np.array_equal(np.asarray(out), codes)


def test_storage_is_exact_bits():
    # 3-bit = exactly 3 bits/element via bit-planes (not 3.2 like 10-in-32)
    for bits in (1, 2, 3, 4, 8):
        assert packing.storage_bits_per_element(bits) == float(bits)
        rows = 320
        assert packing.packed_rows(rows, bits) * 32 == rows * bits


def test_split_planes_consistent():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 8, size=(64, 3)).astype(np.int32)
    packed = packing.pack_codes(jnp.asarray(codes), 3)
    lo, hi = packing.split_planes(packed, 3, 64)
    assert lo.shape == (64 // 16, 3)
    assert hi.shape == (64 // 32, 3)
    lo_codes = packing._unpack_plane(lo, 2, 64)
    hi_codes = packing._unpack_plane(hi, 1, 64)
    recon = np.asarray(lo_codes) | (np.asarray(hi_codes) << 2)
    assert np.array_equal(recon, codes)
